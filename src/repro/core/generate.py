"""Token-generation driver (paper Fig. 3 workflow, single-mesh/monolithic).

Implements the paper's two RALM loops:
  * decoder-only, interval 1: every step retrieves with the hidden state and
    interpolates next-token distributions (kNN-LM);
  * encoder-decoder, interval N: every N steps the hidden state retrieves
    text chunks, the shallow encoder re-encodes them, and the decoder
    cross-attends until the next retrieval boundary (RETRO).

The disaggregated variant of the same loop lives in ``core/coordinator.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rag as rag_lib
from repro.core.chamvs import ChamVSConfig, search_single
from repro.core.ivfpq import IVFPQParams, IVFPQShard
from repro.core.rag import RagConfig
from repro.models import transformer as tf
from repro.models.config import ModelConfig


@dataclasses.dataclass
class RetrievalEngine:
    """Host-facing handle on ChamVS (single-process flavor for tests and
    examples; the distributed flavor plugs the shard_map search in)."""
    params: IVFPQParams
    shards: list
    cfg: ChamVSConfig
    payload_tokens: Optional[jnp.ndarray] = None   # [N] next-token table
    chunk_table: Optional[jnp.ndarray] = None      # [N, chunk_len]
    query_proj: Optional[jnp.ndarray] = None       # [d_model, dq]

    def search(self, queries: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        q = queries.astype(jnp.float32)
        if self.query_proj is not None:
            q = q @ self.query_proj
        return search_single(self.params, self.shards, q, self.cfg)


def generate(
    params,
    cfg: ModelConfig,
    rag: RagConfig,
    prompt: jnp.ndarray,               # [B, T0] int32
    steps: int,
    engine: Optional[RetrievalEngine] = None,
    max_seq: Optional[int] = None,
    greedy: bool = True,
    rng: Optional[jax.Array] = None,
    trace: Optional[list] = None,
) -> jnp.ndarray:
    """Generate ``steps`` tokens after ``prompt``. Returns [B, T0+steps].

    ``trace``: optional list collecting per-step dicts (retrieved ids etc.)
    for the benchmarks."""
    B, T0 = prompt.shape
    max_seq = max_seq or (T0 + steps)
    enc_len = rag.k * rag.chunk_len if rag.mode == "retro" else 0
    caches = tf.init_cache(cfg, B, max_seq=max_seq, enc_len=0)

    enc_states = None
    if cfg.arch == "encdec":
        # initial encoder pass over an empty/neutral chunk set
        neutral = jnp.zeros((B, max(enc_len, 8)), jnp.int32)
        enc_states = tf.encode(params, cfg, tf.embed_tokens(params, neutral))

    pos = jnp.broadcast_to(jnp.arange(T0)[None], (B, T0))
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, T0))
    logits_last, caches = tf.forward(params, cfg, tokens=prompt,
                                     positions=pos, mode="prefill",
                                     caches=caches, enc_states=enc_states)
    logits_last = logits_last[:, None] if logits_last.ndim == 2 else logits_last

    out = [prompt]
    cur = prompt[:, -1:]
    last_logits = None
    for s in range(steps):
        position = jnp.full((B,), T0 + s - 1 if s > 0 else T0 - 1, jnp.int32)
        if s == 0:
            # prefill already consumed the prompt; decode the first new token
            # from the prefill logits' hidden? — simplest: run decode on the
            # final prompt token again is wrong; instead sample from prefill
            # logits directly.
            step_logits = logits_last[:, -1]
            hidden = None
        else:
            step_logits, caches, hidden = tf.decode_step(
                params, cfg, caches, cur, position, enc_states=enc_states,
                return_hidden=True)
        log_or_prob = step_logits
        if engine is not None and rag.mode != "none" and \
                bool(rag_lib.should_retrieve(jnp.asarray(s), rag.interval)):
            if hidden is None:
                # use embedding of current token as a stand-in query at s=0
                hidden = tf.embed_tokens(params, cur)[:, 0]
            dists, ids = engine.search(hidden)
            if trace is not None:
                trace.append(dict(step=s, ids=np.asarray(ids)))
            if rag.mode == "knnlm":
                toks = rag_lib.gather_payload(engine.payload_tokens, ids)
                toks = jnp.where(ids >= 0, toks, -1)
                log_or_prob = rag_lib.knnlm_interpolate(
                    step_logits, dists, toks, rag.lam, rag.temperature)
            elif rag.mode == "retro" and cfg.arch == "encdec":
                chunks = rag_lib.retro_neighbor_tokens(engine.chunk_table, ids)
                emb = tf.embed_tokens(params, chunks.reshape(B, -1))
                enc_states = tf.encode(params, cfg, emb)
        if greedy or rng is None:
            nxt = jnp.argmax(log_or_prob, axis=-1).astype(jnp.int32)
        else:
            rng, k = jax.random.split(rng)
            nxt = jax.random.categorical(k, log_or_prob).astype(jnp.int32)
        cur = nxt[:, None]
        out.append(cur)
    return jnp.concatenate(out, axis=1)
