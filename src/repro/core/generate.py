"""Compatibility shim over ``repro.serve`` (the old monolithic entry
point).

The single-mesh generation loop that used to live here — and its
divergent twin in ``core/coordinator.py`` — were unified into
``repro.serve.engine.RalmEngine``; see ``docs/serving.md`` for the
migration table. This module keeps the historical surface importable:

  * ``RetrievalEngine`` — now an alias of ``repro.serve.LocalRetriever``
    (same field layout, plus the ``resolve()`` required by the
    ``Retriever`` protocol);
  * ``generate(...)`` — same signature and semantics, implemented as a
    one-request ``RalmEngine.monolithic`` run.

New code should use ``repro.serve`` directly.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.rag import RagConfig
from repro.models.config import ModelConfig
from repro.serve.api import LocalRetriever
from repro.serve.engine import RalmEngine


class RetrievalEngine(LocalRetriever):
    """Deprecated name for ``repro.serve.LocalRetriever``."""

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "repro.core.generate.RetrievalEngine is deprecated; use "
            "repro.serve.LocalRetriever (same fields) or "
            "Datastore.retriever(...)", DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)


def generate(
    params,
    cfg: ModelConfig,
    rag: RagConfig,
    prompt: jnp.ndarray,               # [B, T0] int32
    steps: int,
    engine: Optional[LocalRetriever] = None,
    max_seq: Optional[int] = None,
    greedy: bool = True,
    rng: Optional[jax.Array] = None,
    trace: Optional[list] = None,
) -> jnp.ndarray:
    """Generate ``steps`` tokens after ``prompt``. Returns [B, T0+steps].

    ``trace``: optional list collecting per-step dicts (retrieved ids
    etc.) for the benchmarks."""
    warnings.warn(
        "repro.core.generate.generate is deprecated; use "
        "repro.serve.RalmEngine.monolithic(...).generate(...)",
        DeprecationWarning, stacklevel=2)
    ralm = RalmEngine.monolithic(params, cfg, rag, retriever=engine,
                                 max_seq=max_seq)
    return ralm.generate(prompt, steps, greedy=greedy, rng=rng, trace=trace)
