"""IVF-PQ in pure JAX — the algorithmic substrate of ChamVS (paper §2.2, §4).

Implements the full index lifecycle:
  * training (coarse k-means quantizer + per-subspace PQ codebooks),
  * encoding (optionally residual, as in Faiss IVFPQ and the paper's
    per-IVF-list lookup tables),
  * the padded-list physical layout the accelerator scans (paper §4.3:
    each memory node holds an equal slice of *every* IVF list, physically
    contiguous, no pointer chasing),
  * a reference search pipeline (`search_ref`) that is the oracle for the
    Pallas kernels and doubles as the paper's CPU-flavor baseline.

All search-time functions are jit-compatible with static shapes; index
construction is host-side (numpy allowed) as in any real system.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import kmeans, _pairwise_sq_l2


@dataclasses.dataclass(frozen=True)
class IVFPQConfig:
    """Static description of an IVF-PQ index (paper Table 1 symbols)."""

    dim: int                 # D — vector dimensionality
    nlist: int               # number of IVF lists (clusters)
    m: int                   # PQ sub-spaces (bytes per code at nbits=8)
    nbits: int = 8           # bits per sub-quantizer: 8 (paper) or 4 (fast-scan)
    residual: bool = True    # encode residual to coarse centroid (Faiss default)
    list_cap: int = 128      # per-shard padded capacity of each IVF list

    @property
    def ksub(self) -> int:
        return 1 << self.nbits

    @property
    def dsub(self) -> int:
        assert self.dim % self.m == 0, f"dim {self.dim} % m {self.m} != 0"
        return self.dim // self.m

    def db_bytes_per_vector(self) -> float:
        """PQ code + vector-ID footprint (paper Table 3 'PQ and vec ID')."""
        return self.m * self.nbits / 8 + 4


class IVFPQParams(NamedTuple):
    """Learned quantizers (replicated or model-sharded at serve time)."""

    coarse_centroids: jnp.ndarray   # [nlist, D] f32
    codebooks: jnp.ndarray          # [m, ksub, dsub] f32


class IVFPQShard(NamedTuple):
    """One memory node's slice of the database (paper partition scheme 1).

    Every list is padded to `cap` entries so all shapes are static; `list_len`
    carries the valid prefix length. The flat [nlist, cap, m] layout is the
    physical-address-space analogue of the paper's §4.3 memory management.
    """

    codes: jnp.ndarray      # [nlist, cap, m] uint8 (values < ksub)
    ids: jnp.ndarray        # [nlist, cap] int32 (global vector ids, -1 = pad)
    list_len: jnp.ndarray   # [nlist] int32


def train_ivfpq(
    key: jax.Array,
    train_vecs: jnp.ndarray,
    cfg: IVFPQConfig,
    kmeans_iters: int = 15,
) -> IVFPQParams:
    """Train coarse quantizer + PQ codebooks (host-side, one-off)."""
    kc, kp = jax.random.split(key)
    train_vecs = jnp.asarray(train_vecs, jnp.float32)
    coarse, assign = kmeans(kc, train_vecs, cfg.nlist, iters=kmeans_iters)
    if cfg.residual:
        target = train_vecs - coarse[assign]
    else:
        target = train_vecs
    sub = target.reshape(-1, cfg.m, cfg.dsub)            # [n, m, dsub]
    keys = jax.random.split(kp, cfg.m)
    # vmap over sub-spaces: independent k-means per sub-quantizer.
    cb, _ = jax.vmap(lambda k, x: kmeans(k, x, cfg.ksub, iters=kmeans_iters))(
        keys, jnp.swapaxes(sub, 0, 1)
    )
    return IVFPQParams(coarse_centroids=coarse, codebooks=cb)


@jax.jit
def assign_coarse(params: IVFPQParams, vecs: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmin(_pairwise_sq_l2(vecs, params.coarse_centroids), axis=-1)


def encode(params: IVFPQParams, vecs: jnp.ndarray, cfg: IVFPQConfig,
           assign: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """PQ-encode vectors. Returns (codes [n, m] uint8, coarse assignment [n])."""
    vecs = jnp.asarray(vecs, jnp.float32)
    if assign is None:
        assign = assign_coarse(params, vecs)
    target = vecs - params.coarse_centroids[assign] if cfg.residual else vecs
    sub = jnp.swapaxes(target.reshape(-1, cfg.m, cfg.dsub), 0, 1)  # [m, n, dsub]
    codes = jax.vmap(lambda x, c: jnp.argmin(_pairwise_sq_l2(x, c), axis=-1))(
        sub, params.codebooks
    )                                                    # [m, n]
    return codes.T.astype(jnp.uint8), assign


def build_shards(
    params: IVFPQParams,
    vecs: np.ndarray,
    cfg: IVFPQConfig,
    num_shards: int,
    start_id: int = 0,
    encode_batch: int = 65536,
) -> list[IVFPQShard]:
    """Host-side index build: encode, bucket by list, stripe each list evenly
    across shards (paper's balanced partitioning), pad to `cfg.list_cap`.

    Raises if any per-shard list slice exceeds capacity — capacity is a
    deployment parameter, overflow is a config error, not data loss.
    """
    n = vecs.shape[0]
    all_codes = np.empty((n, cfg.m), np.uint8)
    all_assign = np.empty((n,), np.int64)
    for s in range(0, n, encode_batch):
        e = min(n, s + encode_batch)
        c, a = encode(params, jnp.asarray(vecs[s:e]), cfg)
        all_codes[s:e] = np.asarray(c)
        all_assign[s:e] = np.asarray(a)
    ids = np.arange(start_id, start_id + n, dtype=np.int32)

    order = np.argsort(all_assign, kind="stable")
    sorted_codes, sorted_ids = all_codes[order], ids[order]
    sorted_assign = all_assign[order]
    list_starts = np.searchsorted(sorted_assign, np.arange(cfg.nlist))
    list_ends = np.searchsorted(sorted_assign, np.arange(cfg.nlist) + 1)

    shards = []
    for sh in range(num_shards):
        codes = np.zeros((cfg.nlist, cfg.list_cap, cfg.m), np.uint8)
        sids = np.full((cfg.nlist, cfg.list_cap), -1, np.int32)
        lens = np.zeros((cfg.nlist,), np.int32)
        for li in range(cfg.nlist):
            s, e = list_starts[li], list_ends[li]
            # stripe: shard `sh` takes elements sh, sh+num_shards, ...
            sl = slice(s + sh, e, num_shards)
            chunk_codes = sorted_codes[sl]
            chunk_ids = sorted_ids[sl]
            ln = len(chunk_ids)
            if ln > cfg.list_cap:
                raise ValueError(
                    f"list {li} shard {sh}: {ln} codes > cap {cfg.list_cap}; "
                    f"raise IVFPQConfig.list_cap"
                )
            codes[li, :ln] = chunk_codes
            sids[li, :ln] = chunk_ids
            lens[li] = ln
        shards.append(IVFPQShard(jnp.asarray(codes), jnp.asarray(sids), jnp.asarray(lens)))
    return shards


# ---------------------------------------------------------------------------
# Search-time pieces (jit-compatible, static shapes)
# ---------------------------------------------------------------------------

def scan_ivf_index(params: IVFPQParams, queries: jnp.ndarray, nprobe: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """ChamVS.idx — brute-force centroid scan + top-nprobe (paper step 2).

    Returns (probe_dists [nq, nprobe], probe_ids [nq, nprobe])."""
    d = _pairwise_sq_l2(queries, params.coarse_centroids)     # [nq, nlist]
    neg, idx = jax.lax.top_k(-d, nprobe)
    return -neg, idx


def compute_luts(params: IVFPQParams, queries: jnp.ndarray,
                 probe_ids: jnp.ndarray, cfg: IVFPQConfig) -> jnp.ndarray:
    """Distance lookup tables (paper Fig. 2 step 5 / Fig. 4 unit 2).

    Residual PQ -> one LUT per (query, probed list): [nq, nprobe, m, ksub].
    Non-residual -> LUT independent of the list; broadcast to the same shape
    so downstream code is uniform.
    """
    nq, nprobe = probe_ids.shape
    cb = params.codebooks                                     # [m, ksub, dsub]
    cb2 = jnp.sum(cb * cb, axis=-1)                           # [m, ksub]
    if cfg.residual:
        res = queries[:, None, :] - params.coarse_centroids[probe_ids]  # [nq,np,D]
        sub = res.reshape(nq, nprobe, cfg.m, cfg.dsub)
        # ||sub - cb||^2 = ||sub||^2 - 2 sub.cb + ||cb||^2 (matmul form —
        # the broadcast-difference form materializes an [nq,np,m,ksub,dsub]
        # tensor, 8.6 GB/device at serve scale; EXPERIMENTS.md §Perf it. 3)
        x2 = jnp.sum(sub * sub, axis=-1)                      # [nq, np, m]
        xc = jnp.einsum("qpmd,mkd->qpmk", sub, cb)            # MXU
        return x2[..., None] - 2.0 * xc + cb2[None, None]
    sub = queries.reshape(nq, cfg.m, cfg.dsub)
    x2 = jnp.sum(sub * sub, axis=-1)                          # [nq, m]
    xc = jnp.einsum("qmd,mkd->qmk", sub, cb)
    lut = x2[..., None] - 2.0 * xc + cb2[None]                # [nq, m, ksub]
    return jnp.broadcast_to(lut[:, None], (nq, nprobe, cfg.m, cfg.ksub))


def adc_scan_ref(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Asymmetric distance computation — gather formulation (the oracle).

    lut: [..., m, ksub] f32, codes: [..., n, m] uint8 -> [..., n] f32.
    This is exactly the paper's PQ decoding unit semantics: per byte, use the
    code as an address into the LUT column, then sum across the m sub-spaces.
    """
    gathered = jnp.take_along_axis(
        jnp.moveaxis(lut, -2, -1)[..., None, :, :],           # [..., 1, ksub, m]
        codes[..., None, :].astype(jnp.int32),                # [..., n, 1, m]
        axis=-2,
    )                                                         # [..., n, 1, m]
    return jnp.sum(gathered[..., 0, :], axis=-1)


def search_shard_ref(
    params: IVFPQParams,
    shard: IVFPQShard,
    queries: jnp.ndarray,
    probe_ids: jnp.ndarray,
    cfg: IVFPQConfig,
    k: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference per-shard search: LUT -> gather probed lists -> ADC -> top-k.

    Returns (dists [nq, k], ids [nq, k]) — this shard's candidates."""
    nq, nprobe = probe_ids.shape
    lut = compute_luts(params, queries, probe_ids, cfg)       # [nq,np,m,ksub]
    codes = shard.codes[probe_ids]                            # [nq,np,cap,m]
    ids = shard.ids[probe_ids]                                # [nq,np,cap]
    valid = (jnp.arange(cfg.list_cap)[None, None, :]
             < shard.list_len[probe_ids][..., None])          # [nq,np,cap]
    d = adc_scan_ref(lut, codes)                              # [nq,np,cap]
    d = jnp.where(valid, d, jnp.inf)
    flat_d = d.reshape(nq, -1)
    flat_i = ids.reshape(nq, -1)
    neg, pos = jax.lax.top_k(-flat_d, k)
    return -neg, jnp.take_along_axis(flat_i, pos, axis=-1)


def merge_topk(dists: jnp.ndarray, ids: jnp.ndarray, k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """K-way merge of per-shard candidates (paper step 8, CPU aggregation).

    dists/ids: [num_shards, nq, kk] -> ([nq, k], [nq, k]). The merge
    itself is first-class in ``repro.retrieval.merge`` (which also has
    the hierarchical tree variant); this delegates to the flat form."""
    from repro.retrieval.merge import flat_merge
    return flat_merge(dists, ids, k)


def exact_search(vecs: jnp.ndarray, queries: jnp.ndarray, k: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact brute-force nearest neighbors — ground truth for recall@K."""
    d = _pairwise_sq_l2(queries, vecs)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


def recall_at_k(found_ids: jnp.ndarray, true_ids: jnp.ndarray) -> float:
    """R@K: overlap between returned and exact top-K (paper §2.2)."""
    hits = (found_ids[:, :, None] == true_ids[:, None, :]).any(-1).sum(-1)
    return float(jnp.mean(hits / true_ids.shape[-1]))
