"""Sizing math for the approximate hierarchical priority queue (paper §4.2.2).

The paper's insight: with `num_queues` independent producers each keeping a
local top-`k'` queue, the probability that any single producer holds more than
`k'` of the global top-K results is a binomial tail. Truncating the level-one
queues from K to k' saves ~an order of magnitude of queue state (Fig. 8) while
returning results identical to exact K-selection for >= (1 - eps) of queries
(paper targets 99%).

On TPU the "producer" is a Pallas grid block scanning a slice of the database
(DESIGN.md section 3); the math is unchanged because it only depends on the
assumption that top-K elements land on producers uniformly at random — true
when clusters are striped evenly across blocks (paper's partition scheme 1).
"""
from __future__ import annotations

import math


def binom_pmf(n: int, p: float, k: int) -> float:
    """P[Binomial(n, p) == k]."""
    if k < 0 or k > n:
        return 0.0
    return math.comb(n, k) * (p ** k) * ((1.0 - p) ** (n - k))


def binom_tail(n: int, p: float, k: int) -> float:
    """P[Binomial(n, p) > k]."""
    return max(0.0, 1.0 - sum(binom_pmf(n, p, i) for i in range(k + 1)))


def queue_overflow_prob(K: int, num_queues: int, k_prime: int) -> float:
    """P[at least one of `num_queues` L1 queues receives > k_prime of the top-K].

    Union bound over queues of the single-queue binomial tail (paper's p(k)/P(k),
    Fig. 7, made conservative via the union bound so the guarantee is a bound,
    not an approximation)."""
    tail = binom_tail(K, 1.0 / num_queues, k_prime)
    return min(1.0, num_queues * tail)


def truncated_queue_len(K: int, num_queues: int, eps: float = 0.01) -> int:
    """Smallest k' such that P[any L1 queue overflows] <= eps (paper: eps=1%).

    Monotone in k' -> linear scan (K is small, <= a few hundred)."""
    if num_queues <= 1:
        return K
    for k_prime in range(1, K + 1):
        if queue_overflow_prob(K, num_queues, k_prime) <= eps:
            return k_prime
    return K


def resource_saving(K: int, num_queues: int, eps: float = 0.01) -> float:
    """Fig. 8 metric: (exact L1 state) / (truncated L1 state).

    Exact hierarchical design needs num_queues * K entries; the approximate
    design needs num_queues * k'."""
    kp = truncated_queue_len(K, num_queues, eps)
    return K / kp
