"""RALM integration modes (paper §2.1) — how retrieved knowledge enters the LM.

Two categories, exactly as the paper classifies them:

1. **Token-level, decoder-only (kNN-LM family)** [Khandelwal et al.; paper's
   Dec-S/Dec-L, retrieval interval 1]: the last layer's hidden state is the
   query; each database vector maps to the *next token* of its context; the
   LM's next-token distribution is interpolated with a distance-weighted
   distribution over retrieved next-tokens.

2. **Chunk-level, encoder-decoder (RETRO family)** [Borgeaud et al.; paper's
   EncDec-S/EncDec-L, intervals 8/64/512]: retrieved text chunks are encoded
   by a shallow encoder and injected into the decoder via cross-attention.

The vector-ID -> payload conversion (paper step 9, done by the CPU server) is
a device-side gather from payload tables here (token table for kNN-LM, chunk
table for RETRO); the disaggregated coordinator does the same gather on host.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RagConfig:
    mode: str = "knnlm"            # "knnlm" | "retro" | "none"
    interval: int = 1              # retrieve every N generated tokens
    k: int = 100                   # neighbors (paper Table 2)
    lam: float = 0.25              # kNN-LM interpolation weight
    temperature: float = 10.0      # kNN softmax temperature over L2^2 dists
    chunk_len: int = 64            # RETRO chunk length (tokens per neighbor)


def knnlm_interpolate(
    lm_logits: jnp.ndarray,        # [B, V]
    knn_dists: jnp.ndarray,        # [B, K] (L2^2, +inf = missing)
    knn_tokens: jnp.ndarray,       # [B, K] int32 (-1 = missing)
    lam: float,
    temperature: float,
) -> jnp.ndarray:
    """log p = log((1-lam) softmax(lm_logits) + lam p_knn)  -> [B, V].

    p_knn(w) ∝ sum_{i: tok_i = w} exp(-d_i / T)  (kNN-LM, interval-1 RALMs).
    Invalid neighbors (inf dist / id -1) contribute zero mass; if a row has no
    valid neighbor, the result degrades gracefully to the pure LM distribution.
    """
    B, V = lm_logits.shape
    valid = (knn_tokens >= 0) & jnp.isfinite(knn_dists)
    logw = jnp.where(valid, -knn_dists / temperature, -jnp.inf)
    # stable softmax over the neighbor axis; rows with no valid neighbor
    # produce weight 0 for every neighbor.
    m = jnp.max(jnp.where(valid, logw, -jnp.inf), axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    w = jnp.where(valid, jnp.exp(logw - m), 0.0)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    has_knn = denom[:, 0] > 0
    w = w / jnp.maximum(denom, 1e-20)                      # [B, K]
    tok = jnp.maximum(knn_tokens, 0)
    p_knn = jnp.zeros((B, V), jnp.float32).at[
        jnp.arange(B)[:, None], tok].add(w.astype(jnp.float32))
    p_lm = jax.nn.softmax(lm_logits.astype(jnp.float32), axis=-1)
    lam_row = jnp.where(has_knn, lam, 0.0)[:, None]
    mixed = (1.0 - lam_row) * p_lm + lam_row * p_knn
    return jnp.log(jnp.maximum(mixed, 1e-20))


def gather_payload(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Vector-ID -> payload (paper step 9). ids [B, K] (-1 = missing) against
    table [N, ...]; missing ids return row 0 (callers mask by id)."""
    return table[jnp.maximum(ids, 0)]


def retro_neighbor_tokens(
    chunk_table: jnp.ndarray,      # [N, chunk_len] int32
    ids: jnp.ndarray,              # [B, K]
) -> jnp.ndarray:
    """Retrieved chunks for the RETRO encoder: [B, K, chunk_len]; missing
    neighbors yield PAD (token 0) rows."""
    toks = gather_payload(chunk_table, ids)
    return jnp.where((ids >= 0)[..., None], toks, 0)


def should_retrieve(step: jnp.ndarray, interval: int) -> jnp.ndarray:
    """Paper §2.1: interval-1 RALMs retrieve every step; interval-N at every
    Nth generated token (and always at step 0)."""
    if interval <= 1:
        return jnp.asarray(True)
    return (step % interval) == 0
