"""Lloyd's k-means in pure JAX — substrate for IVF coarse quantizer and PQ codebooks.

Used at index-build time (ChamVS.idx training). jit-compiled, static shapes,
k-means++-style seeding via distance-weighted sampling (one pass, cheap
approximation), empty-cluster repair by splitting the largest cluster.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _pairwise_sq_l2(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """[n, d] x [k, d] -> [n, k] squared L2 distances (matmul form, MXU-friendly)."""
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)            # [n, 1]
    c2 = jnp.sum(c * c, axis=-1)                           # [k]
    xc = x @ c.T                                           # [n, k]
    return x2 - 2.0 * xc + c2[None, :]


def _init_centroids(key: jax.Array, x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Distance-weighted seeding: pick one uniform seed, then sample k-1 points
    with probability proportional to distance to the first seed (cheap single-pass
    k-means++ approximation; exact k-means++ is O(n*k) sequential)."""
    n = x.shape[0]
    k0, k1 = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    d = jnp.sum((x - x[first]) ** 2, axis=-1)
    # Gumbel-top-k trick for weighted sampling without replacement.
    logits = jnp.log(d + 1e-12)
    g = jax.random.gumbel(k1, (n,))
    _, idx = jax.lax.top_k(logits + g, k - 1)
    return jnp.concatenate([x[first][None], x[idx]], axis=0)


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(
    key: jax.Array, x: jnp.ndarray, k: int, iters: int = 20
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run Lloyd's algorithm. Returns (centroids [k, d], assignment [n]).

    Deterministic given `key`. Handles empty clusters by re-seeding them at the
    point farthest from its assigned centroid (largest-loss point)."""
    x = x.astype(jnp.float32)
    n, d = x.shape
    cent0 = _init_centroids(key, x, k)

    def step(cent, _):
        dist = _pairwise_sq_l2(x, cent)                    # [n, k]
        assign = jnp.argmin(dist, axis=-1)                 # [n]
        one_hot = jax.nn.one_hot(assign, k, dtype=x.dtype) # [n, k]
        counts = one_hot.sum(axis=0)                       # [k]
        sums = one_hot.T @ x                               # [k, d]
        new_cent = sums / jnp.maximum(counts, 1.0)[:, None]
        # Empty-cluster repair: move empty centroids onto the globally
        # worst-represented points (one per empty slot, by rank).
        point_loss = jnp.min(dist, axis=-1)                # [n]
        _, worst = jax.lax.top_k(point_loss, k)            # [k] farthest points
        empty = counts < 0.5
        rank = jnp.cumsum(empty.astype(jnp.int32)) - 1     # slot -> which worst pt
        repair = x[worst[jnp.clip(rank, 0, k - 1)]]
        new_cent = jnp.where(empty[:, None], repair, new_cent)
        return new_cent, None

    cent, _ = jax.lax.scan(step, cent0, None, length=iters)
    assign = jnp.argmin(_pairwise_sq_l2(x, cent), axis=-1)
    return cent, assign
