"""Compatibility shim over ``repro.serve`` (the old disaggregated entry
point).

The paper's CPU-coordinator + independent-accelerator-pools runtime
(§3) now lives in ``repro.serve``:

  * pool split + timed decode  -> ``serve.engine.DisaggregatedBackend``
  * distributed search/gather  -> ``serve.api.DistributedRetriever``
  * the pipelined loop         -> ``serve.scheduler.RalmScheduler``
  * Fig. 13 ratio tracking     -> ``serve.engine.PoolTimes``

``DisaggregatedRuntime`` keeps the historical constructor and
``generate_pipelined`` surface on top of a ``RalmEngine``; new code
should build the engine directly (``RalmEngine.disaggregated`` or
``RalmEngine.from_config``).
"""
from __future__ import annotations

import warnings
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.chamvs import ChamVSConfig
from repro.core.ivfpq import IVFPQParams, IVFPQShard
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig
from repro.serve.engine import PoolTimes, RalmEngine

__all__ = ["DisaggregatedRuntime", "PoolTimes"]


class DisaggregatedRuntime:
    """Deprecated facade over ``RalmEngine.disaggregated``.

    lm_devices / ret_devices: device counts for each pool (must sum to at
    most len(jax.devices())). Retrieval pool axes: ("data",) memory nodes.
    """

    def __init__(self, cfg: ModelConfig, rag: RagConfig, params,
                 db_params: IVFPQParams, db_shards: List[IVFPQShard],
                 chamvs_cfg: ChamVSConfig,
                 payload_tokens: Optional[jnp.ndarray] = None,
                 lm_devices: int = 1, ret_devices: int = 1,
                 query_proj: Optional[jnp.ndarray] = None):
        warnings.warn(
            "repro.core.coordinator.DisaggregatedRuntime is deprecated; "
            "use repro.serve.RalmEngine.disaggregated(...) or "
            "RalmEngine.from_config(...)", DeprecationWarning, stacklevel=2)
        self.cfg, self.rag = cfg, rag
        self.engine = RalmEngine.disaggregated(
            params, cfg, rag, db_params, db_shards, chamvs_cfg,
            payload_tokens=payload_tokens, lm_devices=lm_devices,
            ret_devices=ret_devices, query_proj=query_proj)

    @property
    def times(self) -> PoolTimes:
        return self.engine.times

    @property
    def lm_mesh(self):
        return self.engine.backend.lm_mesh

    @property
    def ret_mesh(self):
        return self.engine.backend.ret_mesh

    # ------------------------------------------------------------------
    def search(self, queries: jnp.ndarray):
        return self.engine._search(jnp.asarray(queries, jnp.float32))

    def decode(self, caches, token, position):
        return self.engine.backend.decode(caches, token, position)

    # ------------------------------------------------------------------
    def generate_pipelined(self, prompts: List[jnp.ndarray], steps: int
                           ) -> List[np.ndarray]:
        """Pipelined decode/search across request batches — now the
        scheduler's two-phase step. Each entry of ``prompts`` is one
        batch [B, T0]."""
        return self.engine.generate_batches(prompts, steps)
