"""The disaggregated runtime (paper §3: CPU coordinator + independent
accelerator pools).

Chameleon's core system claim is that LM accelerators and retrieval
accelerators must scale *independently* because the optimal ratio between
them varies by orders of magnitude across RALM configs (Fig. 13). This
module realizes that on a JAX device set:

  * the device set is split into an **LM pool** and a **retrieval pool**
    (the ratio is a constructor argument — the Fig. 13 knob);
  * each pool gets its own mesh and its own compiled programs (decode_step
    on the LM pool; ChamVS distributed search on the retrieval pool);
  * the coordinator pipelines multiple request batches: while batch A's
    queries are being searched on the retrieval pool, batch B decodes on
    the LM pool (the paper's multi-process ChamLM overlap). JAX dispatch is
    async, so interleaved submission yields real overlap on real hardware;
  * vector-ID -> payload conversion happens on the coordinator host
    (paper step 9).

For kNN-LM (interval 1) the within-sequence dependency decode -> search ->
interpolate -> sample is fundamental (the paper's Fig. 11 latency plots show
it); cross-batch pipelining is where disaggregation wins throughput
(Fig. 12), which benchmarks/fig12_throughput.py measures.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import chamvs as chamvs_lib
from repro.core import rag as rag_lib
from repro.core.chamvs import ChamVSConfig
from repro.core.ivfpq import IVFPQParams, IVFPQShard
from repro.core.rag import RagConfig
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.launch.mesh import make_mesh_for


@dataclasses.dataclass
class PoolTimes:
    decode_s: List[float] = dataclasses.field(default_factory=list)
    search_s: List[float] = dataclasses.field(default_factory=list)

    def optimal_ratio(self) -> float:
        """Paper Fig. 13: LM-pool units needed to saturate one retrieval
        engine = (retrieval throughput) / (decode throughput) per batch."""
        if not self.decode_s or not self.search_s:
            return float("nan")
        return float(np.median(self.decode_s) / np.median(self.search_s))


class DisaggregatedRuntime:
    """Two device pools + pipelined batches.

    lm_devices / ret_devices: device counts for each pool (must sum to at
    most len(jax.devices())). Retrieval pool axes: ("data",) memory nodes.
    """

    def __init__(self, cfg: ModelConfig, rag: RagConfig, params,
                 db_params: IVFPQParams, db_shards: List[IVFPQShard],
                 chamvs_cfg: ChamVSConfig,
                 payload_tokens: Optional[jnp.ndarray] = None,
                 lm_devices: int = 1, ret_devices: int = 1,
                 query_proj: Optional[jnp.ndarray] = None):
        devs = jax.devices()
        assert lm_devices + ret_devices <= len(devs), (
            lm_devices, ret_devices, len(devs))
        self.cfg, self.rag = cfg, rag
        self.params = params
        self.payload_tokens = payload_tokens
        self.query_proj = query_proj
        self.times = PoolTimes()

        # LM pool: pure data-parallel decode (each unit = one "GPU process")
        self.lm_mesh = make_mesh_for(devs[:lm_devices], data=lm_devices)
        # Retrieval pool: ChamVS memory nodes over its own mesh
        self.ret_mesh = make_mesh_for(devs[lm_devices:lm_devices + ret_devices],
                                      data=ret_devices)
        self.chamvs_cfg = chamvs_cfg
        assert len(db_shards) == ret_devices, "one shard per memory node"
        stacked = chamvs_lib.stack_shards(db_shards)
        from jax.sharding import NamedSharding, PartitionSpec as P
        self.db_params = jax.device_put(
            db_params, NamedSharding(self.ret_mesh, P()))
        self.db_shard = jax.device_put(
            stacked, NamedSharding(self.ret_mesh, P("data")))
        self._search = jax.jit(chamvs_lib.make_distributed_search(
            self.ret_mesh, chamvs_cfg, db_axes=("data",), query_axis=None))

        def _decode(params, caches, token, position):
            return tf.decode_step(params, self.cfg, caches, token, position,
                                  return_hidden=True)

        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------------
    def search(self, queries: jnp.ndarray):
        q = jnp.asarray(queries, jnp.float32)
        if self.query_proj is not None:
            q = q @ self.query_proj
        t0 = time.time()
        with jax.set_mesh(self.ret_mesh):
            d, i = self._search(self.db_params, self.db_shard, q)
        d.block_until_ready()
        self.times.search_s.append(time.time() - t0)
        return d, i

    def decode(self, caches, token, position):
        t0 = time.time()
        with jax.set_mesh(self.lm_mesh):
            logits, caches, hidden = self._decode(self.params, caches,
                                                  token, position)
        logits.block_until_ready()
        self.times.decode_s.append(time.time() - t0)
        return logits, caches, hidden

    # ------------------------------------------------------------------
    def generate_pipelined(self, prompts: List[jnp.ndarray], steps: int
                           ) -> List[np.ndarray]:
        """Round-robin decode/search across request batches (paper's
        coordinator loop). Each entry of ``prompts`` is one batch [B, T0]."""
        states = []
        for prompt in prompts:
            B, T0 = prompt.shape
            caches = tf.init_cache(self.cfg, B, max_seq=T0 + steps)
            pos = jnp.broadcast_to(jnp.arange(T0)[None], (B, T0))
            with jax.set_mesh(self.lm_mesh):
                _, caches = tf.forward(self.params, self.cfg, tokens=prompt,
                                       positions=pos, mode="prefill",
                                       caches=caches)
            states.append(dict(caches=caches, out=[prompt],
                               cur=prompt[:, -1:], t0=T0))
        for s in range(steps):
            # stage 1: decode every batch (async dispatch per batch)
            pending = []
            for st in states:
                B = st["cur"].shape[0]
                position = jnp.full((B,), st["t0"] + s - 1, jnp.int32)
                logits, st["caches"], hidden = self.decode(
                    st["caches"], st["cur"], position)
                pending.append((st, logits, hidden))
            # stage 2: retrieval for all batches (overlaps next decode on HW)
            for st, logits, hidden in pending:
                out = logits
                if self.rag.mode == "knnlm" and \
                        (s % max(self.rag.interval, 1)) == 0:
                    dists, ids = self.search(hidden)
                    toks = rag_lib.gather_payload(self.payload_tokens, ids)
                    toks = jnp.where(ids >= 0, toks, -1)
                    out = rag_lib.knnlm_interpolate(
                        logits, dists, toks, self.rag.lam,
                        self.rag.temperature)
                nxt = jnp.argmax(out, axis=-1).astype(jnp.int32)
                st["cur"] = nxt[:, None]
                st["out"].append(st["cur"])
        return [np.asarray(jnp.concatenate(st["out"], axis=1))
                for st in states]
