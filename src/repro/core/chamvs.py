"""ChamVS — the distributed, accelerated vector search engine (paper §3–§4).

Maps the paper's disaggregated architecture onto a JAX device mesh:

  * **Memory nodes** (paper: FPGA + DRAM) = shards of the PQ database laid out
    over the ``db_axes`` mesh axes (default ``("pod", "data")``). Every IVF
    list is striped evenly across all shards (partition scheme 1, §4.3), so
    any nprobe selection produces balanced scan work.
  * **Index scanner** (paper: GPU ChamVS.idx) = replicated centroid scan +
    top-nprobe, executed where the queries live.
  * **Query broadcast / result aggregation** (paper: CPU coordinator, steps
    3–9) = ``all_gather`` of the query batch onto every shard, local
    ADC + truncated top-k' per shard, ``all_gather`` of the k' survivors,
    exact top-K merge — all in-graph over ICI instead of TCP/IP.

Work parallelism: on top of DB sharding, the query batch is split over the
``query_axis`` (default ``"model"``) so the LUT construction + ADC scan for
different queries run on different TP columns of the same DB shard row.

The ADC + K-selection backends are pluggable:
  ``backend="ref"``    — pure-jnp gather ADC (paper's CPU flavor; also what the
                          multi-pod dry-run lowers, since Pallas does not
                          compile on the CPU backend).
  ``backend="pallas"`` — the near-memory Pallas kernels (interpret=True on CPU).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import ivfpq
from repro.core.approx_topk_math import truncated_queue_len
from repro.core.ivfpq import IVFPQConfig, IVFPQParams, IVFPQShard


@dataclasses.dataclass(frozen=True)
class ChamVSConfig:
    """Serve-time configuration of the search engine."""

    ivfpq: IVFPQConfig
    nprobe: int = 32
    k: int = 100
    eps: float = 0.01             # approx-queue failure budget (paper: 1%)
    backend: str = "ref"          # "ref" | "pallas"
    interpret: bool = True        # Pallas interpret mode (CPU container)
    num_l1_blocks: int = 16       # producers per shard for the approx queue

    def k_prime(self, num_shards: int) -> int:
        """Truncated per-shard queue length (paper §4.2.2): the shards are the
        level-one producers of the global top-K, so each only ships k' << K
        candidates over the network. Note k' > K/num_shards always holds, so
        the merge can always fill K slots."""
        return min(self.k, truncated_queue_len(self.k, max(1, num_shards),
                                               self.eps))


# ---------------------------------------------------------------------------
# per-shard search (runs inside shard_map; also usable standalone)
# ---------------------------------------------------------------------------

def shard_search(params: IVFPQParams, shard: IVFPQShard, queries: jnp.ndarray,
                 probe_ids: jnp.ndarray, cfg: ChamVSConfig, kk: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One memory node's work: LUTs -> stream probed lists -> ADC -> top-kk.

    Returns (dists [nq, kk], global_ids [nq, kk])."""
    icfg = cfg.ivfpq
    nq, nprobe = probe_ids.shape
    luts = ivfpq.compute_luts(params, queries, probe_ids, icfg)  # [nq,np,m,ksub]
    codes = shard.codes[probe_ids]                               # [nq,np,cap,m]
    ids = shard.ids[probe_ids]                                   # [nq,np,cap]
    lens = shard.list_len[probe_ids]                             # [nq,np]

    if cfg.backend == "pallas":
        from repro.kernels.pq_adc.ops import pq_adc_topk
        B = nq * nprobe
        d_l, i_l = pq_adc_topk(
            luts.reshape(B, icfg.m, icfg.ksub),
            codes.reshape(B, icfg.list_cap, icfg.m),
            lens.reshape(B),
            k=min(kk, icfg.list_cap),
            backend="pallas", interpret=cfg.interpret)
        # local row idx -> global vector id via the per-list id table
        gid = jnp.take_along_axis(
            ids.reshape(B, icfg.list_cap),
            jnp.maximum(i_l, 0), axis=1)
        gid = jnp.where(i_l < 0, -1, gid)
        kcap = d_l.shape[-1]
        d = d_l.reshape(nq, nprobe * kcap)
        g = gid.reshape(nq, nprobe * kcap)
    else:
        valid = (jnp.arange(icfg.list_cap)[None, None, :] < lens[..., None])
        d3 = ivfpq.adc_scan_ref(luts, codes)                     # [nq,np,cap]
        d3 = jnp.where(valid, d3, jnp.inf)
        d = d3.reshape(nq, -1)
        g = ids.reshape(nq, -1)

    neg, pos = jax.lax.top_k(-d, min(kk, d.shape[-1]))
    out_d = -neg
    out_i = jnp.take_along_axis(g, pos, axis=1)
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    if out_d.shape[-1] < kk:  # fewer candidates than kk: pad
        pad = kk - out_d.shape[-1]
        out_d = jnp.pad(out_d, ((0, 0), (0, pad)), constant_values=jnp.inf)
        out_i = jnp.pad(out_i, ((0, 0), (0, pad)), constant_values=-1)
    return out_d, out_i


def search_single(params: IVFPQParams, shards: list[IVFPQShard],
                  queries: jnp.ndarray, cfg: ChamVSConfig
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-process reference search over a list of shards (tests, builds)."""
    _, probe_ids = ivfpq.scan_ivf_index(params, queries, cfg.nprobe)
    kk = cfg.k_prime(len(shards))
    per = [shard_search(params, s, queries, probe_ids, cfg, kk) for s in shards]
    return ivfpq.merge_topk(jnp.stack([p[0] for p in per]),
                            jnp.stack([p[1] for p in per]), cfg.k)


# ---------------------------------------------------------------------------
# distributed search (shard_map over the production mesh)
# ---------------------------------------------------------------------------

def stack_shards(shards: list[IVFPQShard]) -> IVFPQShard:
    """[S] shards -> one IVFPQShard with a leading shard axis (to be placed
    with a sharded ``jax.device_put`` along the db axes)."""
    return IVFPQShard(
        codes=jnp.stack([s.codes for s in shards]),
        ids=jnp.stack([s.ids for s in shards]),
        list_len=jnp.stack([s.list_len for s in shards]),
    )


def make_distributed_search(
    mesh: Mesh,
    cfg: ChamVSConfig,
    db_axes: Tuple[str, ...] = ("data",),
    query_axis: Optional[str] = "model",
    nq: Optional[int] = None,
):
    """Build the in-graph distributed search fn for ``mesh``.

    Returns ``search(params, stacked_shard, queries) -> (dists, ids)`` with
    replicated outputs [nq, K]. ``stacked_shard`` must carry a leading shard
    axis of size prod(mesh[a] for a in db_axes).

    Work split over ``query_axis`` (the TP columns of each DB shard row):
      * query-split — each column searches nq/qsize queries (batch serving);
      * probe-split — when nq is not divisible (e.g. long-context batch 1),
        each column scans nprobe/qsize of every query's probed lists; the
        merge then spans shards x columns (more, shorter L1 queues — the
        paper's Fig. 8 regime).
    """
    db_axes = tuple(a for a in db_axes if a in mesh.axis_names)
    num_shards = 1
    for a in db_axes:
        num_shards *= mesh.shape[a]
    qa = query_axis if (query_axis and query_axis in mesh.axis_names) else None
    qsize = mesh.shape[qa] if qa else 1
    probe_split = bool(qa) and nq is not None and (
        nq % qsize != 0 and cfg.nprobe % qsize == 0)
    producers = num_shards * (qsize if probe_split else 1)
    kk = cfg.k_prime(producers)

    def body(params: IVFPQParams, shard: IVFPQShard, queries: jnp.ndarray):
        # shard: leading axis length 1 on this device; queries: [nq_local, D]
        local = jax.tree.map(lambda x: x[0], shard)
        nq_local = queries.shape[0]
        _, probe_ids = ivfpq.scan_ivf_index(params, queries, cfg.nprobe)
        if probe_split:
            npl = cfg.nprobe // qsize
            col = jax.lax.axis_index(qa)
            probe_ids = jax.lax.dynamic_slice_in_dim(
                probe_ids, col * npl, npl, axis=1)
        d, i = shard_search(params, local, queries, probe_ids, cfg, kk)
        # aggregate over memory nodes (paper step 7-8): gather the kk
        # survivors of every producer, then exact-merge.
        gather_axes = db_axes + ((qa,) if probe_split else ())
        if gather_axes:
            d = jax.lax.all_gather(d, gather_axes, axis=0, tiled=False)
            i = jax.lax.all_gather(i, gather_axes, axis=0, tiled=False)
            d = d.reshape(producers, nq_local, kk)
            i = i.reshape(producers, nq_local, kk)
            d = d.transpose(1, 0, 2).reshape(nq_local, producers * kk)
            i = i.transpose(1, 0, 2).reshape(nq_local, producers * kk)
        neg, pos = jax.lax.top_k(-d, min(cfg.k, d.shape[-1]))
        out_d = -neg
        out_i = jnp.take_along_axis(i, pos, axis=1)
        # un-split the query batch (it was sharded over the TP axis)
        if qa and not probe_split:
            out_d = jax.lax.all_gather(out_d, qa, axis=0, tiled=True)
            out_i = jax.lax.all_gather(out_i, qa, axis=0, tiled=True)
        return out_d, out_i

    shard_spec = IVFPQShard(
        codes=P(db_axes if db_axes else None),
        ids=P(db_axes if db_axes else None),
        list_len=P(db_axes if db_axes else None),
    )
    q_spec = P(qa) if (qa and not probe_split) else P()
    in_specs = (
        IVFPQParams(P(), P()),    # quantizers replicated (paper: metadata)
        shard_spec,
        q_spec,
    )
    out_specs = (P(), P())

    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)

    def search(params: IVFPQParams, stacked: IVFPQShard, queries: jnp.ndarray):
        n = queries.shape[0]
        if qa and not probe_split:
            assert n % qsize == 0, (n, qsize)
        return fn(params, stacked, queries)

    return search


def make_distributed_gather(mesh: Mesh, table_axes: Tuple[str, ...]):
    """ID -> payload conversion against a fully sharded table (paper step 9).

    ``table`` [N, ...] is sharded over ``table_axes``; ``ids`` [B, K] are
    replicated. A naive ``table[ids]`` makes GSPMD all-gather the whole
    table (measured 4 GB/step for the 1e9-entry token table —
    EXPERIMENTS.md §Perf iteration 2); instead each shard gathers the ids
    that fall in its range and a psum of the masked results (KB-scale)
    assembles the answer."""
    axes = tuple(a for a in table_axes if a in mesh.axis_names)
    nsh = 1
    for a in axes:
        nsh *= mesh.shape[a]

    def body(table, ids):
        # flattened shard index over `axes` (row-major over the mesh dims)
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        nloc = table.shape[0]
        lo = idx * nloc
        rel = ids - lo
        hit = (rel >= 0) & (rel < nloc)
        vals = table[jnp.clip(rel, 0, nloc - 1)]
        mask = hit.reshape(hit.shape + (1,) * (vals.ndim - hit.ndim))
        vals = jnp.where(mask, vals, 0)
        return jax.lax.psum(vals, axes)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axes), P()), out_specs=P(), check_vma=False)
