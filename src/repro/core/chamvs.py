"""ChamVS kernel frontend (paper §4): config + the per-shard scan.

This module is the *kernel* side of the search engine — one memory
node's LUT construction -> list streaming -> ADC -> truncated top-k',
with pluggable backends routed through ``repro.kernels.registry``
(``ChamVSConfig.kernel_spec()`` is the ``KernelSpec`` everything below
here runs with):

  ``backend="ref"``    — pure-jnp gather ADC (paper's CPU flavor; also what
                          the multi-pod dry-run lowers, since Pallas does
                          not compile on the CPU backend).
  ``backend="pallas"`` — the near-memory Pallas kernels (interpret=True on
                          CPU).

``shard_search`` below is the *staged* per-shard pipeline — kept as the
parity oracle for the fused path. The serving default
(``ChamVSConfig.fused=True``) runs ``kernels/chamvs_scan`` instead: ONE
dispatch covering ADC + streaming top-k' for every shard of a retrieval
wave (see ``retrieval/service._scan_stage_fused``).

Everything *above* the kernel now lives in ``repro.retrieval``:

  * batching, futures, caching, stats  -> ``retrieval.service``
    (``search_single`` below is a one-shot call into it — there is
    exactly one search implementation);
  * hierarchical K-selection merge     -> ``retrieval.merge``;
  * mesh placement + broadcast/gather  -> ``retrieval.router``
    (``make_distributed_search`` / ``make_distributed_gather`` remain
    as deprecated wrappers).
"""
from __future__ import annotations

import collections
import dataclasses
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import ivfpq
from repro.core.approx_topk_math import truncated_queue_len
from repro.core.ivfpq import IVFPQConfig, IVFPQParams, IVFPQShard
from repro.kernels.registry import KernelSpec


@dataclasses.dataclass(frozen=True)
class ChamVSConfig:
    """Serve-time configuration of the search engine."""

    ivfpq: IVFPQConfig
    nprobe: int = 32
    k: int = 100
    eps: float = 0.01             # approx-queue failure budget (paper: 1%)
    backend: str = "ref"          # "ref" | "pallas"
    interpret: bool = True        # Pallas interpret mode (CPU container)
    num_l1_blocks: int = 16       # producers per shard for the approx queue
    fused: bool = True            # ONE fused chamvs_scan dispatch over all
    #                               shards per wave; False keeps the staged
    #                               per-shard pipeline (the parity oracle)

    def kernel_spec(self) -> KernelSpec:
        """The registry ``KernelSpec`` this config routes kernels with —
        the single place ``backend``/``interpret`` are interpreted."""
        return KernelSpec(backend=self.backend, interpret=self.interpret)

    def with_kernel(self, backend: Optional[str] = None,
                    interpret: Optional[bool] = None,
                    fused: Optional[bool] = None) -> "ChamVSConfig":
        """Return a copy with the kernel selection overridden (``None``
        keeps the current value) — the one place the EngineConfig /
        ServiceConfig ``kernel_backend`` / ``kernel_interpret`` /
        ``kernel_fused`` knobs are folded in."""
        if backend is None and interpret is None and fused is None:
            return self
        return dataclasses.replace(
            self,
            backend=backend if backend is not None else self.backend,
            interpret=interpret if interpret is not None else self.interpret,
            fused=fused if fused is not None else self.fused)

    def k_prime(self, num_shards: int) -> int:
        """Truncated per-shard queue length (paper §4.2.2): the shards are the
        level-one producers of the global top-K, so each only ships k' << K
        candidates over the network. Note k' > K/num_shards always holds, so
        the merge can always fill K slots."""
        return min(self.k, truncated_queue_len(self.k, max(1, num_shards),
                                               self.eps))


# ---------------------------------------------------------------------------
# per-shard search (runs inside shard_map; also usable standalone).
# This is the STAGED path — the fused single-dispatch twin is
# kernels/chamvs_scan.ops.fused_shard_scan; the two must stay
# result-identical (tests/test_chamvs_scan.py property test).
# ---------------------------------------------------------------------------

def shard_search(params: IVFPQParams, shard: IVFPQShard, queries: jnp.ndarray,
                 probe_ids: jnp.ndarray, cfg: ChamVSConfig, kk: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One memory node's work: LUTs -> stream probed lists -> ADC -> top-kk.

    Returns (dists [nq, kk], global_ids [nq, kk])."""
    icfg = cfg.ivfpq
    nq, nprobe = probe_ids.shape
    luts = ivfpq.compute_luts(params, queries, probe_ids, icfg)  # [nq,np,m,ksub]
    codes = shard.codes[probe_ids]                               # [nq,np,cap,m]
    ids = shard.ids[probe_ids]                                   # [nq,np,cap]
    lens = shard.list_len[probe_ids]                             # [nq,np]

    if cfg.backend == "pallas":
        from repro.kernels.pq_adc.ops import pq_adc_topk
        B = nq * nprobe
        d_l, i_l = pq_adc_topk(
            luts.reshape(B, icfg.m, icfg.ksub),
            codes.reshape(B, icfg.list_cap, icfg.m),
            lens.reshape(B),
            k=min(kk, icfg.list_cap),
            spec=cfg.kernel_spec())
        # local row idx -> global vector id via the per-list id table
        gid = jnp.take_along_axis(
            ids.reshape(B, icfg.list_cap),
            jnp.maximum(i_l, 0), axis=1)
        gid = jnp.where(i_l < 0, -1, gid)
        kcap = d_l.shape[-1]
        d = d_l.reshape(nq, nprobe * kcap)
        g = gid.reshape(nq, nprobe * kcap)
    else:
        valid = (jnp.arange(icfg.list_cap)[None, None, :] < lens[..., None])
        d3 = ivfpq.adc_scan_ref(luts, codes)                     # [nq,np,cap]
        d3 = jnp.where(valid, d3, jnp.inf)
        d = d3.reshape(nq, -1)
        g = ids.reshape(nq, -1)

    neg, pos = jax.lax.top_k(-d, min(kk, d.shape[-1]))
    out_d = -neg
    out_i = jnp.take_along_axis(g, pos, axis=1)
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i)
    if out_d.shape[-1] < kk:  # fewer candidates than kk: pad
        pad = kk - out_d.shape[-1]
        out_d = jnp.pad(out_d, ((0, 0), (0, pad)), constant_values=jnp.inf)
        out_i = jnp.pad(out_i, ((0, 0), (0, pad)), constant_values=-1)
    return out_d, out_i


def stack_shards(shards: list[IVFPQShard]) -> IVFPQShard:
    """[S] shards -> one IVFPQShard with a leading shard axis (to be placed
    with a sharded ``jax.device_put`` along the db axes)."""
    return IVFPQShard(
        codes=jnp.stack([s.codes for s in shards]),
        ids=jnp.stack([s.ids for s in shards]),
        list_len=jnp.stack([s.list_len for s in shards]),
    )


# LRU memo of the last few (params, shards, cfg) -> RetrievalService. A
# fresh service per call would re-pack the whole database with
# ``stack_shards`` every time (the fused path's one-dispatch layout) —
# fine once per deployment, pathological per search. Keyed on the jax
# buffer identities: the cached service holds references to those exact
# buffers, so a live key can never alias a different index. The memo
# deliberately pins up to ``_SERVICE_MEMO_CAP`` indexes (including their
# packed fused stacks) in device memory; long-lived processes juggling
# many indexes should hold their own ``RetrievalService`` instead, or
# ``_SERVICE_MEMO.clear()`` to release them.
_SERVICE_MEMO: "collections.OrderedDict" = collections.OrderedDict()
_SERVICE_MEMO_CAP = 4


def search_single(params: IVFPQParams, shards: list[IVFPQShard],
                  queries: jnp.ndarray, cfg: ChamVSConfig
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-process search over a list of shards (tests, builds).

    Now a one-shot ``RetrievalService`` call, so the legacy path and the
    serving path share one implementation (the service is memoized per
    (index, config) and its jitted stages are module-level, so repeated
    calls neither re-pack the shard stack nor re-trace). ``measure`` and
    ``bucket_pow2`` are off: a bare function call should not block the
    dispatch stream for stage timings, and a one-shot batch gains
    nothing from shape bucketing (it would only scan padded rows)."""
    from repro.retrieval.service import RetrievalService, ServiceConfig
    key = (tuple(id(leaf) for s in shards for leaf in s),
           id(params.coarse_centroids), id(params.codebooks), cfg)
    svc = _SERVICE_MEMO.get(key)
    if svc is None:
        svc = RetrievalService.local(params, shards, cfg,
                                     ServiceConfig(measure=False,
                                                   bucket_pow2=False))
        while len(_SERVICE_MEMO) >= _SERVICE_MEMO_CAP:
            _SERVICE_MEMO.popitem(last=False)    # evict least-recent
        _SERVICE_MEMO[key] = svc
    else:
        _SERVICE_MEMO.move_to_end(key)           # LRU refresh on hit
    return svc.search(queries)


# ---------------------------------------------------------------------------
# deprecated wrappers (moved to repro.retrieval.router)
# ---------------------------------------------------------------------------

def make_distributed_search(
    mesh: Mesh,
    cfg: ChamVSConfig,
    db_axes: Tuple[str, ...] = ("data",),
    query_axis: Optional[str] = "model",
    nq: Optional[int] = None,
):
    """Deprecated: use ``repro.retrieval.router.build_search`` (or a
    ``ShardRouter``, which also owns placement)."""
    warnings.warn(
        "chamvs.make_distributed_search moved to "
        "repro.retrieval.router.build_search", DeprecationWarning,
        stacklevel=2)
    from repro.retrieval.router import build_search
    return build_search(mesh, cfg, db_axes=db_axes, query_axis=query_axis,
                        nq=nq)


def make_distributed_gather(mesh: Mesh, table_axes: Tuple[str, ...]):
    """Deprecated: use ``repro.retrieval.router.build_gather`` (or a
    ``ShardRouter``)."""
    warnings.warn(
        "chamvs.make_distributed_gather moved to "
        "repro.retrieval.router.build_gather", DeprecationWarning,
        stacklevel=2)
    from repro.retrieval.router import build_gather
    return build_gather(mesh, table_axes)
