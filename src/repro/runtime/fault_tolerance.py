"""Fault-tolerant training runtime: checkpoint/restart, straggler detection,
elastic rescale.

At 1000+ node scale the invariants that matter are:
  1. any node can die at any step and the job resumes bit-identically
     (atomic checkpoints + stateless data order — tests/test_fault_tolerance
     proves loss-trajectory equality across an injected crash);
  2. slow nodes are detected from step-time statistics, not gossip
     (StragglerMonitor: EMA + median filter, pluggable mitigation);
  3. the job can resume on a different device count (elastic reshard —
     checkpoints are mesh-agnostic, restore re-places onto the live mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float
    ratio: float


class StragglerMonitor:
    """Flags steps slower than ``threshold`` x rolling median.

    On a real pod the mitigation callback would trigger hot-spare swap-in or
    within-batch work resteal; here it records the event and lets the caller
    decide (the hook is exercised in tests via injected delays)."""

    def __init__(self, threshold: float = 2.0, window: int = 32,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.threshold = threshold
        self.window = window
        self.on_straggler = on_straggler
        self.durations: List[float] = []
        self.events: List[StragglerEvent] = []

    def record(self, step: int, duration: float) -> Optional[StragglerEvent]:
        hist = self.durations[-self.window:]
        self.durations.append(duration)
        if len(hist) < 5:
            return None
        med = float(np.median(hist))
        if duration > self.threshold * med:
            ev = StragglerEvent(step, duration, med, duration / med)
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
            return ev
        return None


class SimulatedFailure(RuntimeError):
    pass


class TrainController:
    """Drives train_step with periodic async checkpoints and crash recovery.

    ``run`` executes steps [resume_step, total). A registered failure step
    raises SimulatedFailure mid-run (after the step executes, before its
    checkpoint), emulating a node loss; calling ``run`` again resumes from
    the newest complete checkpoint with identical data order."""

    def __init__(self, train_step: Callable, data_source, ckpt_dir,
                 ckpt_every: int = 10,
                 monitor: Optional[StragglerMonitor] = None,
                 shardings: Any = None):
        self.train_step = train_step
        self.data = data_source
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.monitor = monitor or StragglerMonitor()
        self.saver = ckpt_lib.AsyncCheckpointer(ckpt_dir)
        self.shardings = shardings
        self.fail_at: Optional[int] = None
        self.metrics_log: List[Dict] = []

    def resume_or_init(self, params, opt_state):
        step = ckpt_lib.latest_step(self.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        (params, opt_state), _ = ckpt_lib.restore(
            self.ckpt_dir, (params, opt_state), step=step,
            shardings=self.shardings)
        return params, opt_state, step

    def run(self, params, opt_state, total_steps: int):
        params, opt_state, start = self.resume_or_init(params, opt_state)
        import jax
        for step in range(start, total_steps):
            t0 = time.time()
            batch = self.data.host_batch(step)
            params, opt_state, metrics = self.train_step(
                params, opt_state,
                jax.tree.map(lambda x: jax.numpy.asarray(x), batch))
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step"] = step
            self.metrics_log.append(metrics)
            self.monitor.record(step, time.time() - t0)
            done = step + 1
            if done % self.ckpt_every == 0 or done == total_steps:
                self.saver.save(done, (params, opt_state))
            if self.fail_at is not None and done == self.fail_at:
                self.fail_at = None
                self.saver.wait()
                raise SimulatedFailure(f"injected node failure at step {done}")
        self.saver.wait()
        return params, opt_state


def elastic_restore(ckpt_dir, like, mesh, spec_tree):
    """Resume a checkpoint onto a (possibly different-size) mesh: leaves are
    re-placed under the new mesh's shardings (N -> M devices)."""
    import jax
    from jax.sharding import NamedSharding
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                             is_leaf=lambda x: isinstance(
                                 x, jax.sharding.PartitionSpec))
    return ckpt_lib.restore(ckpt_dir, like, shardings=shardings)
