"""Adapters: absorb the repo's pre-existing stats objects into one
``MetricsRegistry``.

The serving stack already counts almost everything — ``PoolStats`` on
the KV pool, ``RetrievalStats`` on the service, admission/degrade
counters on the gateway, fallback counters on the kernel registry —
each with its own shape and no shared read path. Rather than
re-instrumenting those hot paths, these adapters register *collectors*:
zero-arg callables the registry runs at scrape time that copy the live
values into named Prometheus families. Cost is paid per scrape, not per
token.

Family naming: everything is prefixed ``ralm_``; counter families end
in ``_total``; per-stage / per-op breakdowns use labels (``stage=``,
``op=``, ``queue=``), matching Prometheus conventions so the text
exposition is directly scrapeable.
"""
from __future__ import annotations

from repro.obs.metrics import MetricsRegistry

__all__ = ["bind_engine_metrics", "bind_gateway_metrics"]

_STAGES = ("queue_wait", "scan", "merge", "gather")


def bind_engine_metrics(registry: MetricsRegistry, engine) -> None:
    """Register collectors for everything an ``RalmEngine`` owns: KV
    pool, retrieval service, kernel-registry fallbacks. Idempotent
    metric creation; call once per (registry, engine) pair."""
    kv_slots = registry.gauge(
        "ralm_kv_slots", "KV-pool slot rows by state")
    kv_allocs = registry.counter(
        "ralm_kv_allocs_total", "KV-pool slot rows handed out")
    kv_releases = registry.counter(
        "ralm_kv_releases_total", "KV-pool slot rows returned")
    kv_high_water = registry.gauge(
        "ralm_kv_high_water", "max KV slot rows in use at once")
    kv_waves = registry.counter(
        "ralm_kv_waves_total", "decode waves dispatched")
    kv_compiles = registry.gauge(
        "ralm_kv_decode_compiles",
        "distinct decode-wave graph keys traced (jit churn)")
    kv_skip = registry.gauge(
        "ralm_kv_attn_skip_fraction",
        "fraction of pool seq blocks cropped by length-aware attention")
    fallbacks = registry.counter(
        "ralm_kernel_fallbacks_total",
        "pallas->ref kernel routing decisions, by op")
    ret_queries = registry.counter(
        "ralm_retrieval_queries_total", "query rows submitted")
    ret_batches = registry.counter(
        "ralm_retrieval_batches_total", "retrieval flushes (batched "
        "scan+merge dispatches)")
    ret_dispatches = registry.counter(
        "ralm_retrieval_scan_dispatches_total",
        "ChamVS scan kernel dispatches")
    ret_cache = registry.counter(
        "ralm_retrieval_cache_total", "query rows by cache result")
    ret_coalesce = registry.gauge(
        "ralm_retrieval_coalescing_factor", "query rows per dispatch")
    ret_qps = registry.gauge(
        "ralm_retrieval_qps", "query rate over the active window")
    ret_stage = registry.gauge(
        "ralm_retrieval_stage_seconds",
        "per-stage latency summary (mean/max/p50/p99), seconds")
    spec_issued = registry.counter(
        "ralm_spec_issued_total",
        "speculative retrievals issued (due steps that decoded ahead "
        "on stale neighbors)")
    spec_verified = registry.counter(
        "ralm_spec_verified_total",
        "speculation points verified, by outcome")
    spec_landed = registry.counter(
        "ralm_spec_landed_total",
        "speculation points whose search results had already "
        "materialized at harvest (latency fully hidden behind decode)")
    spec_discarded = registry.counter(
        "ralm_spec_discarded_total",
        "speculation points dropped unverified (rollback cascade / "
        "cancel / flush)")
    spec_replayed = registry.counter(
        "ralm_spec_replayed_steps_total",
        "decode steps redone during rollback replay")
    spec_accept = registry.gauge(
        "ralm_spec_acceptance_rate",
        "fraction of verified speculation points whose token matched")
    spec_stage = registry.gauge(
        "ralm_spec_stage_seconds",
        "speculation stage latency summary (spec_wait = residual "
        "retrieval block, spec_replay = rollback cost), seconds")
    fault_total = registry.counter(
        "ralm_retrieval_fault_total",
        "fault-tolerant dispatch events by kind (timeout/hedge/retry/"
        "crash/ejection/recovery/partial_flush/partial_row/spec_flushed)")
    fault_dispatch = registry.gauge(
        "ralm_retrieval_fault_dispatch_seconds",
        "fault-tolerant dispatch loop wall time per flush "
        "(scan + failover + hedging), summary stats in seconds")
    fault_replicas = registry.gauge(
        "ralm_retrieval_fault_replicas",
        "retrieval dispatch replicas by health state")
    straggler_waves = registry.counter(
        "ralm_wave_straggler_total",
        "decode waves flagged as stragglers (>threshold x rolling "
        "median wave time)")

    def collect() -> None:
        pool = engine.pool
        if pool is not None:
            ps = pool.stats
            kv_slots.set(pool.num_used, labels={"state": "used"})
            kv_slots.set(pool.num_free, labels={"state": "free"})
            kv_allocs.set_total(ps.allocs)
            kv_releases.set_total(ps.releases)
            kv_high_water.set(ps.high_water)
            kv_waves.set_total(ps.waves)
            kv_compiles.set(ps.decode_compiles)
            kv_skip.set(ps.skip_fraction())
        from repro.kernels import registry as kreg
        for op, n in kreg.fallback_counts().items():
            fallbacks.set_total(n, labels={"op": op})
        service = getattr(engine.retriever, "service", None)
        if service is not None:
            st = service.stats
            ret_queries.set_total(st.num_queries)
            ret_batches.set_total(st.num_batches)
            ret_dispatches.set_total(st.scan_dispatches)
            ret_cache.set_total(st.cache_hits, labels={"result": "hit"})
            ret_cache.set_total(st.cache_misses,
                                labels={"result": "miss"})
            ret_cache.set_total(st.cache_stale,
                                labels={"result": "stale"})
            ret_coalesce.set(st.coalescing_factor())
            ret_qps.set(st.qps())
            for stage in _STAGES:
                stat = getattr(st, stage)
                ret_stage.set(stat.mean_s,
                              labels={"stage": stage, "stat": "mean"})
                ret_stage.set(stat.max_s,
                              labels={"stage": stage, "stat": "max"})
                ret_stage.set(stat.p50_s(),
                              labels={"stage": stage, "stat": "p50"})
                ret_stage.set(stat.p99_s(),
                              labels={"stage": stage, "stat": "p99"})
            spec_issued.set_total(st.spec_issued)
            spec_verified.set_total(st.spec_accepted,
                                    labels={"outcome": "accepted"})
            spec_verified.set_total(st.spec_rollbacks,
                                    labels={"outcome": "rollback"})
            spec_landed.set_total(st.spec_landed)
            spec_discarded.set_total(st.spec_discarded)
            spec_replayed.set_total(st.spec_replayed_steps)
            spec_accept.set(st.spec_acceptance_rate())
            for stage in ("spec_wait", "spec_replay"):
                stat = getattr(st, stage)
                spec_stage.set(stat.mean_s,
                               labels={"stage": stage, "stat": "mean"})
                spec_stage.set(stat.p99_s(),
                               labels={"stage": stage, "stat": "p99"})
            for kind, val in (("timeout", st.ft_timeouts),
                              ("hedge", st.ft_hedges),
                              ("retry", st.ft_retries),
                              ("crash", st.ft_crashes),
                              ("ejection", st.ft_ejections),
                              ("recovery", st.ft_recoveries),
                              ("partial_flush", st.ft_partial_flushes),
                              ("partial_row", st.ft_partial_rows),
                              ("spec_flushed", st.ft_spec_flushed)):
                fault_total.set_total(val, labels={"kind": kind})
            fault_dispatch.set(st.ft_dispatch.mean_s,
                               labels={"stat": "mean"})
            fault_dispatch.set(st.ft_dispatch.p99_s(),
                               labels={"stat": "p99"})
            replicas = getattr(service, "replicas", None)
            if replicas is not None:
                for state, n in replicas.state_counts().items():
                    fault_replicas.set(n, labels={"state": state})
        scheduler = getattr(engine, "scheduler", None)
        if scheduler is not None:
            straggler_waves.set_total(
                getattr(scheduler, "straggler_events", 0))

    registry.register_collector(collect)


def bind_gateway_metrics(registry: MetricsRegistry, gateway) -> None:
    """Register collectors for the gateway's own counters (admission,
    degrade, streams) on top of ``bind_engine_metrics``."""
    bind_engine_metrics(registry, gateway.engine)
    uptime = registry.gauge(
        "ralm_uptime_seconds", "gateway uptime")
    completions = registry.counter(
        "ralm_completions_total", "requests completed")
    cancelled = registry.counter(
        "ralm_cancelled_total", "requests cancelled mid-stream")
    disconnects = registry.counter(
        "ralm_disconnects_total", "client disconnects observed")
    tokens_out = registry.counter(
        "ralm_tokens_out_total", "tokens streamed to clients")
    admission = registry.counter(
        "ralm_admission_total", "admission verdicts by outcome")
    queue_depth = registry.gauge(
        "ralm_queue_depth", "requests waiting, by queue")
    active = registry.gauge(
        "ralm_active_requests", "sequences currently decoding")
    degrade_level = registry.gauge(
        "ralm_degrade_level", "current degrade-ladder rung (0=baseline)")
    degrade_trans = registry.counter(
        "ralm_degrade_transitions_total",
        "degrade-ladder transitions by direction")

    def collect() -> None:
        import time
        uptime.set(time.perf_counter() - gateway._t_start)
        completions.set_total(gateway.completions)
        cancelled.set_total(gateway.cancelled)
        disconnects.set_total(gateway.disconnects)
        tokens_out.set_total(gateway.tokens_out)
        adm = gateway.admission
        admission.set_total(adm.admitted, labels={"outcome": "admitted"})
        admission.set_total(adm.released, labels={"outcome": "released"})
        admission.set_total(adm.rejected_quota,
                            labels={"outcome": "rejected_quota"})
        admission.set_total(adm.rejected_capacity,
                            labels={"outcome": "rejected_capacity"})
        queue_depth.set(adm.pending, labels={"queue": "admission"})
        queue_depth.set(gateway.scheduler.queued_requests,
                        labels={"queue": "scheduler"})
        active.set(gateway.scheduler.num_active)
        if gateway.policy is not None:
            degrade_level.set(gateway.policy.level)
            degrade_trans.set_total(gateway.policy.transitions_down,
                                    labels={"direction": "down"})
            degrade_trans.set_total(gateway.policy.transitions_up,
                                    labels={"direction": "up"})

    registry.register_collector(collect)
