"""Metrics: counters, gauges, fixed-bucket histograms with reservoir
percentiles, rendered as Prometheus text exposition.

One ``MetricsRegistry`` per gateway absorbs the repo's scattered stats
objects (``PoolStats``, ``RetrievalStats``, admission counters, kernel
fallback counts — see ``repro.obs.adapters``) behind two read paths:

  * ``render()`` — Prometheus text format 0.0.4 for ``GET /metricsz``
    (scrapeable by an actual Prometheus, parseable by the regex in
    ``tests/test_obs.py``);
  * ``snapshot()`` — plain nested dict, merged into the ``/statsz``
    JSON so the legacy endpoint stays an aggregated view of the same
    registry rather than a second bookkeeping system.

Percentiles come from a bounded reservoir (Vitter's algorithm R) kept
alongside each histogram's fixed buckets: buckets give Prometheus its
cumulative ``le`` series for server-side quantile math, the reservoir
gives exact-ish p50/p95/p99 gauges without unbounded memory. Collectors
registered with ``register_collector`` run at scrape time, so gauge
families always reflect live engine state with zero hot-path cost.
"""
from __future__ import annotations

import random
import threading
from bisect import bisect_left
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Reservoir", "Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_BUCKETS"]

#: latency buckets in seconds, spanning ~100us .. 30s — wide enough for
#: interpret-mode CI (slow) and compiled serving (fast) alike
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Dict[str, str]]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(key: LabelKey, extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in key]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(x: float) -> str:
    if x == float("inf"):
        return "+Inf"
    if isinstance(x, float) and x.is_integer():
        return str(int(x))
    return repr(float(x)) if isinstance(x, float) else str(x)


class Reservoir:
    """Bounded uniform sample of a stream (algorithm R).

    Keeps at most ``cap`` values; each of the ``n`` observed values has
    equal probability cap/n of being in the sample, so quantiles of the
    reservoir estimate quantiles of the full stream. The RNG is seeded
    per-instance for reproducible tests."""

    __slots__ = ("cap", "n", "_values", "_rng", "_sorted")

    def __init__(self, cap: int = 1024, seed: int = 0):
        self.cap = cap
        self.n = 0
        self._values: List[float] = []
        self._rng = random.Random(seed)
        self._sorted = True

    def add(self, value: float) -> None:
        self.n += 1
        if len(self._values) < self.cap:
            self._values.append(value)
            self._sorted = False
        else:
            j = self._rng.randrange(self.n)
            if j < self.cap:
                self._values[j] = value
                self._sorted = False

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile of the sample; 0.0 when empty."""
        if not self._values:
            return 0.0
        if not self._sorted:
            self._values.sort()
            self._sorted = True
        idx = min(len(self._values) - 1,
                  max(0, int(q * len(self._values))))
        return self._values[idx]

    def __len__(self) -> int:
        return len(self._values)


class Counter:
    """Monotonic counter, optionally labelled. ``inc`` adds;
    ``set_total`` absorbs an externally-maintained running total (the
    adapter pattern — admission counters already count themselves)."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[LabelKey, float] = {}
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0,
            labels: Optional[Dict[str, str]] = None) -> None:
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def set_total(self, total: float,
                  labels: Optional[Dict[str, str]] = None) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(total)

    def value(self, labels: Optional[Dict[str, str]] = None) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def samples(self) -> Iterable[Tuple[str, float]]:
        with self._lock:
            items = list(self._values.items())
        if not items:
            items = [((), 0.0)]
        for key, v in sorted(items):
            yield self.name + _render_labels(key), v

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} counter"]
        lines += [f"{s} {_fmt(v)}" for s, v in self.samples()]
        return lines

    def snapshot(self):
        with self._lock:
            if set(self._values) == {()}:
                return self._values[()]
            return {_render_labels(k) or "": v
                    for k, v in sorted(self._values.items())}


class Gauge(Counter):
    """A value that can go up and down (queue depth, degrade level)."""

    def set(self, value: float,
            labels: Optional[Dict[str, str]] = None) -> None:
        self.set_total(value, labels)

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} gauge"]
        lines += [f"{s} {_fmt(v)}" for s, v in self.samples()]
        return lines


class Histogram:
    """Fixed-bucket histogram + reservoir percentiles.

    Renders the standard Prometheus cumulative ``_bucket{le=...}`` /
    ``_sum`` / ``_count`` series plus companion gauge families
    ``{name}_p50`` / ``_p95`` / ``_p99`` computed from the reservoir —
    bucket-interpolated quantiles are only as fine as the bucket grid,
    and the ±10% TTFT consistency check in ``benchmarks/loadgen.py``
    needs better than that."""

    QUANTILES = ((0.50, "p50"), (0.95, "p95"), (0.99, "p99"))

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS,
                 reservoir_cap: int = 1024):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets))
        self._counts = [0] * (len(self.buckets) + 1)  # +1 for +Inf
        self._sum = 0.0
        self._n = 0
        self.reservoir = Reservoir(cap=reservoir_cap)
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        # bisect_left: a value equal to a bucket edge belongs IN that
        # bucket (Prometheus `le` is an inclusive upper bound)
        idx = bisect_left(self.buckets, value)
        with self._lock:
            self._counts[idx] += 1
            self._sum += value
            self._n += 1
            self.reservoir.add(value)

    @property
    def count(self) -> int:
        return self._n

    @property
    def sum(self) -> float:
        return self._sum

    def quantile(self, q: float) -> float:
        with self._lock:
            return self.reservoir.quantile(q)

    def render(self) -> List[str]:
        with self._lock:
            counts = list(self._counts)
            total, tsum = self._n, self._sum
            quants = [(label, self.reservoir.quantile(q))
                      for q, label in self.QUANTILES]
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        cum = 0
        for edge, c in zip(self.buckets, counts):
            cum += c
            lines.append(f'{self.name}_bucket{{le="{_fmt(edge)}"}} {cum}')
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {total}')
        lines.append(f"{self.name}_sum {_fmt(tsum)}")
        lines.append(f"{self.name}_count {total}")
        for label, v in quants:
            qname = f"{self.name}_{label}"
            lines.append(f"# HELP {qname} {label} of {self.name} "
                         f"(reservoir estimate)")
            lines.append(f"# TYPE {qname} gauge")
            lines.append(f"{qname} {_fmt(v)}")
        return lines

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "count": self._n,
                "sum": self._sum,
                "p50": self.reservoir.quantile(0.50),
                "p95": self.reservoir.quantile(0.95),
                "p99": self.reservoir.quantile(0.99),
            }


class MetricsRegistry:
    """Named metric families + pull-at-scrape collectors.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent by
    name, so adapters can bind repeatedly); ``register_collector`` adds
    a zero-arg callable run at the top of every ``render()``/
    ``snapshot()`` — the bridge that copies live engine state
    (pool stats, retrieval stats, fallback counts) into gauge families
    without instrumenting those hot paths."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._order: List[str] = []
        self._collectors: List[Callable[[], None]] = []
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, factory, kind):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
                self._order.append(name)
            elif not isinstance(m, kind):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(
            name, lambda: Counter(name, help), Counter)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(name, lambda: Gauge(name, help), Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(
            name, lambda: Histogram(name, help, buckets), Histogram)

    def register_collector(self, fn: Callable[[], None]) -> None:
        self._collectors.append(fn)

    def collect(self) -> None:
        for fn in self._collectors:
            fn()

    def render(self) -> str:
        """Prometheus text exposition 0.0.4 (``GET /metricsz`` body)."""
        self.collect()
        lines: List[str] = []
        for name in list(self._order):
            lines += self._metrics[name].render()
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """Plain-dict view of every family (merged into ``/statsz``)."""
        self.collect()
        return {name: self._metrics[name].snapshot()
                for name in list(self._order)}
