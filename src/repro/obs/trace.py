"""Per-request tracing: ring-buffered spans exported as Chrome trace
events (Perfetto-loadable).

Design constraints, in priority order:

  1. **Zero cost when disabled.** The serving hot path (one scheduler
     wave per generated token) cannot afford allocations for telemetry
     nobody asked for. A disabled tracer's ``span()`` returns one
     process-wide ``_NullSpan`` singleton — no span object, no event
     dict, no timestamp read — and the instrumentation sites build
     their ``args`` dicts only behind an ``if tracer.enabled`` guard.
     ``tests/test_obs.py::test_overhead_guard_disabled_tracer`` pins
     this with tracemalloc.
  2. **Thread-safe, bounded, never blocking.** Events land in a
     ``collections.deque(maxlen=capacity)`` — appends are atomic under
     the GIL, old events fall off the back instead of growing without
     bound, and nothing on the recording path takes a lock (only track
     registration does, once per track name).
  3. **A standard viewer, not a bespoke one.** Export is the Chrome
     trace-event JSON format (``{"traceEvents": [...]}``): open the
     file at https://ui.perfetto.dev or chrome://tracing. Wave-level
     spans share one named track, retrieval stages another, and
     per-request *flow events* (``ph: "s"`` / ``"f"``) draw the TTFT
     arrow from a request's queue-wait slice to the wave that emitted
     its first token — across tracks.

Event vocabulary used here (all timestamps in microseconds since the
tracer's origin):

  ===  =========================================================
  ph   meaning
  ===  =========================================================
  X    complete span (``ts`` + ``dur``) — what ``span()`` records
  i    instant event (alloc/release, degrade transition, recompile)
  s/f  flow start / finish, paired by ``id`` (the request trace id)
  M    metadata (track names — one ``thread_name`` per track)
  ===  =========================================================
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Union

__all__ = ["Tracer", "NULL_TRACER", "validate_chrome_trace"]


class _NullSpan:
    """The do-nothing context manager a disabled tracer hands out.
    One module-level instance; identity is asserted by the overhead
    guard test."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """A live span: times its ``with`` body, records one ``X`` event."""
    __slots__ = ("_tracer", "name", "tid", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, tid: int,
                 args: Optional[dict]):
        self._tracer = tracer
        self.name = name
        self.tid = tid
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        t1 = tr._clock()
        ev = {"name": self.name, "ph": "X", "pid": tr.pid,
              "tid": self.tid, "ts": (self._t0 - tr._origin) * 1e6,
              "dur": (t1 - self._t0) * 1e6}
        if self.args:
            ev["args"] = self.args
        tr._events.append(ev)
        return False


class Tracer:
    """Ring-buffered trace recorder with named tracks.

    ``enabled`` is the master switch: every recording method returns
    immediately (span: the null singleton) when it is False, so a
    deployment can keep the instrumentation compiled in and pay only an
    attribute check per wave. Tracks are logical lanes in the viewer
    ("wave", "retrieval", "requests", ...) mapped to stable ``tid``
    integers, each announced once with a ``thread_name`` metadata
    event."""

    def __init__(self, enabled: bool = True, capacity: int = 1 << 16,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = enabled
        self.capacity = capacity
        self._clock = clock
        self._origin = clock()
        self.pid = os.getpid()
        self._events: deque = deque(maxlen=capacity)
        self._tracks: Dict[str, int] = {}
        self._lock = threading.Lock()

    # -- track bookkeeping --------------------------------------------------

    def _tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            with self._lock:
                tid = self._tracks.get(track)
                if tid is None:
                    tid = len(self._tracks) + 1
                    self._tracks[track] = tid
                    self._events.append(
                        {"name": "thread_name", "ph": "M", "pid": self.pid,
                         "tid": tid, "ts": 0,
                         "args": {"name": track}})
        return tid

    def _ts(self, t_s: Optional[float] = None) -> float:
        """Clock seconds -> trace microseconds (now when ``t_s`` None)."""
        t = self._clock() if t_s is None else t_s
        return (t - self._origin) * 1e6

    # -- recording ----------------------------------------------------------

    def span(self, name: str, track: str = "engine",
             args: Optional[dict] = None) -> Union[_Span, _NullSpan]:
        """``with tracer.span("retrieval.scan", "retrieval"): ...`` —
        records one complete event around the body. Returns the null
        singleton when disabled; pass ``args`` only behind an
        ``if tracer.enabled`` guard on hot paths (the dict literal is
        the allocation, not this call)."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, self._tid(track), args)

    def instant(self, name: str, track: str = "engine",
                args: Optional[dict] = None) -> None:
        """Point event (thread-scoped): alloc/release, transitions."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "s": "t", "pid": self.pid,
              "tid": self._tid(track), "ts": self._ts()}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def complete(self, name: str, track: str, t0_s: float, dur_s: float,
                 args: Optional[dict] = None) -> None:
        """Retroactive span from explicit clock timestamps — for
        intervals whose start predates the recording site (queue wait:
        the flush knows when the oldest row was submitted)."""
        if not self.enabled:
            return
        ev = {"name": name, "ph": "X", "pid": self.pid,
              "tid": self._tid(track), "ts": self._ts(t0_s),
              "dur": max(0.0, dur_s) * 1e6}
        if args:
            ev["args"] = args
        self._events.append(ev)

    def flow_start(self, flow_id: int, name: str = "request",
                   track: str = "requests",
                   t_s: Optional[float] = None) -> None:
        """Open a flow arrow (pairs with ``flow_end`` on any track)."""
        if not self.enabled:
            return
        self._events.append(
            {"name": name, "cat": "flow", "ph": "s", "id": int(flow_id),
             "pid": self.pid, "tid": self._tid(track),
             "ts": self._ts(t_s)})

    def flow_end(self, flow_id: int, name: str = "request",
                 track: str = "requests",
                 t_s: Optional[float] = None) -> None:
        if not self.enabled:
            return
        self._events.append(
            {"name": name, "cat": "flow", "ph": "f", "bp": "e",
             "id": int(flow_id), "pid": self.pid,
             "tid": self._tid(track), "ts": self._ts(t_s)})

    # -- export -------------------------------------------------------------

    def events(self) -> List[dict]:
        """Snapshot of the ring buffer (oldest first)."""
        return list(self._events)

    def clear(self) -> None:
        """Drop buffered events (the per-load-level capture boundary in
        ``benchmarks/loadgen.py``). Track metadata is re-emitted so an
        export after ``clear()`` remains self-contained."""
        with self._lock:
            fresh: deque = deque(maxlen=self.capacity)
            for track, tid in self._tracks.items():
                fresh.append(
                    {"name": "thread_name", "ph": "M", "pid": self.pid,
                     "tid": tid, "ts": 0, "args": {"name": track}})
            self._events = fresh

    def export(self) -> dict:
        """The Chrome trace-event document (open in Perfetto)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)


#: the shared disabled tracer every component defaults to — one
#: attribute check (`tracer.enabled`) is the entire disabled-path cost
NULL_TRACER = Tracer(enabled=False, capacity=1)


# ---------------------------------------------------------------------------
# schema validation (tests + the loadgen/CI trace check)
# ---------------------------------------------------------------------------

_REQUIRED = ("ph", "ts", "pid", "tid")
_KNOWN_PH = {"X", "B", "E", "i", "I", "s", "t", "f", "M", "C"}


def validate_chrome_trace(doc: Union[dict, list]) -> List[str]:
    """Check a trace document against the Chrome trace-event contract
    this repo relies on. Returns a list of problems (empty == valid):

      * the document is ``{"traceEvents": [...]}`` (or a bare list);
      * every event carries ``ph``/``ts``/``pid``/``tid`` and a string
        ``name``, with a known phase;
      * ``X`` events have a non-negative numeric ``dur``;
      * flow events pair up — every ``ph:"s"`` id has a matching
        ``ph:"f"`` and vice versa (an unpaired flow renders as an arrow
        into nowhere)."""
    problems: List[str] = []
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return ["document has no 'traceEvents' list"]
    elif isinstance(doc, list):
        events = doc
    else:
        return [f"document must be dict or list, got {type(doc).__name__}"]

    flow_s: Dict[int, int] = {}
    flow_f: Dict[int, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        for key in _REQUIRED:
            if key not in ev:
                problems.append(f"event {i} ({ev.get('name')!r}): "
                                f"missing {key!r}")
        if not isinstance(ev.get("name"), str):
            problems.append(f"event {i}: missing/non-string name")
        ph = ev.get("ph")
        if ph is not None and ph not in _KNOWN_PH:
            problems.append(f"event {i}: unknown phase {ph!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} ({ev.get('name')!r}): X event "
                                f"needs dur >= 0, got {dur!r}")
        if ph in ("s", "f"):
            if "id" not in ev:
                problems.append(f"event {i}: flow event missing id")
            else:
                side = flow_s if ph == "s" else flow_f
                side[ev["id"]] = side.get(ev["id"], 0) + 1
    for fid, n in flow_s.items():
        if flow_f.get(fid, 0) != n:
            problems.append(
                f"flow id {fid}: {n} start(s) vs "
                f"{flow_f.get(fid, 0)} finish(es)")
    for fid, n in flow_f.items():
        if fid not in flow_s:
            problems.append(f"flow id {fid}: {n} finish(es) without start")
    return problems
