"""``repro.obs`` — the unified observability plane (tracing + metrics).

Chameleon's headline results are latency *decompositions* (paper
Fig. 9/10: queue wait vs scan vs merge vs gather; TTFT/TPOT under
disaggregation), and every scheduling/partitioning decision downstream
of this repo (RAGO's LM:retrieval split, the ROADMAP's SLO controller)
keys on exactly that per-stage telemetry. This package is the
measurement substrate, stdlib-only:

  * ``trace`` — a ``Tracer`` with a zero-cost-when-disabled span API,
    thread-safe ring-buffered events, per-request trace IDs, and
    Chrome trace-event JSON export loadable in Perfetto
    (https://ui.perfetto.dev);
  * ``metrics`` — a ``MetricsRegistry`` (counters, gauges, fixed-bucket
    histograms with reservoir p50/p95/p99) rendered in Prometheus text
    exposition format (the gateway's ``GET /metricsz``);
  * ``adapters`` — thin collectors that absorb the pre-existing
    scattered stats (``PoolStats``, ``RetrievalStats``, scheduler queue
    depths, kernel-registry fallback counters) into one registry.

See ``docs/observability.md`` for the span taxonomy and the
``/statsz`` -> ``/metricsz`` migration table.
"""
from repro.obs.adapters import bind_engine_metrics, bind_gateway_metrics
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry, Reservoir)
from repro.obs.trace import (NULL_TRACER, Tracer, validate_chrome_trace)

__all__ = [
    "Counter", "DEFAULT_BUCKETS", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_TRACER", "Reservoir", "Tracer", "validate_chrome_trace",
    "bind_engine_metrics", "bind_gateway_metrics",
]
