"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 (data, model) = 256 chips (TPU v5e pod slice).
    Multi-pod: 2x16x16 (pod, data, model) = 512 chips; the leading "pod"
    axis is the slow inter-pod hop (DCN), which is why gradient compression
    and the ChamVS k'-truncated result aggregation target it."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(devices=None, data: int = 1, model: int = 1,
                  pod: int = 1):
    """Arbitrary mesh over an explicit device list (tests, disaggregated
    pools)."""
    import numpy as np
    devices = list(jax.devices()) if devices is None else list(devices)
    n = pod * data * model
    assert len(devices) >= n, (len(devices), n)
    arr = np.array(devices[:n])
    if pod > 1:
        return jax.sharding.Mesh(arr.reshape(pod, data, model),
                                 ("pod", "data", "model"))
    return jax.sharding.Mesh(arr.reshape(data, model), ("data", "model"))
