"""Trip-count-aware HLO cost analyzer for the roofline (§Roofline).

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified on this
jax/XLA build), so a scan-over-layers program would under-report FLOPs by
n_layers. This analyzer parses the post-SPMD compiled HLO text and computes:

  * flops            — dot ops (2 * prod(out) * prod(contracting)), recursing
                       into fusions/calls, multiplying while bodies by their
                       trip count (parsed from the loop-condition constant);
  * bytes            — per-op HBM traffic at fusion boundaries: sum of
                       operand+output buffer sizes of every materializing op
                       (fusions counted as single ops — post-fusion buffers
                       are exactly what hits HBM);
  * collective_bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       trip-count aware, per collective kind.

All numbers are PER DEVICE (the compiled module is the per-device SPMD
program). Hardware constants for TPU v5e close the roofline terms.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e (target hardware; this container is compile-only CPU)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 45e9                # bytes/s per link (~50 GB/s nominal)

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_FREE_OPS = {"get-tuple-element", "tuple", "parameter", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota"}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _first_shape(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    # per-op info filled on parse
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        c = dict(self.coll)
        for k, v in o.coll.items():
            c[k] = c.get(k, 0.0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes, c)

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.bytes * t,
                    {k: v * t for k, v in self.coll.items()})

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        s = line.rstrip()
        st = s.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$", st)
        if header and not st.startswith("%param"):
            cur = Computation(name=header.group(2), lines=[])
            comps[cur.name] = cur
            if header.group(1):
                comps["__entry__"] = cur
            continue
        if st == "}":
            cur = None
            continue
        if cur is not None:
            cur.lines.append(st)
    return comps


def _parse_shapes(comp: Computation) -> None:
    for ln in comp.lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rest = m.groups()
        # the defining type is the text before the op name
        comp.shapes[name] = rest


def _operand_bytes(comp: Computation, ln: str, op_pos: int,
                   cap: Optional[int] = None) -> int:
    """Sum the buffer sizes of the operands referenced in op(...).

    ``cap``: optional per-operand byte cap (see fusion handling)."""
    seg = ln[op_pos:]
    par = seg.find("(")
    if par < 0:
        return 0
    # take text up to the matching close paren (heuristic: first ')' at depth 0)
    depth, end = 0, len(seg)
    for i, ch in enumerate(seg[par:], par):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = seg[par + 1:end]
    total = 0
    for ref in re.findall(r"%([\w.\-]+)", inner):
        t = comp.shapes.get(ref)
        if t:
            b = _shape_bytes(t.split(" ")[0] if t else "")
            total += min(b, cap) if cap else b
            continue
        # operand may carry an inline type like f32[8,16] %x
    for dt, dims in _SHAPE_RE.findall(inner):
        if dt in DTYPE_BYTES:
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            b = n * DTYPE_BYTES[dt]
            total += min(b, cap) if cap else b
    return total


def _dot_flops(comp: Computation, ln: str) -> float:
    m = _DEF_RE.match(ln)
    if not m:
        return 0.0
    out_t = _first_shape(m.group(2))
    if out_t is None:
        return 0.0
    _, out_dims = out_t
    out_n = 1
    for d in out_dims:
        out_n *= d
    # contracting size from lhs shape + lhs_contracting_dims
    lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ln)
    opm = re.search(r"\bdot\(", ln)
    if not lc or not opm:
        return 2.0 * out_n  # degenerate
    inner = ln[opm.end():]
    refs = re.findall(r"%([\w.\-]+)", inner)
    lhs_dims: List[int] = []
    if refs:
        t = comp.shapes.get(refs[0], "")
        sh = _first_shape(t)
        if sh:
            lhs_dims = sh[1]
    if not lhs_dims:
        inline = _SHAPE_RE.search(inner)
        if inline:
            lhs_dims = ([int(d) for d in inline.group(2).split(",")]
                        if inline.group(2) else [])
    k = 1
    if lc.group(1):
        for d in lc.group(1).split(","):
            if int(d) < len(lhs_dims):
                k *= lhs_dims[int(d)]
    return 2.0 * out_n * k


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for ln in comp.lines:
        for c in re.findall(r"constant\((\d+)\)", ln):
            best = max(best, int(c))
    return best


_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")


def _comp_cost(comps: Dict[str, Computation], name: str,
               memo: Dict[str, Cost]) -> Cost:
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    if comp is None:
        return Cost()
    memo[name] = Cost()  # cycle guard
    total = Cost()
    for ln in comp.lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        rest = m.group(2)
        opm = re.search(r"\b([a-z][\w\-]*)\(", rest)
        if not opm:
            continue
        op = opm.group(1)
        if op in _FREE_OPS:
            continue
        if op == "while":
            body = _CALL_RE.search(ln)
            cond = _COND_RE.search(ln)
            trip = _trip_count(comps, cond.group(1)) if cond else 1
            if body:
                total = total + _comp_cost(comps, body.group(1), memo
                                           ).scaled(trip)
            continue
        if op in ("gather", "dynamic-slice"):
            # in-place-aware: only the gathered/sliced region moves
            out_b = _shape_bytes(rest[:opm.start()])
            total = total + Cost(bytes=float(2 * out_b))
            continue
        if op in ("scatter", "dynamic-update-slice"):
            # write-through of the updated region only (operand aliased)
            ops_in = re.findall(r"%([\w.\-]+)", ln[opm.end():])
            upd_b = 0
            if len(ops_in) >= 2:
                # update operand: scatter -> 3rd, dus -> 2nd
                idx = 2 if op == "scatter" and len(ops_in) >= 3 else 1
                t = comp.shapes.get(ops_in[idx], "")
                upd_b = _shape_bytes(t.split(" ")[0] if t else "")
            if upd_b == 0:
                upd_b = _shape_bytes(rest[:opm.start()])  # fallback: output
            total = total + Cost(bytes=float(2 * upd_b))
            continue
        if op in ("fusion", "call", "custom-call", "reduce", "sort", "map",
                  "reduce-window", "select-and-scatter"):
            # bytes at the fusion boundary. Fusions that internally
            # dynamic-slice a large operand (e.g. per-layer reads of stacked
            # remat saves) only touch the slice — cap each operand at
            # 4x the fusion output (validated against known-traffic
            # programs; uncapped counting overstated llama3 bwd 100x).
            out_b = _shape_bytes(rest[:opm.start()])
            in_b = _operand_bytes(comp, ln, opm.start(),
                                  cap=max(4 * out_b, 1 << 26))
            total = total + Cost(bytes=float(out_b + in_b))
            callee = _CALL_RE.search(ln)
            if callee and op in ("fusion", "call", "map"):
                sub = _comp_cost(comps, callee.group(1), memo)
                total = total + Cost(flops=sub.flops, coll=sub.coll)
            continue
        if op == "conditional":
            branches = re.findall(r"%([\w.\-]+)", ln[opm.end():])
            sub = [(b, _comp_cost(comps, b, memo)) for b in branches
                   if b in comps]
            if sub:
                best = max(sub, key=lambda x: x[1].flops + x[1].bytes)
                total = total + best[1]
            continue
        coll = next((c for c in COLLECTIVES if op.startswith(c)), None)
        if coll is not None:
            if op.endswith("-done"):
                continue
            b = _operand_bytes(comp, ln, opm.start())
            total = total + Cost(bytes=float(b + _shape_bytes(
                rest[:opm.start()])),
                coll={coll: float(b)})
            continue
        if op == "dot":
            out_b = _shape_bytes(rest[:opm.start()])
            in_b = _operand_bytes(comp, ln, opm.start())
            total = total + Cost(flops=_dot_flops(comp, ln),
                                 bytes=float(out_b + in_b))
            continue
        if op in ("convolution",):
            out_b = _shape_bytes(rest[:opm.start()])
            in_b = _operand_bytes(comp, ln, opm.start())
            total = total + Cost(flops=2.0 * out_b, bytes=float(out_b + in_b))
            continue
        # other materializing ops: count buffer traffic only
        out_b = _shape_bytes(rest[:opm.start()])
        in_b = _operand_bytes(comp, ln, opm.start())
        total = total + Cost(bytes=float(out_b + in_b))
    memo[name] = total
    return total


def analyze_hlo(hlo_text: str) -> Cost:
    """Per-device flops/bytes/collective-bytes of a compiled SPMD module."""
    comps = _split_computations(hlo_text)
    for c in comps.values():
        _parse_shapes(c)
    entry = comps.get("__entry__")
    if entry is None:
        return Cost()
    return _comp_cost(comps, entry.name, {})


def roofline_terms(cost: Cost) -> Dict[str, float]:
    """Seconds per step for the three roofline terms (per chip)."""
    return dict(
        compute_s=cost.flops / PEAK_FLOPS,
        memory_s=cost.bytes / HBM_BW,
        collective_s=cost.collective_bytes / ICI_BW,
    )


def dominant_term(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])
