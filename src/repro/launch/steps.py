"""Step builders: the jit-able programs the launcher / dry-run compile.

  * ``build_train_step``   — fwd+bwd+AdamW, FSDP/TP(/EP) sharded
  * ``build_prefill_step`` — full-sequence forward building the KV cache
  * ``build_serve_step``   — one retrieval-augmented decode step: LM decode,
    hidden-state query, ChamVS distributed search, payload gather, kNN-LM
    interpolation (decoder-only) or retrieved-chunk re-encoding (encdec) —
    paper Fig. 3 steps 1-10 in one program (monolithic mode).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ArchSpec
from repro.core import rag as rag_lib
from repro.models import transformer as tf
from repro.models.ctx import activation_sharding
from repro.models.sharding import cache_specs, dp_axes, param_specs, sanitize
from repro.optim import adamw
from repro.launch import specs as specs_lib


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def abstract_params(cfg):
    return jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def default_microbatches(spec: ArchSpec, shape_name: str, mesh: Mesh) -> int:
    """Gradient-accumulation factor so per-microbatch saved activations fit
    HBM: scan-over-layers remat saves n_layers x [B_loc, T, d] bf16 per
    device (270 GB/device for llama3-405b at B_loc=16 — unrunnable without
    accumulation)."""
    import math
    cfg = spec.model
    sh = SHAPES[shape_name]
    dp_size = math.prod(mesh.shape[a] for a in dp_axes(mesh)) or 1
    b_loc = max(sh["global_batch"] // dp_size, 1)
    save_bytes = cfg.n_layers * b_loc * sh["seq_len"] * cfg.d_model * 2
    budget = 6e9          # leave headroom beside params/optimizer/grads
    micro = 1
    while save_bytes / micro > budget and micro < b_loc:
        micro *= 2
    return micro


def build_train_step(spec: ArchSpec, shape_name: str, mesh: Mesh,
                     opt_cfg: Optional[adamw.AdamWConfig] = None,
                     remat: bool = True, microbatches: Optional[int] = None):
    """Returns (train_step, in_shardings, out_shardings).

    ``microbatches`` > 1 runs gradient accumulation: fwd+bwd over batch
    slices inside a lax.scan, one optimizer step — bounds remat-saved
    activations (§Perf iteration 10)."""
    cfg = spec.model
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    if microbatches is None:
        microbatches = default_microbatches(spec, shape_name, mesh)

    dp = dp_axes(mesh)

    def loss_fn(p, b):
        return tf.lm_loss(p, cfg, b, remat=remat)

    def train_step(params, opt_state, batch):
        with activation_sharding(dp, "model"):
            if microbatches <= 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                mb = {k: v.reshape((microbatches,
                                    v.shape[0] // microbatches) + v.shape[1:])
                      if k != "positions" or v.ndim != 3
                      else v.reshape(v.shape[0], microbatches,
                                     v.shape[1] // microbatches, v.shape[2]
                                     ).transpose(1, 0, 2, 3)
                      for k, v in batch.items()}

                def acc_step(carry, bslice):
                    l_acc, g_acc = carry
                    l, g = jax.value_and_grad(loss_fn)(params, bslice)
                    g_acc = jax.tree.map(
                        lambda a, b_: a + b_.astype(a.dtype), g_acc, g)
                    return (l_acc + l, g_acc), None

                g0 = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    acc_step, (jnp.zeros((), jnp.float32), g0), mb)
                loss = loss / microbatches
                grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state, metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    p_struct = abstract_params(cfg)
    p_specs = sanitize(param_specs(cfg, mesh), p_struct, mesh)
    opt_specs = adamw.OptState(step=P(), m=p_specs, v=p_specs)
    b_specs = sanitize(
        specs_lib.train_batch_specs(spec, shape_name, mesh),
        specs_lib.train_batch_struct(spec, shape_name), mesh)
    in_sh = (named(mesh, p_specs), named(mesh, opt_specs),
             named(mesh, b_specs))
    out_sh = (named(mesh, p_specs), named(mesh, opt_specs), None)
    jitted = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
    return jitted, in_sh, out_sh


# ---------------------------------------------------------------------------
# serving: prefill
# ---------------------------------------------------------------------------

def build_prefill_step(spec: ArchSpec, shape_name: str, mesh: Mesh):
    cfg = spec.model
    sh = SHAPES[shape_name]
    dp = dp_axes(mesh)

    kv_batch = "dp" if sh["global_batch"] >= 8 else None
    kv_seq = "model" if sh["global_batch"] >= 8 else ("dp", "model")

    def prefill_step(params, caches, batch):
        with activation_sharding(dp, "model", kv_batch=kv_batch,
                                 kv_seq=kv_seq):
            enc_states = None
            if "enc_embeds" in batch:
                enc_states = tf.encode(params, cfg, batch["enc_embeds"])
            logits, caches = tf.forward(
                params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), positions=batch.get("positions"),
                mode="prefill", caches=caches, enc_states=enc_states)
        return logits[:, -1], caches

    p_specs = sanitize(param_specs(cfg, mesh), abstract_params(cfg), mesh)
    c_struct = specs_lib.cache_struct(spec, shape_name)
    c_specs = sanitize(
        cache_specs(cfg, mesh, c_struct, shard_seq=(sh["global_batch"] < 8)),
        c_struct, mesh)
    b_struct = specs_lib.prefill_struct(spec, shape_name)
    b_specs = {k: P(dp, *([None] * (len(v.shape) - 1)))
               if k != "positions" or v.shape[0] != 3
               else P(None, dp, None)
               for k, v in b_struct.items()}
    b_specs = sanitize(b_specs, b_struct, mesh)
    in_sh = (named(mesh, p_specs), named(mesh, c_specs), named(mesh, b_specs))
    logits_spec = sanitize(
        P(dp, "model"),
        jax.ShapeDtypeStruct((sh["global_batch"], cfg.vocab_size),
                             jnp.float32), mesh)
    out_sh = (NamedSharding(mesh, logits_spec), named(mesh, c_specs))
    jitted = jax.jit(prefill_step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(1,))
    return jitted, (p_specs, c_specs, b_specs)


# ---------------------------------------------------------------------------
# serving: retrieval-augmented decode
# ---------------------------------------------------------------------------

def build_serve_step(spec: ArchSpec, shape_name: str, mesh: Mesh,
                     db: Optional[specs_lib.ServeDBSpec] = None,
                     with_retrieval: bool = True):
    """The paper's token-generation step (Fig. 3). Returns
    (serve_step, shardings, (db_cfg, structs)).

    serve_step(params, caches, batch, db_params, db_shard, payload[, proj])
      -> (logprobs_or_logits [B, V], caches)
    """
    cfg = spec.model
    rag = spec.rag
    sh = SHAPES[shape_name]
    B = sh["global_batch"]
    dp = dp_axes(mesh)
    db = db or specs_lib.ServeDBSpec()
    n_shards = specs_lib.num_db_shards(mesh)
    ccfg = db.for_model(cfg, n_shards, rag.k)
    dq = ccfg.ivfpq.dim
    needs_proj = cfg.d_model != dq

    from repro.retrieval import router as router_lib
    search = router_lib.build_search(
        mesh, ccfg, db_axes=dp, query_axis="model", nq=B) \
        if with_retrieval else None
    pgather = router_lib.build_gather(mesh, dp + ("model",)) \
        if with_retrieval else None

    kv_batch = "dp" if B >= 8 else None
    kv_seq = "model" if B >= 8 else ("dp", "model")

    def serve_step(params, caches, batch, db_params=None, db_shard=None,
                   payload=None, proj=None):
        token, position = batch["token"], batch["position"]
        enc_states = batch.get("enc_states")
        with activation_sharding(dp, "model", kv_batch=kv_batch,
                                 kv_seq=kv_seq):
            logits, caches, hidden = tf.decode_step(
                params, cfg, caches, token, position, enc_states=enc_states,
                return_hidden=True)
        if not with_retrieval:
            return logits, caches
        # --- paper Fig. 3, steps 1-9 ---
        query = hidden.astype(jnp.float32)
        if needs_proj:
            query = query @ proj                        # OPQ-style down-proj
        dists, ids = search(db_params, db_shard, query)  # [B, K] each
        if rag.mode == "retro" and cfg.arch == "encdec":
            # chunk payload -> embed -> shallow encoder -> new cross-states.
            # This is the *retrieval-boundary* step (the latency spikes in
            # paper Fig. 11); steady-state steps reuse enc_states.
            chunks = pgather(payload, ids)                       # [B,K,cl]
            chunks = jnp.where((ids >= 0)[..., None], chunks, 0)
            emb = tf.embed_tokens(params, chunks.reshape(B, -1))
            new_enc = tf.encode(params, cfg, emb)
            logits2, caches, _ = tf.decode_step(
                params, cfg, caches, token, position, enc_states=new_enc,
                return_hidden=True)
            return logits2, caches
        # kNN-LM: payload maps vector id -> next token of that context
        knn_tok = pgather(payload, ids)
        knn_tok = jnp.where(ids >= 0, knn_tok, -1)
        logp = rag_lib.knnlm_interpolate(logits, dists, knn_tok,
                                         rag.lam, rag.temperature)
        return logp, caches

    # shardings
    p_specs = sanitize(param_specs(cfg, mesh), abstract_params(cfg), mesh)
    c_struct = specs_lib.cache_struct(spec, shape_name)
    c_specs = sanitize(cache_specs(cfg, mesh, c_struct, shard_seq=(B < 8)),
                       c_struct, mesh)
    b_specs: Dict[str, Any] = {"token": P(dp, None), "position": P(dp)}
    if cfg.arch == "encdec":
        b_specs["enc_states"] = P(dp, None, None)
    if B < 8:  # long_500k: batch too small to shard
        b_specs = {"token": P(), "position": P()}
        if cfg.arch == "encdec":
            b_specs["enc_states"] = P(None, None, "model")
    shardings: Dict[str, Any] = dict(params=p_specs, caches=c_specs,
                                     batch=b_specs)
    structs: Dict[str, Any] = dict(cache=c_struct,
                                   batch=specs_lib.decode_struct(spec, shape_name))
    if with_retrieval:
        dbp_struct, dbs_struct = specs_lib.db_struct(ccfg, n_shards)
        dbp_specs, dbs_specs = specs_lib.db_specs(mesh)
        if rag.mode == "retro" and cfg.arch == "encdec":
            payload_struct = jax.ShapeDtypeStruct(
                (db.n_vectors, rag.chunk_len), jnp.int32)
            payload_spec = P(dp + ("model",), None)
        else:
            payload_struct = jax.ShapeDtypeStruct((db.n_vectors,), jnp.int32)
            payload_spec = P(dp + ("model",))
        shardings.update(db_params=dbp_specs, db_shard=dbs_specs,
                         payload=payload_spec)
        structs.update(db_params=dbp_struct, db_shard=dbs_struct,
                       payload=payload_struct)
        if needs_proj:
            shardings["proj"] = P(None, "model")
            structs["proj"] = jax.ShapeDtypeStruct((cfg.d_model, dq),
                                                   jnp.float32)
    in_sh = tuple(named(mesh, shardings[k]) for k in
                  ("params", "caches", "batch"))
    extra = tuple(named(mesh, shardings[k])
                  for k in ("db_params", "db_shard", "payload", "proj")
                  if k in shardings)
    logits_spec = sanitize(
        P(dp if B >= 8 else None, "model"),
        jax.ShapeDtypeStruct((B, cfg.vocab_size), jnp.float32), mesh)
    out_sh = (NamedSharding(mesh, logits_spec), named(mesh, c_specs))
    jitted = jax.jit(serve_step, in_shardings=in_sh + extra,
                     out_shardings=out_sh, donate_argnums=(1,))
    return jitted, shardings, (ccfg, structs)
