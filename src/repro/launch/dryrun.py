import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below this line may import jax ---------------------------
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.compat import use_mesh
from repro.configs import SHAPES, get_arch, list_archs
from repro.launch import specs as specs_lib
from repro.launch import steps as steps_lib
from repro.launch.analysis import (analyze_hlo, dominant_term, roofline_terms,
                                   PEAK_FLOPS, HBM_BW, ICI_BW)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tf
from repro.optim import adamw

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def _abstract_params(cfg):
    return jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))


def run_cell(arch: str, shape: str, multi_pod: bool,
             with_retrieval: bool = True) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return roofline record."""
    spec = get_arch(arch)
    if shape in spec.skip_shapes:
        return dict(arch=arch, shape=shape,
                    mesh="multi" if multi_pod else "single",
                    status="SKIP", reason=spec.skip_shapes[shape])
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = spec.model
    kind = SHAPES[shape]["kind"]
    t0 = time.time()
    with use_mesh(mesh):
        if kind == "train":
            jitted, _, _ = steps_lib.build_train_step(spec, shape, mesh)
            p = _abstract_params(cfg)
            opt = jax.eval_shape(
                lambda: adamw.init_opt_state(
                    tf.init_params(jax.random.PRNGKey(0), cfg),
                    adamw.AdamWConfig()))
            batch = specs_lib.train_batch_struct(spec, shape)
            lowered = jitted.lower(p, opt, batch)
        elif kind == "prefill":
            jitted, _ = steps_lib.build_prefill_step(spec, shape, mesh)
            p = _abstract_params(cfg)
            caches = specs_lib.cache_struct(spec, shape)
            batch = specs_lib.prefill_struct(spec, shape)
            lowered = jitted.lower(p, caches, batch)
        else:  # decode
            jitted, shardings, (ccfg, structs) = steps_lib.build_serve_step(
                spec, shape, mesh, with_retrieval=with_retrieval)
            p = _abstract_params(cfg)
            args = [p, structs["cache"], structs["batch"]]
            if with_retrieval:
                args += [structs["db_params"], structs["db_shard"],
                         structs["payload"]]
                if "proj" in structs:
                    args.append(structs["proj"])
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    cost = analyze_hlo(compiled.as_text())
    terms = roofline_terms(cost)
    dom = dominant_term(terms)

    # MODEL_FLOPS: 6*N*D for train (fwd+bwd), 2*N*D for inference, with
    # N = active params; D = tokens processed by this step.
    n_active = cfg.active_param_count()
    sh = SHAPES[shape]
    if kind == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        model_flops = 6.0 * n_active * tokens
    elif kind == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = sh["global_batch"]          # one token per sequence
        model_flops = 2.0 * n_active * tokens
    n_dev = mesh.devices.size
    model_flops_per_dev = model_flops / n_dev
    total = max(sum(terms.values()), 1e-30)

    rec = dict(
        arch=arch, shape=shape, mesh="multi" if multi_pod else "single",
        status="OK", kind=kind, n_devices=int(n_dev),
        retrieval=bool(with_retrieval and kind == "decode"),
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        hlo_flops_per_dev=cost.flops,
        hlo_bytes_per_dev=cost.bytes,
        collective_bytes_per_dev=cost.collective_bytes,
        collectives={k: v for k, v in cost.coll.items()},
        compute_s=terms["compute_s"], memory_s=terms["memory_s"],
        collective_s=terms["collective_s"], dominant=dom,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops_per_dev / cost.flops
                            if cost.flops else 0.0),
        roofline_fraction=(max(terms.values()) / total),
        arg_bytes_per_dev=mem.argument_size_in_bytes,
        temp_bytes_per_dev=mem.temp_size_in_bytes,
        out_bytes_per_dev=mem.output_size_in_bytes,
        xla_cost_flops=ca.get("flops", 0.0),
        xla_cost_bytes=ca.get("bytes accessed", 0.0),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--no-retrieval", action="store_true")
    ap.add_argument("--paper-archs", action="store_true",
                    help="also run the paper's Table-2 RALM configs")
    ap.add_argument("--out", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = (list_archs(include_paper=args.paper_archs)
             if args.arch == "all" else [args.arch])
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    RESULTS.mkdir(parents=True, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for m in meshes:
                out = pathlib.Path(args.out) if args.out else (
                    RESULTS / f"dryrun_{arch}_{shape}_{m}.json")
                if out.exists() and not args.force:
                    print(f"[skip-cached] {arch} {shape} {m}")
                    continue
                print(f"[dryrun] {arch} x {shape} x {m} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod=(m == "multi"),
                                   with_retrieval=not args.no_retrieval)
                except Exception as e:  # a failing cell is a bug — record it
                    rec = dict(arch=arch, shape=shape, mesh=m,
                               status="FAIL", error=str(e)[-2000:],
                               tb=traceback.format_exc()[-4000:])
                out.write_text(json.dumps(rec, indent=1))
                status = rec["status"]
                if status == "OK":
                    print(f"  OK compile={rec['compile_s']}s "
                          f"dom={rec['dominant']} "
                          f"flops/dev={rec['hlo_flops_per_dev']:.3e} "
                          f"bytes/dev={rec['hlo_bytes_per_dev']:.3e} "
                          f"coll/dev={rec['collective_bytes_per_dev']:.3e}",
                          flush=True)
                else:
                    print(f"  {status}: {rec.get('reason', rec.get('error', ''))[:300]}",
                          flush=True)


if __name__ == "__main__":
    main()
