"""ShapeDtypeStruct stand-ins for every model/engine input — the dry-run
lowers against these (no device allocation).

The vector database is described at the paper's scale (1e9 vectors, Table 3)
with per-arch dimensionality: query dim = min(d_model, 1024) (larger models
project the hidden state down before search, standard OPQ-style practice;
the projection is a serve-time parameter), m = query_dim / 16 (the paper's
dsub=16 across all datasets).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import SHAPES, ArchSpec
from repro.core.chamvs import ChamVSConfig
from repro.core.ivfpq import IVFPQConfig, IVFPQParams, IVFPQShard
from repro.models.config import ModelConfig
from repro.models.sharding import dp_axes

S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ServeDBSpec:
    """Deployment-scale retrieval database description (paper Table 3)."""
    n_vectors: int = 1_000_000_000
    nlist: int = 32768
    nprobe: int = 32
    nbits: int = 8

    def for_model(self, cfg: ModelConfig, num_shards: int, k: int
                  ) -> ChamVSConfig:
        dq = min(cfg.d_model, 1024)
        m = max(dq // 16, 4)
        per = self.n_vectors / self.nlist / num_shards
        cap = int(-(-per * 1.10 // 128) * 128)  # +10% imbalance headroom
        icfg = IVFPQConfig(dim=dq, nlist=self.nlist, m=m, nbits=self.nbits,
                           residual=True, list_cap=max(cap, 128))
        return ChamVSConfig(ivfpq=icfg, nprobe=self.nprobe, k=k,
                            backend="ref")


def db_struct(ccfg: ChamVSConfig, num_shards: int
              ) -> Tuple[IVFPQParams, IVFPQShard]:
    i = ccfg.ivfpq
    params = IVFPQParams(
        coarse_centroids=S((i.nlist, i.dim), jnp.float32),
        codebooks=S((i.m, i.ksub, i.dsub), jnp.float32))
    shard = IVFPQShard(
        codes=S((num_shards, i.nlist, i.list_cap, i.m), jnp.uint8),
        ids=S((num_shards, i.nlist, i.list_cap), jnp.int32),
        list_len=S((num_shards, i.nlist), jnp.int32))
    return params, shard


def db_specs(mesh: Mesh) -> Tuple[Any, Any]:
    """Partition specs for (IVFPQParams, stacked IVFPQShard)."""
    dp = dp_axes(mesh)
    return (IVFPQParams(P(), P()),
            IVFPQShard(codes=P(dp), ids=P(dp), list_len=P(dp)))


def num_db_shards(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# per-(arch, shape) input structs
# ---------------------------------------------------------------------------

def train_batch_struct(spec: ArchSpec, shape_name: str) -> Dict[str, Any]:
    sh = SHAPES[shape_name]
    B, T = sh["global_batch"], sh["seq_len"]
    cfg = spec.model
    batch: Dict[str, Any] = {"labels": S((B, T), jnp.int32)}
    if cfg.frontend == "vision":
        # patch embeddings from the stub frontend + M-RoPE position streams
        batch["embeds"] = S((B, T, cfg.d_model), jnp.dtype(cfg.dtype))
        batch["positions"] = S((3, B, T), jnp.int32)
    else:
        batch["tokens"] = S((B, T), jnp.int32)
    if cfg.arch == "encdec":
        enc_len = 512 if cfg.frontend == "audio" else spec.rag.k * spec.rag.chunk_len
        batch["enc_embeds"] = S((B, enc_len, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    return batch


def train_batch_specs(spec: ArchSpec, shape_name: str, mesh: Mesh
                      ) -> Dict[str, Any]:
    dp = dp_axes(mesh)
    cfg = spec.model
    out: Dict[str, Any] = {"labels": P(dp, None)}
    if cfg.frontend == "vision":
        out["embeds"] = P(dp, None, None)
        out["positions"] = P(None, dp, None)
    else:
        out["tokens"] = P(dp, None)
    if cfg.arch == "encdec":
        out["enc_embeds"] = P(dp, None, None)
    return out


def prefill_struct(spec: ArchSpec, shape_name: str) -> Dict[str, Any]:
    sh = SHAPES[shape_name]
    B, T = sh["global_batch"], sh["seq_len"]
    cfg = spec.model
    batch: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        batch["embeds"] = S((B, T, cfg.d_model), jnp.dtype(cfg.dtype))
        batch["positions"] = S((3, B, T), jnp.int32)
    else:
        batch["tokens"] = S((B, T), jnp.int32)
        batch["positions"] = S((B, T), jnp.int32)
    if cfg.arch == "encdec":
        enc_len = 512 if cfg.frontend == "audio" else spec.rag.k * spec.rag.chunk_len
        batch["enc_embeds"] = S((B, enc_len, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    return batch


def decode_struct(spec: ArchSpec, shape_name: str) -> Dict[str, Any]:
    sh = SHAPES[shape_name]
    B = sh["global_batch"]
    cfg = spec.model
    batch: Dict[str, Any] = {
        "token": S((B, 1), jnp.int32),
        "position": S((B,), jnp.int32),
    }
    if cfg.arch == "encdec":
        enc_len = 512 if cfg.frontend == "audio" else spec.rag.k * spec.rag.chunk_len
        batch["enc_states"] = S((B, enc_len, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    return batch


def cache_struct(spec: ArchSpec, shape_name: str) -> Any:
    """Abstract decode caches for the shape's KV length."""
    from repro.models import transformer as tf
    sh = SHAPES[shape_name]
    B, T = sh["global_batch"], sh["seq_len"]
    cfg = spec.model
    enc_len = 0
    if cfg.arch == "encdec":
        enc_len = 512 if cfg.frontend == "audio" else spec.rag.k * spec.rag.chunk_len
    return jax.eval_shape(
        lambda: tf.init_cache(cfg, B, max_seq=T, enc_len=enc_len))
