"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Production path: builds the mesh, shards params/optimizer, runs the
fault-tolerant TrainController (periodic async checkpoints, deterministic
resume, straggler monitoring). On this CPU container use ``--reduced`` with
small steps; on a pod the same flags drive the full config.
"""
from __future__ import annotations

import argparse

import jax

from repro.compat import use_mesh
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh_for
from repro.models import transformer as tf
from repro.models.sharding import param_specs, put_named, sanitize
from repro.optim import adamw
from repro.runtime.fault_tolerance import StragglerMonitor, TrainController


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-size config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data", type=int, default=1, help="data-parallel size")
    ap.add_argument("--model", type=int, default=1, help="tensor-parallel size")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.reduced if args.reduced else spec.model
    mesh = make_mesh_for(data=args.data, model=args.model)
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                             total_steps=args.steps)

    with use_mesh(mesh):
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        p_specs = sanitize(param_specs(cfg, mesh), params, mesh)
        params = put_named(params, p_specs, mesh)
        opt = adamw.init_opt_state(params, ocfg)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: tf.lm_loss(p, cfg, batch))(params)
            params, opt_state, m = adamw.apply_updates(params, grads,
                                                       opt_state, ocfg)
            m["loss"] = loss
            return params, opt_state, m

        data = SyntheticTokens(DataConfig(seq_len=args.seq_len,
                                          global_batch=args.batch,
                                          vocab_size=cfg.vocab_size))
        mon = StragglerMonitor(on_straggler=lambda ev: print(
            f"[straggler] step {ev.step}: {ev.ratio:.1f}x median"))
        ctl = TrainController(jax.jit(train_step), data, args.ckpt_dir,
                              ckpt_every=args.ckpt_every, monitor=mon)
        params, opt = ctl.run(params, opt, total_steps=args.steps)
        losses = [m["loss"] for m in ctl.metrics_log]
        print(f"[train] {args.arch}: step0 loss {losses[0]:.4f} -> "
              f"final {losses[-1]:.4f} over {len(losses)} steps")


if __name__ == "__main__":
    main()
