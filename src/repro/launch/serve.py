"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Builds a small RALM deployment end-to-end on the local devices through
the unified ``repro.serve`` API: a ``DatastoreBuilder`` indexes a
synthetic datastore, an ``EngineConfig`` picks monolithic (one mesh) or
disaggregated (LM pool + retrieval pool) deployment, and the engine's
scheduler pipelines the request batches.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.serve import (DatastoreBuilder, EngineConfig, RalmEngine,
                         RalmRequest)


def build_datastore(params, cfg, rng, n_docs=64, doc_len=32, num_shards=2):
    """kNN-LM datastore over a synthetic corpus. Returns a
    ``repro.serve.Datastore`` (the build recipe itself lives in
    ``DatastoreBuilder``)."""
    corpus = rng.integers(0, cfg.vocab_size, size=(n_docs, doc_len),
                          dtype=np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8,
                          num_shards=num_shards).from_corpus(
                              params, cfg, corpus)
    return ds


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dec_s")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--requests", type=int, default=2,
                    help="concurrent request batches (pipelined)")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split devices into LM + retrieval pools")
    ap.add_argument("--async-retrieval", action="store_true",
                    help="route searches through a RetrievalService "
                         "(wave coalescing + result cache)")
    ap.add_argument("--retrieval-cache", type=int, default=0,
                    help="RetrievalService LRU cache entries (0 = off)")
    ap.add_argument("--speculate-k", type=int, default=0,
                    help="speculative retrieval depth: due steps decode "
                         "ahead on stale neighbors while the real search "
                         "runs async; verified (and rolled back on "
                         "mismatch) k waves later. Requires "
                         "--async-retrieval. 0 = off")
    ap.add_argument("--no-speculate-verify", action="store_true",
                    help="skip verify-and-rollback: trust stale "
                         "neighbors outright (bounded quality drift, "
                         "zero rollback cost)")
    ap.add_argument("--retrieval-deadline-ms", type=float, default=0.0,
                    help="per-dispatch retrieval latency budget in ms: a "
                         "fault domain still unresolved past it is dropped "
                         "and the flush serves exact top-k over the "
                         "survivors (0 = wait indefinitely). Arms the "
                         "fault-tolerant dispatch layer; requires "
                         "--async-retrieval")
    ap.add_argument("--hedge-quantile", type=float, default=0.95,
                    help="latency quantile after which a hung retrieval "
                         "dispatch is hedged to another replica")
    ap.add_argument("--shard-replicas", type=int, default=1,
                    help="dispatch-target replicas per retrieval fault "
                         "domain (>1 arms replica failover; requires "
                         "--async-retrieval)")
    ap.add_argument("--chaos", default=None, metavar="PLAN.json",
                    help="arm a deterministic FaultPlan (JSON) at the "
                         "retrieval scan boundary: injected hangs / "
                         "crashes / errors / slowdowns exercise failover, "
                         "hedging, and partial results (docs/retrieval.md)")
    ap.add_argument("--no-retrieval-measure", action="store_true",
                    help="drop the per-flush stage-timing host blocks "
                         "(maximum decode/search overlap; the stats line "
                         "then reports counters only)")
    ap.add_argument("--per-sequence", action="store_true",
                    help="per-sequence oracle decode (one LM dispatch per "
                         "sequence) instead of wave-batched decode over "
                         "the KV-cache pool")
    ap.add_argument("--kv-slots", type=int, default=None,
                    help="fix the KV pool capacity in prompt rows; "
                         "default grows on demand")
    ap.add_argument("--kernel-backend", choices=["ref", "pallas"],
                    default=None,
                    help="override the ChamVS scan kernel backend")
    ap.add_argument("--no-interpret", action="store_true",
                    help="run Pallas kernels compiled instead of in "
                         "interpret mode (needs a real accelerator)")
    ap.add_argument("--staged-scan", action="store_true",
                    help="per-shard staged scan pipeline (one chamvs "
                         "dispatch per shard; the parity oracle) instead "
                         "of the fused single-dispatch chamvs_scan")
    ap.add_argument("--attn-kernel", choices=["ref", "pallas", "einsum"],
                    default=None,
                    help="wave decode-attention kernel: ref = grouped "
                         "einsum over the KV-head axis (default, the CPU "
                         "serving flavor), pallas = the streaming "
                         "decode_attn kernel (pair with --no-interpret "
                         "on a real accelerator), einsum = the legacy "
                         "full-materialization oracle")
    ap.add_argument("--attn-seq-block", type=int, default=16,
                    help="KV-pool seq-axis alignment quantum: per-wave "
                         "attention reads crop to this multiple of the "
                         "valid prefix instead of the padded max_seq")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the observability tracer and write the "
                         "Chrome trace-event JSON here on exit (open at "
                         "https://ui.perfetto.dev; docs/observability.md)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the Prometheus text exposition of the "
                         "run's metrics registry after the demo batches "
                         "(with --gateway the same data is live at "
                         "GET /metricsz)")
    ap.add_argument("--gateway", action="store_true",
                    help="serve over HTTP instead of running the demo "
                         "batches: OpenAI-style /v1/completions with SSE "
                         "streaming, per-tenant admission + backpressure, "
                         "load-shedding degradation (docs/serving.md)")
    ap.add_argument("--port", type=int, default=8000,
                    help="gateway listen port (with --gateway)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="gateway bind address (with --gateway)")
    args = ap.parse_args()

    from repro.models import transformer as tf
    spec = get_arch(args.arch)
    cfg = spec.reduced if args.reduced else spec.model
    rag = spec.rag
    rng = np.random.default_rng(0)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)

    disaggregate = args.disaggregate and len(jax.devices()) >= 2
    ret_devices = min(2, len(jax.devices()) - 1) if disaggregate else 1
    ds = build_datastore(params, cfg, rng,
                         num_shards=ret_devices if disaggregate else 2)
    ccfg = ds.search_config(nprobe=4, k=min(rag.k, 8), backend="ref")

    econfig = EngineConfig(model=cfg, rag=rag, disaggregate=disaggregate,
                           lm_devices=1, ret_devices=ret_devices,
                           async_retrieval=args.async_retrieval,
                           retrieval_cache=args.retrieval_cache,
                           retrieval_measure=not args.no_retrieval_measure,
                           speculate_k=args.speculate_k,
                           speculate_verify=not args.no_speculate_verify,
                           wave_decode=not args.per_sequence,
                           kv_slots=args.kv_slots,
                           kernel_backend=args.kernel_backend,
                           kernel_interpret=(False if args.no_interpret
                                             else None),
                           kernel_fused=(False if args.staged_scan
                                         else None),
                           attn_backend=args.attn_kernel,
                           attn_interpret=(False if args.no_interpret
                                           else None),
                           attn_seq_block=args.attn_seq_block,
                           retrieval_deadline_s=(
                               args.retrieval_deadline_ms / 1e3),
                           hedge_quantile=args.hedge_quantile,
                           shard_replicas=args.shard_replicas,
                           chaos_plan=args.chaos,
                           trace=args.trace is not None,
                           trace_path=args.trace)
    engine = RalmEngine.from_config(econfig, params, ds, ccfg)

    if args.gateway:
        from repro.serve import Gateway, GatewayConfig
        Gateway(engine, GatewayConfig(host=args.host,
                                      port=args.port)).serve_forever()
        if args.trace:
            print(f"[serve] trace written to {engine.write_trace()}")
        return

    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size,
                                        size=(args.batch, 8), dtype=np.int32))
               for _ in range(args.requests)]
    t0 = time.time()
    for prompt in prompts:
        engine.submit(RalmRequest(prompt=prompt, steps=args.steps))
    responses = engine.run()
    dt = time.time() - t0

    mode = engine.backend.name
    for resp in responses:
        print(f"[serve] {mode} request {resp.request_id}: "
              f"{resp.tokens.shape} last tokens "
              f"{resp.tokens[:, -4:].tolist()}")
    ntok = sum(r.tokens.shape[0] * r.steps for r in responses)
    line = f"[serve] {mode}: {len(responses)} batches, {ntok} tokens in " \
           f"{dt:.2f}s ({ntok/dt:.1f} tok/s)"
    if engine.times is not None:
        line += (f"; optimal LM:retrieval ratio estimate "
                 f"{engine.times.optimal_ratio():.2f}")
    print(line)
    if engine.pool is not None:
        ps = engine.pool.stats
        print(f"[serve] kv pool: {engine.pool.capacity} slots "
              f"(high water {ps.high_water}), {ps.waves} waves avg "
              f"{ps.mean_wave():.1f} rows -> {engine.decode_dispatches} "
              f"LM dispatches, buckets {sorted(ps.buckets)}")
        print(f"[serve] decode attn [{engine.attn_spec.backend}]: "
              f"{ps.blocks_skipped}/{ps.blocks_total} seq blocks skipped "
              f"({ps.skip_fraction():.0%} of pool padding), "
              f"{ps.decode_compiles} decode graphs "
              f"(seq block {engine.pool.seq_block})")
    service = getattr(engine.retriever, "service", None)
    if service is not None:
        st = service.stats
        line = (f"[serve] retrieval service: {st.batched_rows} rows in "
                f"{st.num_batches} waves / {st.scan_dispatches} scan "
                f"dispatches "
                f"(coalescing {st.coalescing_factor():.1f}x, "
                f"cache {st.cache_hits} hit / {st.cache_misses} miss)")
        if service.config.measure:
            line += (f"; queue-wait {st.queue_wait.mean_s * 1e6:.0f}us "
                     f"scan {st.scan.mean_s * 1e6:.0f}us "
                     f"merge {st.merge.mean_s * 1e6:.0f}us")
        print(line)
        if service.replicas is not None:
            states = service.replicas.state_counts()
            print(f"[serve] fault tolerance: {st.ft_timeouts} timeouts, "
                  f"{st.ft_hedges} hedges, {st.ft_retries} retries, "
                  f"{st.ft_crashes} crashes -> {st.ft_ejections} "
                  f"ejections / {st.ft_recoveries} recoveries; "
                  f"{st.ft_partial_flushes} partial flushes "
                  f"({st.ft_partial_rows} rows); replicas "
                  + " ".join(f"{k}={v}" for k, v in states.items() if v))
        if st.spec_issued:
            print(f"[serve] speculation: {st.spec_issued} issued, "
                  f"{st.spec_accepted}/{st.spec_verified} accepted "
                  f"({st.spec_acceptance_rate():.0%}), "
                  f"{st.spec_rollbacks} rollbacks "
                  f"({st.spec_replayed_steps} steps replayed), "
                  f"residual wait {st.spec_wait.mean_s * 1e6:.0f}us/wave")

    if args.trace:
        print(f"[serve] trace written to {engine.write_trace()} "
              f"({len(engine.tracer.events())} events — open at "
              "https://ui.perfetto.dev)")
    if args.metrics:
        from repro.obs import MetricsRegistry, bind_engine_metrics
        reg = MetricsRegistry()
        bind_engine_metrics(reg, engine)
        print(reg.render(), end="")


if __name__ == "__main__":
    main()
