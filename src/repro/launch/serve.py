"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Builds a small RALM deployment end-to-end on the local devices: trains an
IVF-PQ index over a synthetic datastore, splits devices into LM/retrieval
pools (disaggregated mode) or keeps one mesh (monolithic), then serves
batched generation requests with retrieval at the configured interval.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.chamvs import ChamVSConfig
from repro.core.coordinator import DisaggregatedRuntime
from repro.core.generate import RetrievalEngine, generate
from repro.core.ivfpq import IVFPQConfig, build_shards, train_ivfpq
from repro.models import transformer as tf


def build_datastore(params, cfg, rng, n_docs=64, doc_len=32, num_shards=2):
    """kNN-LM datastore from the model's own hidden states over a corpus."""
    corpus = rng.integers(0, cfg.vocab_size, size=(n_docs, doc_len),
                          dtype=np.int32)
    _, _, hidden = tf.forward(params, cfg, tokens=jnp.asarray(corpus),
                              mode="train", return_hidden=True)
    keys = np.asarray(hidden[:, :-1].astype(jnp.float32)).reshape(
        -1, cfg.d_model)
    nxt = corpus[:, 1:].reshape(-1)
    icfg = IVFPQConfig(dim=cfg.d_model, nlist=8,
                       m=max(cfg.d_model // 16, 4), list_cap=1024)
    db_params = train_ivfpq(jax.random.PRNGKey(1), jnp.asarray(keys), icfg,
                            kmeans_iters=8)
    shards = build_shards(db_params, keys, icfg, num_shards=num_shards)
    return db_params, shards, icfg, jnp.asarray(nxt)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dec_s")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--requests", type=int, default=2,
                    help="concurrent request batches (pipelined)")
    ap.add_argument("--disaggregate", action="store_true",
                    help="split devices into LM + retrieval pools")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.reduced if args.reduced else spec.model
    rag = spec.rag
    rng = np.random.default_rng(0)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    db_params, shards, icfg, payload = build_datastore(params, cfg, rng)
    ccfg = ChamVSConfig(ivfpq=icfg, nprobe=4, k=min(rag.k, 8), backend="ref")

    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, size=(args.batch, 8),
                                        dtype=np.int32))
               for _ in range(args.requests)]
    t0 = time.time()
    if args.disaggregate and len(jax.devices()) >= 2:
        rt = DisaggregatedRuntime(
            cfg, rag, params, db_params, shards, ccfg,
            payload_tokens=payload, lm_devices=1,
            ret_devices=min(len(shards), len(jax.devices()) - 1))
        outs = rt.generate_pipelined(prompts, steps=args.steps)
        print(f"[serve] disaggregated: {len(outs)} batches x "
              f"{outs[0].shape} in {time.time()-t0:.2f}s; "
              f"optimal LM:retrieval ratio estimate "
              f"{rt.times.optimal_ratio():.2f}")
    else:
        engine = RetrievalEngine(params=db_params, shards=shards, cfg=ccfg,
                                 payload_tokens=payload)
        for i, prompt in enumerate(prompts):
            out = generate(params, cfg, rag, prompt, steps=args.steps,
                           engine=engine)
            print(f"[serve] monolithic batch {i}: {out.shape} "
                  f"last tokens {np.asarray(out[:, -4:]).tolist()}")
        print(f"[serve] total {time.time()-t0:.2f}s")


if __name__ == "__main__":
    main()
