"""DBRX-132B — fine-grained MoE, 16 experts top-4
[hf:databricks/dbrx-base; unverified]."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352, d_head=128, block="moe",
    n_experts=16, top_k=4, rope_theta=5e5)

REDUCED = reduce_cfg(CONFIG)

register(ArchSpec(
    name="dbrx_132b", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="hf:databricks/dbrx-base; unverified",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
