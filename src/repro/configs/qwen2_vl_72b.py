"""Qwen2-VL-72B — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings + M-RoPE position streams for the backbone."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064, d_head=128, qkv_bias=True,
    rope_mode="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    frontend="vision")

REDUCED = reduce_cfg(CONFIG, mrope_sections=(2, 3, 3))

register(ArchSpec(
    name="qwen2_vl_72b", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="arXiv:2409.12191; hf",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
