"""Paper Table 2: Dec-S — 101M decoder-only RALM (kNN-LM, interval 1, K=100).

d_ff chosen so gated-MLP params match the paper's 2*d*4d FFN budget
(3*d*f = 8*d^2 -> f = 8d/3), giving ~101M with tied embeddings."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dec-s", n_layers=24, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=1368, vocab_size=50000, d_head=64, tie_embeddings=True)

REDUCED = reduce_cfg(CONFIG, n_kv_heads=4)

register(ArchSpec(
    name="dec_s", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="paper Table 2",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
