"""RWKV-6 "Finch" 3B — attention-free, data-dependent decay
[arXiv:2404.05892; hf].

The paper's interval-1 decoder-only RALM case maps perfectly: the RWKV
hidden state is the retrieval query (kNN-LM). long_500k RUNS: O(1)-state
decode is the designated sub-quadratic cell."""
from repro.configs import ArchSpec, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536, d_head=64, block="rwkv6", rope_mode="none")

REDUCED = reduce_cfg(CONFIG, n_heads=4, n_kv_heads=4)

register(ArchSpec(
    name="rwkv6_3b", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="arXiv:2404.05892; hf"))
