"""Gemma-3-4B — 5:1 local:global sliding-window, 262k vocab
[hf:google/gemma-3-1b-pt; unverified].

long_500k RUNS for this arch: 5/6 of layers use a 1024-token ring KV cache
(sub-quadratic); the sparse global layers decode O(L) against the full cache
(hybrid-subquadratic, DESIGN.md §5)."""
from repro.configs import ArchSpec, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab_size=262144, d_head=256,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024, rope_theta=1e6, tie_embeddings=True)

REDUCED = reduce_cfg(CONFIG)

register(ArchSpec(
    name="gemma3_4b", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="hf:google/gemma-3-1b-pt; unverified"))
