"""Paper Table 2: EncDec-S — 158M RETRO-style RALM (2-layer shallow encoder +
24-layer decoder; retrieval intervals 8/64/512, K=10)."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="encdec-s", n_layers=24, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=1368, vocab_size=50000, d_head=64, arch="encdec", n_enc_layers=2,
    tie_embeddings=True)

REDUCED = reduce_cfg(CONFIG, n_kv_heads=4)

register(ArchSpec(
    name="encdec_s", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="retro", interval=64, k=10, chunk_len=64),
    source="paper Table 2",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
