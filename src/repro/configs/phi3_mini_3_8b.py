"""Phi-3-mini-3.8B — RoPE SwiGLU, MHA (kv=32) [arXiv:2404.14219; unverified]."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32,
    n_kv_heads=32, d_ff=8192, vocab_size=32064, d_head=96, rope_theta=1e4)

REDUCED = reduce_cfg(CONFIG, n_kv_heads=4)

register(ArchSpec(
    name="phi3_mini_3_8b", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="arXiv:2404.14219; unverified",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
