"""Paper Table 2: Dec-L — 1259M decoder-only RALM (kNN-LM, interval 1)."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dec-l", n_layers=96, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2736, vocab_size=50000, d_head=64, tie_embeddings=True)

REDUCED = reduce_cfg(CONFIG, n_kv_heads=4)

register(ArchSpec(
    name="dec_l", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="paper Table 2",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
