"""Paper Table 2: EncDec-L — 1738M RETRO-style RALM (2-layer encoder +
96-layer decoder)."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="encdec-l", n_layers=96, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2736, vocab_size=50000, d_head=64, arch="encdec", n_enc_layers=2,
    tie_embeddings=True)

REDUCED = reduce_cfg(CONFIG, n_kv_heads=4)

register(ArchSpec(
    name="encdec_l", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="retro", interval=64, k=10, chunk_len=64),
    source="paper Table 2",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
