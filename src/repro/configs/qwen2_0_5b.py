"""Qwen2-0.5B — dense GQA with QKV bias [arXiv:2407.10671; hf]."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151936, d_head=64, qkv_bias=True,
    rope_theta=1e6, tie_embeddings=True)

REDUCED = reduce_cfg(CONFIG)

register(ArchSpec(
    name="qwen2_0_5b", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="arXiv:2407.10671; hf",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
