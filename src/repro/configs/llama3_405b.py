"""Llama-3.1-405B — dense GQA, 128k vocab [arXiv:2407.21783; unverified]."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", n_layers=126, d_model=16384, n_heads=128,
    n_kv_heads=8, d_ff=53248, vocab_size=128256, d_head=128,
    rope_theta=5e5)

REDUCED = reduce_cfg(CONFIG)

register(ArchSpec(
    name="llama3_405b", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="arXiv:2407.21783; unverified",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
