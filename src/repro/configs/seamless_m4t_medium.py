"""SeamlessM4T-medium — encoder-decoder, multimodal [arXiv:2308.11596; hf].

Audio frontend is a STUB (input_specs() provides precomputed frame
embeddings for the encoder). This is the closest assigned arch to the
paper's EncDec RALMs: retrieved chunks feed the encoder, the decoder
cross-attends (RETRO-style, paper §2.1 category 1)."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", n_layers=12, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=4096, vocab_size=256206, d_head=64,
    arch="encdec", n_enc_layers=12, frontend="audio")

REDUCED = reduce_cfg(CONFIG, n_kv_heads=4)

register(ArchSpec(
    name="seamless_m4t_medium", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="retro", interval=64, k=10, chunk_len=64),
    source="arXiv:2308.11596; hf",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
