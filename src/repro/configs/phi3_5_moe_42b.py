"""Phi-3.5-MoE-42B (6.6B active) — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
from repro.configs import ArchSpec, FULL_ATTENTION_SKIP, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=6400, vocab_size=32064, d_head=128, block="moe",
    n_experts=16, top_k=2)

REDUCED = reduce_cfg(CONFIG)

register(ArchSpec(
    name="phi3_5_moe_42b", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
    skip_shapes={"long_500k": FULL_ATTENTION_SKIP}))
