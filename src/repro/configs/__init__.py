"""Architecture registry: the 10 assigned backbones + the paper's own four
RALM configs (Table 2), each with a full config (dry-run only) and a reduced
config (CPU smoke tests).

``--arch <id>`` everywhere resolves through ``get_arch``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

ASSIGNED = (
    "qwen2_0_5b", "llama3_405b", "phi3_mini_3_8b", "gemma3_4b",
    "qwen2_vl_72b", "seamless_m4t_medium", "hymba_1_5b", "dbrx_132b",
    "phi3_5_moe_42b", "rwkv6_3b",
)
PAPER = ("dec_s", "dec_l", "encdec_s", "encdec_l")

# the assigned input-shape grid (LM transformer shapes: seq_len x global_batch)
SHAPES: Dict[str, Dict] = {
    "train_4k":    dict(kind="train",   seq_len=4096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768,  global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32768,  global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524288, global_batch=1),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    model: ModelConfig
    reduced: ModelConfig
    rag: RagConfig
    source: str                         # public-literature citation
    # shape name -> reason, for cells that are skipped per the assignment
    skip_shapes: Dict[str, str] = dataclasses.field(default_factory=dict)

    def applicable_shapes(self) -> Tuple[str, ...]:
        return tuple(s for s in SHAPES if s not in self.skip_shapes)


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_arch(name: str) -> ArchSpec:
    name = name.replace("-", "_").replace(".", "_")
    if name not in _REGISTRY:
        importlib.import_module(f"repro.configs.{name}")
    return _REGISTRY[name]


def list_archs(include_paper: bool = True) -> Tuple[str, ...]:
    return ASSIGNED + (PAPER if include_paper else ())


FULL_ATTENTION_SKIP = (
    "pure full attention — long_500k requires sub-quadratic attention "
    "(DESIGN.md §5); skipped per assignment"
)


def reduce_cfg(cfg: ModelConfig, **over) -> ModelConfig:
    """Family-preserving reduction for CPU smoke tests."""
    pattern = over.get("layer_pattern", cfg.layer_pattern)
    base = dict(
        n_layers=min(cfg.n_layers, 4 if len(pattern) <= 2
                     else len(pattern) + 1),
        d_model=64, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        d_ff=128, vocab_size=512, d_head=0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        window=min(cfg.window, 8) if cfg.window else 0,
    )
    base.update(over)
    return dataclasses.replace(cfg, **base)
