"""Hymba-1.5B — parallel attention + mamba heads in every block
[arXiv:2411.13676; hf].

Adaptation notes (DESIGN.md §5): Hymba places full-attention layers at
{0, 15, 31} and SWA elsewhere; our cyclic layer-pattern mechanism puts the
full-attention layers at {0, 16} (period-16 cycle). Meta tokens are omitted.
long_500k RUNS: hybrid attn∥SSM with ring caches is sub-quadratic."""
from repro.configs import ArchSpec, reduce_cfg, register
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, d_head=64, block="hybrid", ssm_state=16,
    layer_pattern=("global",) + ("local",) * 15, window=1024)

REDUCED = reduce_cfg(CONFIG, layer_pattern=("global", "local", "local"),
                     n_heads=4, n_kv_heads=2)

register(ArchSpec(
    name="hymba_1_5b", model=CONFIG, reduced=REDUCED,
    rag=RagConfig(mode="knnlm", interval=1, k=100),
    source="arXiv:2411.13676; hf"))
