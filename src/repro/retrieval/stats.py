"""Per-stage accounting for the retrieval service (paper Fig. 9/10 axes).

ChamVS latency decomposes into queue wait (micro-batching delay), the
per-shard IVF/PQ scan, the hierarchical K-selection merge, and the
payload gather. ``RetrievalStats`` accumulates each stage plus the
service-level counters the benchmarks and the overlap/cache tests key
on: how many queries arrived, how many *kernel dispatches* served them
(coalescing factor), and the cache hit/miss split.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

from repro.obs.metrics import Reservoir


@dataclasses.dataclass
class StageStat:
    """Accumulated wall time for one pipeline stage.

    Besides mean/max, a bounded reservoir (``repro.obs.metrics.
    Reservoir``, algorithm R) keeps a uniform sample of the per-event
    durations so ``summary()`` can report p50/p99 — micro-batching
    makes the stage distributions bimodal (deadline flushes vs full
    flushes), and a mean+max pair hides exactly that tail."""
    total_s: float = 0.0
    count: int = 0
    max_s: float = 0.0
    reservoir: Reservoir = dataclasses.field(
        default_factory=lambda: Reservoir(cap=1024))

    def add(self, dt: float) -> None:
        self.total_s += dt
        self.count += 1
        if dt > self.max_s:
            self.max_s = dt
        self.reservoir.add(dt)

    @property
    def mean_s(self) -> float:
        return self.total_s / self.count if self.count else 0.0

    def p50_s(self) -> float:
        return self.reservoir.quantile(0.50)

    def p99_s(self) -> float:
        return self.reservoir.quantile(0.99)

    def summary(self) -> Dict[str, float]:
        return dict(mean_us=self.mean_s * 1e6, max_us=self.max_s * 1e6,
                    p50_us=self.p50_s() * 1e6, p99_us=self.p99_s() * 1e6,
                    total_s=self.total_s, count=self.count)


class RetrievalStats:
    """Counters + stage timings for one ``RetrievalService``.

    ``num_batches`` counts flushes (one batched scan+merge per flush);
    dividing ``num_queries`` by it gives the achieved coalescing factor
    — the quantity the deadline/max_batch knobs trade against queue
    wait. ``scan_dispatches`` counts the underlying ChamVS scan kernel
    dispatches: with the fused ``chamvs_scan`` path it equals
    ``num_batches`` regardless of shard count (one dispatch per wave);
    with the staged oracle it is ``num_batches * num_shards``. The
    per-flush dispatch count is derived from the pipeline's structure
    (``LocalPipeline.scan_dispatches``); the structure itself is pinned
    by a jaxpr-level test counting ``pallas_call``s
    (tests/test_chamvs_scan.py::test_fused_graph_contains_single_scan_kernel).
    """

    #: gaps between consecutive recorded events larger than this are
    #: treated as idle time and excluded from the QPS window
    idle_gap_s: float = 1.0

    def __init__(self, clock: Callable[[], float] = time.perf_counter
                 ) -> None:
        self._clock = clock
        self.reset()

    def reset(self) -> None:
        self.num_queries = 0          # query rows submitted
        self.num_batches = 0          # flushes (batched scan+merge runs)
        self.scan_dispatches = 0      # ChamVS scan kernel dispatches: the
        #                               fused path issues ONE per flush
        #                               regardless of shard count, the
        #                               staged oracle one per shard
        self.batched_rows = 0         # query rows that reached a dispatch
        self.cache_hits = 0           # query rows answered from cache
        self.cache_misses = 0         # query rows that went to the kernel
        self.cache_stale = 0          # rows present but generation-stale
        #                               at a fresh lookup (missed)
        self.max_coalesced = 0        # largest rows-per-dispatch seen
        self.queue_wait = StageStat()
        self.scan = StageStat()
        self.merge = StageStat()
        self.gather = StageStat()
        # -- speculative retrieval (engine-side, mirrored here so one
        #    snapshot covers the whole retrieval plane) ----------------
        self.spec_issued = 0          # speculative dispatches: due steps
        #                               that decoded ahead on stale
        #                               neighbors while the real search
        #                               ran async
        self.spec_verified = 0        # speculation points verified
        self.spec_landed = 0          # points whose search results were
        #                               already materialized when the
        #                               harvest asked — latency fully
        #                               hidden behind the decode wave(s)
        self.spec_accepted = 0        # ... whose emitted token matched
        self.spec_rollbacks = 0       # ... that mismatched -> rollback
        self.spec_discarded = 0       # points dropped unverified (later
        #                               points of a rolled-back sequence,
        #                               cancelled requests, flushes)
        self.spec_replayed_steps = 0  # decode steps redone during
        #                               rollback replay
        self.spec_wait = StageStat()  # host block at verification: the
        #                               residual retrieval time NOT
        #                               hidden behind decode
        self.spec_replay = StageStat()  # rollback + replay cost per event
        # -- fault tolerance (replica failover / deadlines / chaos) ----
        self.ft_timeouts = 0          # dispatches past the deadline: hung
        #                               replicas AND late-but-used results
        self.ft_hedges = 0            # hedged re-dispatches after a hang
        #                               outlived the hedge delay
        self.ft_retries = 0           # transient-error re-dispatches
        #                               (retry-with-backoff)
        self.ft_crashes = 0           # replica-crash outcomes observed
        self.ft_ejections = 0         # health transitions into `ejected`
        self.ft_recoveries = 0        # probation -> healthy transitions
        self.ft_partial_flushes = 0   # flushes that served a live subset
        self.ft_partial_rows = 0      # query rows in those flushes (the
        #                               recall-proxy accounting: each row's
        #                               top-k covered only live domains)
        self.ft_spec_flushed = 0      # speculation points settled against
        #                               a partial (timed-out) real search
        self.ft_dispatch = StageStat()  # wall time of the fault-tolerant
        #                               dispatch loop per flush (scan +
        #                               failover + hedging)
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._active_s = 0.0          # accumulated busy window (gaps
        #                               clipped to idle_gap_s)

    # ------------------------------------------------------------------
    def _touch(self, now: float) -> None:
        """Advance the active-time window: accumulate the gap since the
        previous event, clipped to ``idle_gap_s`` so a long idle pause
        between bursts doesn't deflate the rate."""
        if self._t_first is None:
            self._t_first = now
        else:
            self._active_s += min(max(0.0, now - self._t_last),
                                  self.idle_gap_s)
        self._t_last = now

    def record_submit(self, nrows: int) -> None:
        self._touch(self._clock())
        self.num_queries += nrows

    def record_batch(self, nrows: int, dispatches: int = 1) -> None:
        self.num_batches += 1
        self.scan_dispatches += dispatches
        self.batched_rows += nrows
        self._touch(self._clock())
        if nrows > self.max_coalesced:
            self.max_coalesced = nrows

    def coalescing_factor(self) -> float:
        """Rows per kernel dispatch, over the rows that actually reached
        a dispatch — cache-hit rows never produce one, so they are
        excluded (else a cached run would overstate batching)."""
        return self.batched_rows / self.num_batches if self.num_batches \
            else 0.0

    def qps(self) -> float:
        """Queries per second over the *active* window.

        The old first-to-last-timestamp window had two failure modes:
        a single flush (submit and batch at nearly the same instant)
        reported ~0 or wildly inflated rates, and any idle gap between
        bursts deflated the rate toward zero. The active window sums
        inter-event gaps clipped to ``idle_gap_s``, so bursts separated
        by idle time report the rate *within* the bursts."""
        if self.num_queries == 0 or self._t_first is None:
            return 0.0
        window = self._active_s
        if window <= 0.0:
            # only one recorded instant so far: measure to "now",
            # clipped to the idle gap, so a single flush reports a
            # finite rate instead of 0.0
            window = min(max(self._clock() - self._t_first, 1e-9),
                         self.idle_gap_s)
        return self.num_queries / window

    def spec_acceptance_rate(self) -> float:
        """Fraction of verified speculation points whose speculated
        token matched the real neighbors' (RaLMSpec's headline metric)."""
        return (self.spec_accepted / self.spec_verified
                if self.spec_verified else 0.0)

    def spec_rollback_rate(self) -> float:
        return (self.spec_rollbacks / self.spec_verified
                if self.spec_verified else 0.0)

    def snapshot(self) -> Dict[str, object]:
        """The Fig. 9/10-style breakdown the benchmark emits."""
        return dict(
            num_queries=self.num_queries,
            num_batches=self.num_batches,
            scan_dispatches=self.scan_dispatches,
            batched_rows=self.batched_rows,
            coalescing_factor=self.coalescing_factor(),
            cache_hits=self.cache_hits,
            cache_misses=self.cache_misses,
            cache_stale=self.cache_stale,
            max_coalesced=self.max_coalesced,
            qps=self.qps(),
            queue_wait=self.queue_wait.summary(),
            scan=self.scan.summary(),
            merge=self.merge.summary(),
            gather=self.gather.summary(),
            speculation=dict(
                issued=self.spec_issued,
                verified=self.spec_verified,
                landed=self.spec_landed,
                accepted=self.spec_accepted,
                rollbacks=self.spec_rollbacks,
                discarded=self.spec_discarded,
                replayed_steps=self.spec_replayed_steps,
                acceptance_rate=self.spec_acceptance_rate(),
                rollback_rate=self.spec_rollback_rate(),
                spec_wait=self.spec_wait.summary(),
                spec_replay=self.spec_replay.summary(),
            ),
            fault=dict(
                timeouts=self.ft_timeouts,
                hedges=self.ft_hedges,
                retries=self.ft_retries,
                crashes=self.ft_crashes,
                ejections=self.ft_ejections,
                recoveries=self.ft_recoveries,
                partial_flushes=self.ft_partial_flushes,
                partial_rows=self.ft_partial_rows,
                spec_flushed=self.ft_spec_flushed,
                dispatch=self.ft_dispatch.summary(),
            ),
        )
