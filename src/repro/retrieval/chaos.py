"""Deterministic fault injection for the retrieval tier.

Real shard failure cannot happen in CI, so every failure mode the
fault-tolerance layer claims to survive is *injected* here,
reproducibly: a seeded ``FaultPlan`` decides, per (flush, fault domain,
replica, attempt), whether a dispatch hangs, crashes, errors
transiently, or runs slow — and the decision is a pure function of the
plan, so two runs with the same plan and request stream observe the
same fault sequence regardless of wall-clock jitter.

The injection point is the pipeline ``scan`` boundary inside
``RetrievalService._dispatch_scan`` (both ``LocalPipeline`` and
``RouterPipeline`` route through it): the service consults
``ChaosInjector.outcome(...)`` for the replica it is about to charge
with the dispatch, and the returned fault shapes what the dispatch
loop sees —

  * ``hang``  — the replica never answers; the service waits out the
    quantile-based hedge delay and re-dispatches (a *hedge*);
  * ``crash`` — the replica is gone; instant failover + ejection;
  * ``error`` — transient failure; retry-with-backoff on the same
    replica, failover once ``max_retries`` is spent;
  * ``slow``  — the dispatch completes but ``slow_s`` late; late
    completions past the per-dispatch deadline count as timeouts and
    feed the suspect/eject machine.

``FaultPlan.realtime`` decides whether modeled latencies (hedge waits,
slowdowns, backoffs) are also *slept* — the availability benchmark
sleeps them so latency-under-faults is honest wall-clock; the unit
tests keep ``realtime=False`` and assert on the modeled accounting,
so the chaos suite runs in milliseconds.

Plans round-trip through JSON (``--chaos plan.json`` on the serve
launcher)::

    {"seed": 0, "realtime": false,
     "faults": [{"kind": "crash", "shard": 0, "replica": 0,
                 "start_flush": 8, "stop_flush": 24, "p": 1.0}]}
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["FaultKind", "FaultSpec", "FaultPlan", "ChaosInjector",
           "ScanHang", "ReplicaCrash", "TransientScanError"]

#: the injectable failure modes
FaultKind = ("hang", "crash", "error", "slow")


class ScanHang(TimeoutError):
    """A dispatch that never answered (surfaced only when the dispatch
    loop has no replica left to hedge to and partials are disabled)."""


class ReplicaCrash(RuntimeError):
    """A dispatch whose target process died."""


class TransientScanError(RuntimeError):
    """A dispatch that failed but is worth retrying."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule. ``shard``/``replica`` of -1 match any fault
    domain / any replica; the flush window is [start_flush, stop_flush)
    with ``stop_flush=-1`` meaning forever; ``p`` is the per-dispatch
    injection probability (sampled deterministically — see
    ``ChaosInjector.outcome``). ``slow_s`` is the added latency for
    ``kind="slow"`` (a fixed slowdown; fractional slowdowns come from
    ``p < 1``: only that fraction of dispatches is slowed)."""
    kind: str
    shard: int = -1
    replica: int = -1
    start_flush: int = 0
    stop_flush: int = -1
    p: float = 1.0
    slow_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FaultKind:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FaultKind}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p must be in [0, 1], got {self.p}")

    def matches(self, flush: int, shard: int, replica: int) -> bool:
        if self.shard >= 0 and shard != self.shard:
            return False
        if self.replica >= 0 and replica != self.replica:
            return False
        if flush < self.start_flush:
            return False
        return self.stop_flush < 0 or flush < self.stop_flush

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded set of injection rules. First matching rule wins (rule
    order is declaration order), so a plan can carve exceptions by
    listing a narrower rule first."""
    faults: Tuple[FaultSpec, ...] = ()
    seed: int = 0
    realtime: bool = False    # sleep the modeled latencies for honest
    #                           wall-clock (benchmarks); False keeps the
    #                           accounting but never sleeps (tests)

    @staticmethod
    def make(faults: Sequence[FaultSpec], seed: int = 0,
             realtime: bool = False) -> "FaultPlan":
        return FaultPlan(faults=tuple(faults), seed=seed,
                         realtime=realtime)

    # -- JSON round-trip (the --chaos plan.json surface) --------------------

    def to_json(self) -> str:
        return json.dumps(dict(
            seed=self.seed, realtime=self.realtime,
            faults=[f.as_dict() for f in self.faults]), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        obj = json.loads(text)
        return cls(faults=tuple(FaultSpec(**f)
                                for f in obj.get("faults", ())),
                   seed=int(obj.get("seed", 0)),
                   realtime=bool(obj.get("realtime", False)))

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as fh:
            return cls.from_json(fh.read())


class ChaosInjector:
    """Evaluates a ``FaultPlan`` at the scan boundary.

    Determinism contract: the outcome for a given (flush, shard,
    replica, attempt) is a pure function of the plan — each probability
    draw uses ``np.random.default_rng`` seeded with exactly that tuple
    (plus the rule index), so outcomes are independent of dispatch
    order, wall-clock, and each other. Two services running the same
    plan over the same request stream inject the same faults.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.injected: Dict[str, int] = {k: 0 for k in FaultKind}

    def outcome(self, flush: int, shard: int, replica: int,
                attempt: int = 0) -> Optional[FaultSpec]:
        """The fault (if any) this dispatch suffers; ``None`` = healthy."""
        for idx, spec in enumerate(self.plan.faults):
            if not spec.matches(flush, shard, replica):
                continue
            if spec.p < 1.0:
                rng = np.random.default_rng(
                    [self.plan.seed, idx, flush, shard, replica, attempt])
                if rng.random() >= spec.p:
                    continue
            self.injected[spec.kind] += 1
            return spec
        return None

    def counts(self) -> Dict[str, int]:
        return dict(self.injected)


def crash_plan(shard: int = -1, replica: int = 0, start: int = 0,
               stop: int = -1, seed: int = 0,
               realtime: bool = False) -> FaultPlan:
    """Convenience: the benchmark's 1-of-N-replicas-crashed scenario."""
    return FaultPlan.make(
        [FaultSpec(kind="crash", shard=shard, replica=replica,
                   start_flush=start, stop_flush=stop)],
        seed=seed, realtime=realtime)
