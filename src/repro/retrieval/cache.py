"""LRU query-result cache for the retrieval service.

RALM decode queries are hidden states, so exact-match caching never
fires; instead keys are the query vectors quantized to a grid
(``round(q / quant)``) — queries within the quantization radius share a
key, which is the regime where their top-K lists agree anyway. Entries
are per query *row*; a batch lookup is all-or-nothing so a batched
submission either skips the kernel entirely or runs as one batch (no
partial-batch scatter on the hot path).

Hit/miss counters live here (mirrored into ``RetrievalStats`` by the
service); eviction is least-recently-*used* — both hits and inserts
refresh recency.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np


class QueryCache:
    """LRU map: quantized query vector -> (dists [K], ids [K])."""

    def __init__(self, capacity: int, quant: float = 1e-3):
        if capacity <= 0:
            raise ValueError(f"capacity must be > 0, got {capacity}")
        self.capacity = capacity
        self.quant = quant
        self.hits = 0
        self.misses = 0
        self._data: "OrderedDict[bytes, Tuple[np.ndarray, np.ndarray]]" = \
            OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def key(self, row: np.ndarray) -> bytes:
        q = np.asarray(row, np.float32)
        return np.round(q / self.quant).astype(np.int64).tobytes()

    # ------------------------------------------------------------------
    def get_batch(self, queries: np.ndarray
                  ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """All-or-nothing lookup of a [B, d] query batch.

        Every row present -> (dists [B, K], ids [B, K]), counted as B
        hits with recency refreshed. Any row absent -> None, counted as
        B misses (the whole batch goes to the kernel)."""
        queries = np.asarray(queries, np.float32)
        keys = [self.key(row) for row in queries]
        if any(kb not in self._data for kb in keys):
            self.misses += len(keys)
            return None
        self.hits += len(keys)
        rows = []
        for kb in keys:
            self._data.move_to_end(kb)
            rows.append(self._data[kb])
        return (np.stack([r[0] for r in rows]),
                np.stack([r[1] for r in rows]))

    def put_batch(self, queries: np.ndarray, dists: np.ndarray,
                  ids: np.ndarray) -> None:
        """Insert per-row results, evicting least-recently-used entries
        beyond capacity."""
        queries = np.asarray(queries, np.float32)
        for row, d, i in zip(queries, np.asarray(dists), np.asarray(ids)):
            kb = self.key(row)
            self._data[kb] = (d, i)
            self._data.move_to_end(kb)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    def contains(self, row: np.ndarray) -> bool:
        """Membership probe without touching counters or recency."""
        return self.key(row) in self._data

    def clear(self) -> None:
        self._data.clear()
