"""LRU query-result cache for the retrieval service.

RALM decode queries are hidden states, so exact-match caching never
fires; instead keys are the query vectors quantized to a grid
(``round(q / quant)``) — queries within the quantization radius share a
key, which is the regime where their top-K lists agree anyway. Entries
are per query *row*.

Batch lookups come in two flavors, selected at construction:

  * ``partial=False`` (the historical default): all-or-nothing — a
    batched submission either skips the kernel entirely or runs as one
    batch (no partial-batch scatter on the hot path). Kept as-is for
    the existing parity tests.
  * ``partial=True``: per-row lookup returning a hit mask alongside the
    result arrays, so the service can send ONLY the missed rows to the
    kernel and stitch the batch back together at flush.

Entries also carry a **generation**: ``mark_stale()`` bumps the cache's
current generation without dropping entries, so a quality-knob change
(the degrade ladder's nprobe swaps) invalidates them for *fresh*
lookups while ``get_stale`` can still serve them as speculation seeds —
stale neighbors are exactly what speculative retrieval decodes ahead
with, and verification catches any divergence.

Hit/miss/stale counters live here (mirrored into ``RetrievalStats`` by
the service); eviction is least-recently-*used* — both hits and inserts
refresh recency.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np


class QueryCache:
    """LRU map: quantized query vector -> (dists [K], ids [K], gen)."""

    def __init__(self, capacity: int, quant: float = 1e-3,
                 partial: bool = False):
        if capacity <= 0:
            raise ValueError(f"capacity must be > 0, got {capacity}")
        self.capacity = capacity
        self.quant = quant
        self.partial = partial
        self.generation = 0      # bumped by mark_stale(); entries written
        #                          at an older generation only serve
        #                          through get_stale()
        self.hits = 0            # fresh rows served by get_batch
        self.misses = 0          # rows get_batch could not serve fresh
        self.stale = 0           # of those misses: present but outdated
        self.stale_served = 0    # stale rows served via get_stale()
        self._data: "OrderedDict[bytes, Tuple[np.ndarray, np.ndarray, int]]" \
            = OrderedDict()

    def __len__(self) -> int:
        return len(self._data)

    def key(self, row: np.ndarray) -> bytes:
        q = np.asarray(row, np.float32)
        return np.round(q / self.quant).astype(np.int64).tobytes()

    def mark_stale(self) -> None:
        """Invalidate every current entry for fresh lookups WITHOUT
        dropping it — the degrade ladder calls this on quality changes
        so stale neighbors stay available as speculation seeds."""
        self.generation += 1

    # ------------------------------------------------------------------
    def get_batch(self, queries: np.ndarray):
        """Fresh lookup of a [B, d] query batch.

        All-or-nothing mode (``partial=False``): every row present at
        the current generation -> (dists [B, K], ids [B, K]), counted
        as B hits with recency refreshed; otherwise None, counted as B
        misses (rows found but stale additionally bump ``stale``).

        Partial mode (``partial=True``): returns (dists [B, K],
        ids [B, K], hit [B] bool) with missed rows zero-filled, or None
        when no row hits at all; per-row hit/miss/stale counting."""
        queries = np.asarray(queries, np.float32)
        keys = [self.key(row) for row in queries]
        fresh = [kb in self._data and self._data[kb][2] == self.generation
                 for kb in keys]
        if not self.partial:
            if not all(fresh):
                self.misses += len(keys)
                self.stale += sum(1 for kb, f in zip(keys, fresh)
                                  if not f and kb in self._data)
                return None
            self.hits += len(keys)
            rows = []
            for kb in keys:
                self._data.move_to_end(kb)
                rows.append(self._data[kb])
            return (np.stack([r[0] for r in rows]),
                    np.stack([r[1] for r in rows]))
        nhit = sum(fresh)
        self.hits += nhit
        self.misses += len(keys) - nhit
        self.stale += sum(1 for kb, f in zip(keys, fresh)
                          if not f and kb in self._data)
        if nhit == 0:
            return None
        first = next(self._data[kb] for kb, f in zip(keys, fresh) if f)
        dists = np.zeros((len(keys),) + first[0].shape, first[0].dtype)
        ids = np.full((len(keys),) + first[1].shape, -1, first[1].dtype)
        for j, (kb, f) in enumerate(zip(keys, fresh)):
            if f:
                self._data.move_to_end(kb)
                d, i, _ = self._data[kb]
                dists[j], ids[j] = d, i
        return dists, ids, np.asarray(fresh, bool)

    def get_stale(self, queries: np.ndarray
                  ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Stale-tolerant all-or-nothing lookup: serve ANY generation.

        Feeds speculation (the caller decodes ahead on these and
        verifies against the real search), so correctness never depends
        on freshness here. No hit/miss accounting — only
        ``stale_served`` for rows whose entry is outdated — and no
        recency refresh (a speculation seed is not a demand hit)."""
        queries = np.asarray(queries, np.float32)
        keys = [self.key(row) for row in queries]
        if any(kb not in self._data for kb in keys):
            return None
        rows = [self._data[kb] for kb in keys]
        self.stale_served += sum(1 for r in rows
                                 if r[2] != self.generation)
        return (np.stack([r[0] for r in rows]),
                np.stack([r[1] for r in rows]))

    def put_batch(self, queries: np.ndarray, dists: np.ndarray,
                  ids: np.ndarray) -> None:
        """Insert per-row results at the current generation, evicting
        least-recently-used entries beyond capacity."""
        queries = np.asarray(queries, np.float32)
        for row, d, i in zip(queries, np.asarray(dists), np.asarray(ids)):
            kb = self.key(row)
            self._data[kb] = (d, i, self.generation)
            self._data.move_to_end(kb)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    def contains(self, row: np.ndarray, any_generation: bool = False
                 ) -> bool:
        """Membership probe without touching counters or recency."""
        kb = self.key(row)
        if kb not in self._data:
            return False
        return any_generation or self._data[kb][2] == self.generation

    def clear(self) -> None:
        self._data.clear()
