"""Shard replica groups: the health state machine behind fault-tolerant
retrieval dispatch.

Chameleon disaggregates the vector-search tier so it can scale
independently of the LM tier (paper §3) — which also makes it an
independent *failure domain*: a hung or crashed ChamVS shard must not
stall every decode wave behind the retrieval flush. This module owns
the control-plane half of the answer: each fault domain (a shard for
``LocalPipeline``, the whole in-graph search for ``RouterPipeline``)
has a group of dispatch-target replicas, each with a health state
machine driven by per-dispatch outcome reports:

    healthy --bad x suspect_after--> suspect
    suspect --bad x eject_after----> ejected      (crash: any -> ejected)
    ejected --probation_s cool-off-> probation    (probe traffic resumes)
    probation --ok x probation_successes--> healthy   (a "recovery")
    probation --any bad------------> ejected      (failed probe)

``pick()`` is the dispatch router: healthy replicas round-robin;
suspect and probation-due replicas receive probe traffic every
``probe_every`` picks (so a benched replica can either re-prove itself
or finish failing toward ejection while healthy peers carry the load);
suspects otherwise serve only when nothing better exists.
``hedge_delay_s()`` is the quantile of observed dispatch latencies —
the delay after which ``RetrievalService`` hedges a hung dispatch to
another replica (the classic tail-at-scale hedged-request rule).

In-process the replicas are *dispatch-target bookkeeping*, not copies
of the shard data: all replicas of a domain answer from the same
arrays, so a failover re-serves bit-identical candidates. What this
layer models faithfully is the control plane — which target is asked,
when the service gives up on it, and how latency/ejection accounting
evolves — which is exactly what the chaos tests and the availability
benchmark exercise. A multi-host deployment would back each replica id
with a real copy; nothing in the state machine changes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.obs.metrics import Reservoir

__all__ = ["FailoverConfig", "ReplicaGroup", "ReplicaHealth",
           "HEALTHY", "SUSPECT", "EJECTED", "PROBATION"]

HEALTHY = "healthy"
SUSPECT = "suspect"
EJECTED = "ejected"
PROBATION = "probation"

#: outcomes a dispatch can report; everything but "ok" counts against
#: the replica ("slow" = completed past the per-dispatch deadline,
#: "timeout" = never answered before the hedge fired, "error" = a
#: transient failure worth retrying, "crash" = the process is gone)
OUTCOMES = ("ok", "slow", "timeout", "error", "crash")


@dataclasses.dataclass(frozen=True)
class FailoverConfig:
    """Knobs of the fault-tolerant dispatch layer (``ServiceConfig.
    failover``). ``replicas`` is per fault domain; the deadline/hedge
    fields govern ``RetrievalService._dispatch_scan``; the rest drive
    the health state machine above."""
    replicas: int = 2             # dispatch targets per fault domain
    dispatch_deadline_s: float = 0.0  # per-dispatch latency budget; a
    #                               dispatch still pending past it stops
    #                               failing over and serves partial
    #                               results (0 = no deadline)
    hedge_quantile: float = 0.95  # latency quantile after which a hung
    #                               dispatch is hedged to another replica
    hedge_floor_s: float = 0.005  # hedge delay floor while the latency
    #                               reservoir is still cold
    suspect_after: int = 1        # consecutive bad outcomes -> suspect
    eject_after: int = 3          # consecutive bad outcomes -> ejected
    probation_s: float = 1.0      # cool-off before an ejected replica
    #                               becomes probe-eligible again
    probation_successes: int = 2  # consecutive probe successes -> healthy
    probe_every: int = 4          # send probe traffic to a probation-due
    #                               replica every N picks (healthy peers
    #                               carry the rest)
    max_retries: int = 1          # transient-error retries per replica
    #                               within one dispatch
    backoff_s: float = 0.0        # base retry backoff (doubles per retry)
    sleep_cap_s: float = 0.25     # cap on any single real-time chaos/
    #                               hedge/backoff sleep
    allow_partial: bool = True    # serve exact top-k over the surviving
    #                               domains when a domain is down past
    #                               the deadline; False raises instead


@dataclasses.dataclass
class ReplicaHealth:
    """Per-(domain, replica) state machine cell."""
    state: str = HEALTHY
    consec_fail: int = 0
    consec_ok: int = 0
    ejected_at: float = 0.0
    dispatches: int = 0
    failures: int = 0


class ReplicaGroup:
    """Health-tracked dispatch targets for every fault domain of one
    pipeline. ``clock`` is injectable so tests drive probation cool-off
    without sleeping; ``on_transition(domain, replica, old, new)`` lets
    the owning service count ejections/recoveries and emit trace
    instants without this module importing the tracer."""

    def __init__(self, num_shards: int, cfg: FailoverConfig,
                 clock: Callable[[], float] = time.perf_counter,
                 on_transition: Optional[
                     Callable[[int, int, str, str], None]] = None):
        if num_shards < 1 or cfg.replicas < 1:
            raise ValueError(f"need >= 1 shard and >= 1 replica, got "
                             f"{num_shards} x {cfg.replicas}")
        self.num_shards = num_shards
        self.cfg = cfg
        self.clock = clock
        self.sleep: Callable[[float], None] = time.sleep
        self.on_transition = on_transition
        self.health: Dict[Tuple[int, int], ReplicaHealth] = {
            (s, r): ReplicaHealth()
            for s in range(num_shards) for r in range(cfg.replicas)}
        self._rr = [0] * num_shards
        self.latency = Reservoir(cap=512)
        self.ejections = 0
        self.recoveries = 0
        self.transitions: List[Dict[str, object]] = []   # bounded log

    # -- dispatch routing ---------------------------------------------------

    def pick(self, shard: int, exclude: Optional[Set[int]] = None
             ) -> Optional[int]:
        """Choose the dispatch target for ``shard``, skipping
        ``exclude`` (replicas already tried this dispatch). Returns
        ``None`` when every remaining replica is ejected and not yet
        probation-due — the shard is down."""
        exclude = exclude or set()
        cand = [r for r in range(self.cfg.replicas) if r not in exclude]
        if not cand:
            return None
        self._rr[shard] += 1
        now = self.clock()
        healthy, suspect, probing = [], [], []
        for r in cand:
            h = self.health[(shard, r)]
            if h.state == HEALTHY:
                healthy.append(r)
            elif h.state == SUSPECT:
                suspect.append(r)
            elif h.state == PROBATION:
                probing.append(r)
            elif h.state == EJECTED and \
                    now - h.ejected_at >= self.cfg.probation_s:
                probing.append(r)      # cool-off served: probe-eligible
        # probe cadence: when probe-eligible or suspect replicas exist,
        # divert every probe_every-th pick to one — otherwise a benched
        # replica never gets the traffic it needs to recover (suspect +
        # ok -> healthy) or to finish failing (suspect + bad x
        # eject_after -> ejected) while healthy peers carry the load
        revisit = probing + suspect
        if revisit and (not healthy or
                        self._rr[shard] % self.cfg.probe_every == 0):
            return self._begin_probe(shard, revisit[0], now)
        if healthy:
            return healthy[self._rr[shard] % len(healthy)]
        if suspect:
            return suspect[self._rr[shard] % len(suspect)]
        if probing:
            return self._begin_probe(shard, probing[0], now)
        return None

    def _begin_probe(self, shard: int, r: int, now: float) -> int:
        h = self.health[(shard, r)]
        if h.state == EJECTED:
            self._transition(shard, r, h, PROBATION, now)
            h.consec_ok = 0
            h.consec_fail = 0
        return r

    # -- outcome reporting --------------------------------------------------

    def report(self, shard: int, replica: int, outcome: str,
               latency_s: Optional[float] = None) -> None:
        """Feed one dispatch outcome into the state machine. ``latency_s``
        (successful dispatches) feeds the hedge-delay quantile."""
        if outcome not in OUTCOMES:
            raise ValueError(f"unknown outcome {outcome!r}")
        h = self.health[(shard, replica)]
        h.dispatches += 1
        now = self.clock()
        if latency_s is not None:
            self.latency.add(latency_s)
        if outcome == "ok":
            h.consec_fail = 0
            h.consec_ok += 1
            if h.state == SUSPECT:
                self._transition(shard, replica, h, HEALTHY, now)
            elif h.state == PROBATION and \
                    h.consec_ok >= self.cfg.probation_successes:
                self._transition(shard, replica, h, HEALTHY, now)
            return
        h.failures += 1
        h.consec_ok = 0
        if outcome == "crash":
            h.consec_fail = 0
            h.ejected_at = now
            if h.state != EJECTED:
                self._transition(shard, replica, h, EJECTED, now)
            return
        h.consec_fail += 1
        if h.state == PROBATION or h.consec_fail >= self.cfg.eject_after:
            h.ejected_at = now                       # failed probe, or
            h.consec_fail = 0                        # chronic failures
            if h.state != EJECTED:
                self._transition(shard, replica, h, EJECTED, now)
        elif h.state == HEALTHY and \
                h.consec_fail >= self.cfg.suspect_after:
            self._transition(shard, replica, h, SUSPECT, now)

    def _transition(self, shard: int, replica: int, h: ReplicaHealth,
                    new: str, now: float) -> None:
        old, h.state = h.state, new
        if new == EJECTED:
            self.ejections += 1
        if old == PROBATION and new == HEALTHY:
            self.recoveries += 1
        if len(self.transitions) < 256:
            self.transitions.append(dict(
                t=now, shard=shard, replica=replica, old=old, new=new))
        if self.on_transition is not None:
            self.on_transition(shard, replica, old, new)

    # -- hedging ------------------------------------------------------------

    def hedge_delay_s(self) -> float:
        """Quantile-based hedge delay (tail-at-scale): hedge a pending
        dispatch once it has outlived the ``hedge_quantile`` of observed
        latencies; floor while the reservoir is cold."""
        q = self.latency.quantile(self.cfg.hedge_quantile)
        return max(q, self.cfg.hedge_floor_s)

    # -- observability ------------------------------------------------------

    def live_domains(self) -> List[bool]:
        """Per-domain liveness: at least one replica not ejected (an
        ejected-but-probation-due replica counts as live: it can still
        be dispatched to)."""
        now = self.clock()
        out = []
        for s in range(self.num_shards):
            live = False
            for r in range(self.cfg.replicas):
                h = self.health[(s, r)]
                if h.state != EJECTED or \
                        now - h.ejected_at >= self.cfg.probation_s:
                    live = True
                    break
            out.append(live)
        return out

    def state_counts(self) -> Dict[str, int]:
        counts = {HEALTHY: 0, SUSPECT: 0, EJECTED: 0, PROBATION: 0}
        for h in self.health.values():
            counts[h.state] += 1
        return counts

    def snapshot(self) -> Dict[str, object]:
        return dict(
            num_shards=self.num_shards,
            replicas=self.cfg.replicas,
            states=self.state_counts(),
            ejections=self.ejections,
            recoveries=self.recoveries,
            hedge_delay_s=self.hedge_delay_s(),
            transitions=list(self.transitions[-32:]),
            per_replica={
                f"{s}/{r}": dict(state=h.state,
                                 dispatches=h.dispatches,
                                 failures=h.failures)
                for (s, r), h in self.health.items()},
        )
