"""Hierarchical K-selection merge (paper §4.2, steps 7-8).

Every memory node ships a truncated top-k' candidate list; the global
top-K is their exact merge. This module owns that merge as a
first-class, independently tested component (it used to be inlined in
``core/chamvs.py`` / ``core/ivfpq.py``):

  * ``flat_merge``   — single-level: concatenate all producers' lists
    and run one K-selection over ``S * k'`` candidates (the CPU
    coordinator flavor);
  * ``hierarchical_merge`` — tree of partial K-selections with
    ``fanout`` producers per node (the paper's network-aggregation
    topology for large shard counts): each level keeps only
    ``min(K, fanout * k')`` survivors, so no single selection ever sees
    the full candidate set.

Both are exact: for any shard count, the returned (distance, id) pairs
equal the global top-K over the union of candidates (the property test
in ``tests/test_retrieval.py`` asserts hierarchical ≡ flat). Padded or
absent candidates are carried as ``(+inf, -1)`` and sort last, matching
the per-shard convention in ``chamvs.shard_search``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _pad_to_k(dists: jnp.ndarray, ids: jnp.ndarray, k: int
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pad [..., c] candidate lists with (+inf, -1) up to k columns."""
    c = dists.shape[-1]
    if c >= k:
        return dists[..., :k], ids[..., :k]
    widths = [(0, 0)] * (dists.ndim - 1) + [(0, k - c)]
    return (jnp.pad(dists, widths, constant_values=jnp.inf),
            jnp.pad(ids, widths, constant_values=-1))


def _select(dists: jnp.ndarray, ids: jnp.ndarray, k: int
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k smallest along the last axis (ascending order)."""
    keep = min(k, dists.shape[-1])
    neg, pos = jax.lax.top_k(-dists, keep)
    return -neg, jnp.take_along_axis(ids, pos, axis=-1)


def flat_merge(dists: jnp.ndarray, ids: jnp.ndarray, k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One K-selection over every producer's candidates.

    dists/ids: [S, nq, k'] -> ([nq, K], [nq, K]), ascending by distance.
    """
    S, nq, c = dists.shape
    d = jnp.moveaxis(dists, 0, 1).reshape(nq, S * c)
    i = jnp.moveaxis(ids, 0, 1).reshape(nq, S * c)
    return _pad_to_k(*_select(d, i, k), k)


def hierarchical_merge(dists: jnp.ndarray, ids: jnp.ndarray, k: int,
                       fanout: int = 2
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Tree-merge: ``fanout`` producers per node, exact at every level.

    Keeping ``min(K, fanout * c)`` survivors per node loses nothing —
    a candidate outside its node's top-K cannot be in the global top-K.

    dists/ids: [S, nq, k'] -> ([nq, K], [nq, K]), ascending by distance.
    """
    if fanout < 2:
        raise ValueError(f"fanout must be >= 2, got {fanout}")
    d, i = dists, ids
    while d.shape[0] > 1:
        S, nq, c = d.shape
        pad = (-S) % fanout
        if pad:  # absent producers contribute (+inf, -1) candidates
            d = jnp.concatenate(
                [d, jnp.full((pad, nq, c), jnp.inf, d.dtype)], axis=0)
            i = jnp.concatenate(
                [i, jnp.full((pad, nq, c), -1, i.dtype)], axis=0)
        groups = d.shape[0] // fanout
        d = d.reshape(groups, fanout, nq, c).transpose(0, 2, 1, 3) \
             .reshape(groups, nq, fanout * c)
        i = i.reshape(groups, fanout, nq, c).transpose(0, 2, 1, 3) \
             .reshape(groups, nq, fanout * c)
        d, i = _select(d, i, k)
    # the loop never runs for S == 1, and its last iteration may keep
    # fewer than k sorted columns — one final exact selection either way
    return _pad_to_k(*_select(d[0], i[0], k), k)


def merge_topk(dists: jnp.ndarray, ids: jnp.ndarray, k: int,
               fanout: Optional[int] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The service's merge entry point: flat (``fanout=None``) or
    hierarchical. Flat is the parity-exact default (identical candidate
    ordering to the historical ``ivfpq.merge_topk``)."""
    if fanout is None or dists.shape[0] <= 1:
        return flat_merge(dists, ids, k)
    return hierarchical_merge(dists, ids, k, fanout=fanout)


def mask_producers(dists: jnp.ndarray, ids: jnp.ndarray,
                   live: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mask dead producers' candidate lists to the padding sentinel
    before a merge: ``live`` is a [S] bool over the producer axis, and
    a False row becomes ``(+inf, -1)`` — the same convention padded
    candidates already use, so the downstream K-selection is *exactly*
    the global top-k over the union of the surviving producers'
    candidates. This is how a partial-result flush stays an exact
    search over the live subset rather than an approximation."""
    mask = live.reshape((-1,) + (1,) * (dists.ndim - 1))
    return (jnp.where(mask, dists, jnp.inf),
            jnp.where(mask, ids, -1))
