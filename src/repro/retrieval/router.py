"""Shard placement + query broadcast / result gather over the retrieval
mesh (paper steps 3-9, the coordinator's network fabric).

This replaces the ad-hoc ``make_distributed_search`` /
``make_distributed_gather`` pair that lived in ``core/chamvs.py``:

  * ``build_search(mesh, cfg, ...)`` — the in-graph distributed search
    (query all-gather -> per-shard scan -> truncated-survivor all-gather
    -> exact merge), unchanged semantics;
  * ``build_gather(mesh, axes)`` — id -> payload conversion against a
    fully sharded table without the full-table all-gather;
  * ``ShardRouter`` — the object form: owns the mesh, the placement of
    quantizers / DB shards / payload tables, and the jitted search and
    gather callables, so callers stop re-deriving shard counts and
    ``PartitionSpec``s at every site.

``core/chamvs.py`` keeps deprecated wrappers for the two builders.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map, use_mesh
from repro.core import ivfpq
from repro.core.chamvs import (ChamVSConfig, shard_search, stack_shards)
from repro.core.ivfpq import IVFPQParams, IVFPQShard


def num_db_shards(mesh: Mesh, db_axes: Tuple[str, ...]) -> int:
    """Memory-node count = product of the db mesh axes present."""
    n = 1
    for a in db_axes:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def build_search(
    mesh: Mesh,
    cfg: ChamVSConfig,
    db_axes: Tuple[str, ...] = ("data",),
    query_axis: Optional[str] = "model",
    nq: Optional[int] = None,
):
    """Build the in-graph distributed search fn for ``mesh``.

    Returns ``search(params, stacked_shard, queries) -> (dists, ids)`` with
    replicated outputs [nq, K]. ``stacked_shard`` must carry a leading shard
    axis of size prod(mesh[a] for a in db_axes).

    Work split over ``query_axis`` (the TP columns of each DB shard row):
      * query-split — each column searches nq/qsize queries (batch serving);
      * probe-split — when nq is not divisible (e.g. long-context batch 1),
        each column scans nprobe/qsize of every query's probed lists; the
        merge then spans shards x columns (more, shorter L1 queues — the
        paper's Fig. 8 regime).
    """
    db_axes = tuple(a for a in db_axes if a in mesh.axis_names)
    num_shards = num_db_shards(mesh, db_axes)
    qa = query_axis if (query_axis and query_axis in mesh.axis_names) else None
    qsize = mesh.shape[qa] if qa else 1
    probe_split = bool(qa) and nq is not None and (
        nq % qsize != 0 and cfg.nprobe % qsize == 0)
    producers = num_shards * (qsize if probe_split else 1)
    kk = cfg.k_prime(producers)

    def body(params: IVFPQParams, shard: IVFPQShard, queries: jnp.ndarray):
        # shard: leading axis length 1 on this device; queries: [nq_local, D]
        local = jax.tree.map(lambda x: x[0], shard)
        nq_local = queries.shape[0]
        _, probe_ids = ivfpq.scan_ivf_index(params, queries, cfg.nprobe)
        if probe_split:
            npl = cfg.nprobe // qsize
            col = jax.lax.axis_index(qa)
            probe_ids = jax.lax.dynamic_slice_in_dim(
                probe_ids, col * npl, npl, axis=1)
        d, i = shard_search(params, local, queries, probe_ids, cfg, kk)
        # aggregate over memory nodes (paper step 7-8): gather the kk
        # survivors of every producer, then exact-merge.
        gather_axes = db_axes + ((qa,) if probe_split else ())
        if gather_axes:
            d = jax.lax.all_gather(d, gather_axes, axis=0, tiled=False)
            i = jax.lax.all_gather(i, gather_axes, axis=0, tiled=False)
            d = d.reshape(producers, nq_local, kk)
            i = i.reshape(producers, nq_local, kk)
            d = d.transpose(1, 0, 2).reshape(nq_local, producers * kk)
            i = i.transpose(1, 0, 2).reshape(nq_local, producers * kk)
        neg, pos = jax.lax.top_k(-d, min(cfg.k, d.shape[-1]))
        out_d = -neg
        out_i = jnp.take_along_axis(i, pos, axis=1)
        # un-split the query batch (it was sharded over the TP axis)
        if qa and not probe_split:
            out_d = jax.lax.all_gather(out_d, qa, axis=0, tiled=True)
            out_i = jax.lax.all_gather(out_i, qa, axis=0, tiled=True)
        return out_d, out_i

    shard_spec = IVFPQShard(
        codes=P(db_axes if db_axes else None),
        ids=P(db_axes if db_axes else None),
        list_len=P(db_axes if db_axes else None),
    )
    q_spec = P(qa) if (qa and not probe_split) else P()
    in_specs = (
        IVFPQParams(P(), P()),    # quantizers replicated (paper: metadata)
        shard_spec,
        q_spec,
    )
    out_specs = (P(), P())

    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)

    def search(params: IVFPQParams, stacked: IVFPQShard, queries: jnp.ndarray):
        n = queries.shape[0]
        if qa and not probe_split:
            assert n % qsize == 0, (n, qsize)
        return fn(params, stacked, queries)

    return search


def build_gather(mesh: Mesh, table_axes: Tuple[str, ...]):
    """ID -> payload conversion against a fully sharded table (paper step 9).

    ``table`` [N, ...] is sharded over ``table_axes``; ``ids`` [B, K] are
    replicated. A naive ``table[ids]`` makes GSPMD all-gather the whole
    table (measured 4 GB/step for the 1e9-entry token table —
    EXPERIMENTS.md §Perf iteration 2); instead each shard gathers the ids
    that fall in its range and a psum of the masked results (KB-scale)
    assembles the answer."""
    axes = tuple(a for a in table_axes if a in mesh.axis_names)

    def body(table, ids):
        # flattened shard index over `axes` (row-major over the mesh dims)
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        nloc = table.shape[0]
        lo = idx * nloc
        rel = ids - lo
        hit = (rel >= 0) & (rel < nloc)
        vals = table[jnp.clip(rel, 0, nloc - 1)]
        mask = hit.reshape(hit.shape + (1,) * (vals.ndim - hit.ndim))
        vals = jnp.where(mask, vals, 0)
        return jax.lax.psum(vals, axes)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axes), P()), out_specs=P(), check_vma=False)


class ShardRouter:
    """Placement + broadcast/gather for one retrieval mesh.

    Owns what every distributed call site used to re-derive by hand:
    the memory-node count, the ``PartitionSpec`` of each table, and the
    jitted search/gather callables. ``DistributedRetriever`` and the
    distributed ``RetrievalService`` pipeline are thin layers over this.
    """

    def __init__(self, mesh: Mesh, cfg: ChamVSConfig,
                 db_axes: Tuple[str, ...] = ("data",),
                 query_axis: Optional[str] = "model",
                 nq: Optional[int] = None):
        self.mesh = mesh
        self.cfg = cfg
        self.db_axes = tuple(a for a in db_axes if a in mesh.axis_names)
        self.num_shards = num_db_shards(mesh, db_axes)
        # query-split constraint: batches must divide evenly over the TP
        # columns (callers that batch dynamically pad to this multiple)
        qa = query_axis if (query_axis and
                            query_axis in mesh.axis_names) else None
        self.query_size = mesh.shape[qa] if qa else 1
        self._search = jax.jit(build_search(mesh, cfg, db_axes=db_axes,
                                            query_axis=query_axis, nq=nq))
        self._gather = jax.jit(build_gather(mesh, db_axes))

    # -- placement ----------------------------------------------------------

    def place_params(self, params: IVFPQParams) -> IVFPQParams:
        """Quantizers are metadata: replicated on every memory node."""
        return jax.device_put(params, NamedSharding(self.mesh, P()))

    def place_shards(self, shards: List[IVFPQShard]) -> IVFPQShard:
        """One DB shard per memory node along the db axes."""
        if len(shards) != self.num_shards:
            raise ValueError(
                f"one shard per memory node: {len(shards)} shards vs "
                f"{self.num_shards} nodes")
        return jax.device_put(stack_shards(shards),
                              NamedSharding(self.mesh, P(self.db_axes)))

    def place_table(self, table: Optional[jnp.ndarray]
                    ) -> Optional[jnp.ndarray]:
        """Place a payload table across the memory nodes (pad the trailing
        rows so every node holds an equal slice; padded rows are never
        addressed because ids < N)."""
        if table is None:
            return None
        n = table.shape[0]
        rem = (-n) % self.num_shards
        if rem:
            pad = [(0, rem)] + [(0, 0)] * (table.ndim - 1)
            table = jnp.pad(table, pad)
        return jax.device_put(table,
                              NamedSharding(self.mesh, P(self.db_axes)))

    # -- execution ----------------------------------------------------------

    def search(self, params: IVFPQParams, stacked: IVFPQShard,
               queries: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        with use_mesh(self.mesh):
            return self._search(params, stacked, queries)

    def gather(self, table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
        with use_mesh(self.mesh):
            return self._gather(table, ids)
