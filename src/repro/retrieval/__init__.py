"""``repro.retrieval`` — ChamVS as a standalone, disaggregated
vector-search service (paper §3-§4).

The pieces, bottom-up:

  * ``merge``   — hierarchical per-shard top-k' -> global top-K
    K-selection (exact at every tree level);
  * ``cache``   — LRU query-result cache on quantized query vectors;
  * ``router``  — shard placement + query broadcast / payload gather
    over the retrieval mesh (``ShardRouter``);
  * ``stats``   — per-stage latency / QPS / coalescing accounting;
  * ``replica`` — per-shard replica groups + the health state machine
    behind fault-tolerant dispatch (failover, hedging, ejection);
  * ``chaos``   — deterministic fault injection (``FaultPlan``) at the
    pipeline scan boundary;
  * ``service`` — ``RetrievalService``: in-flight request table,
    deadline-based micro-batching, ``SearchHandle`` futures,
    fault-tolerant dispatch with partial-result degradation.

``repro.serve`` plugs this in through ``AsyncRetriever``; the legacy
``core.chamvs.search_single`` is a one-shot call into the same service.
"""
from repro.retrieval.cache import QueryCache
from repro.retrieval.chaos import (ChaosInjector, FaultPlan, FaultSpec,
                                   ScanHang, crash_plan)
from repro.retrieval.merge import (flat_merge, hierarchical_merge,
                                   mask_producers, merge_topk)
from repro.retrieval.replica import FailoverConfig, ReplicaGroup
from repro.retrieval.router import ShardRouter, build_gather, build_search
from repro.retrieval.service import (LocalPipeline, RetrievalService,
                                     RouterPipeline, SearchHandle,
                                     ServiceConfig)
from repro.retrieval.stats import RetrievalStats, StageStat

__all__ = [
    "ChaosInjector", "FailoverConfig", "FaultPlan", "FaultSpec",
    "LocalPipeline", "QueryCache", "ReplicaGroup", "RetrievalService",
    "RetrievalStats", "RouterPipeline", "ScanHang", "SearchHandle",
    "ServiceConfig", "ShardRouter", "StageStat", "build_gather",
    "build_search", "crash_plan", "flat_merge", "hierarchical_merge",
    "mask_producers", "merge_topk",
]
