"""``repro.retrieval`` — ChamVS as a standalone, disaggregated
vector-search service (paper §3-§4).

The pieces, bottom-up:

  * ``merge``   — hierarchical per-shard top-k' -> global top-K
    K-selection (exact at every tree level);
  * ``cache``   — LRU query-result cache on quantized query vectors;
  * ``router``  — shard placement + query broadcast / payload gather
    over the retrieval mesh (``ShardRouter``);
  * ``stats``   — per-stage latency / QPS / coalescing accounting;
  * ``service`` — ``RetrievalService``: in-flight request table,
    deadline-based micro-batching, ``SearchHandle`` futures.

``repro.serve`` plugs this in through ``AsyncRetriever``; the legacy
``core.chamvs.search_single`` is a one-shot call into the same service.
"""
from repro.retrieval.cache import QueryCache
from repro.retrieval.merge import flat_merge, hierarchical_merge, merge_topk
from repro.retrieval.router import ShardRouter, build_gather, build_search
from repro.retrieval.service import (LocalPipeline, RetrievalService,
                                     RouterPipeline, SearchHandle,
                                     ServiceConfig)
from repro.retrieval.stats import RetrievalStats, StageStat

__all__ = [
    "LocalPipeline", "QueryCache", "RetrievalService", "RetrievalStats",
    "RouterPipeline", "SearchHandle", "ServiceConfig", "ShardRouter",
    "StageStat", "build_gather", "build_search", "flat_merge",
    "hierarchical_merge", "merge_topk",
]
