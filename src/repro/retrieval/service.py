"""``RetrievalService`` — ChamVS as a standalone vector-search service.

The paper's disaggregation argument (§3) is that vector search deserves
its own service tier, scaled and scheduled independently of the LM.
This module is that tier in-process:

  * an **in-flight request table**: every ``submit()`` gets a ticket and
    a ``SearchHandle`` future, so callers (the serve scheduler) issue
    queries for one wave of sequences while the previous wave is still
    decoding;
  * **deadline-based micro-batching**: pending queries from many
    concurrent sequences coalesce into *one* batched IVF-scan/PQ-ADC/
    top-k dispatch, flushed when ``max_batch`` rows accumulate, when the
    oldest query's ``deadline_s`` expires, or explicitly at the end of a
    scheduler wave (RAGO, arXiv:2503.14649, shows this cross-request
    batching dominates RAG serving throughput);
  * an **LRU result cache** on quantized query vectors — a hit skips
    the kernel entirely;
  * **per-stage stats** (queue wait / scan / merge / gather) feeding the
    Fig. 9/10-style benchmark.

The search math itself lives in ``core/chamvs.py`` (kernel frontend)
and ``retrieval/merge.py`` (K-selection); this module only batches,
caches, and accounts. ``chamvs.search_single`` is a one-shot call into
this service, so there is exactly one search implementation.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ivfpq
from repro.core.chamvs import ChamVSConfig, shard_search, stack_shards
from repro.obs.trace import NULL_TRACER
from repro.core.ivfpq import IVFPQParams, IVFPQShard
from repro.kernels.chamvs_scan.ops import fused_shard_scan
from repro.kernels.ivf_scan.ops import ivf_index_scan
from repro.retrieval import merge as merge_lib
from repro.retrieval.cache import QueryCache
from repro.retrieval.stats import RetrievalStats


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Batching / caching knobs of one service instance."""
    max_batch: int = 64           # flush when this many rows are pending
    deadline_s: float = 0.0       # flush when the oldest row waited this
    #                               long (checked at submit/poll; 0 = only
    #                               max_batch or an explicit flush())
    bucket_pow2: bool = True      # pad batches to powers of two so jit
    #                               retraces O(log max_batch) shapes
    cache_entries: int = 0        # LRU result-cache entries (0 = off).
    #                               NOTE: the cache keys on host-side
    #                               query values, so enabling it syncs
    #                               each submit (and each flush, for the
    #                               insert) — it trades async overlap for
    #                               skipping whole kernel dispatches
    cache_quant: float = 1e-3     # query quantization step for cache keys
    cache_partial: bool = True    # per-row cache hits: cached rows are
    #                               served immediately and ONLY the
    #                               missed rows go to the kernel (the
    #                               flush stitches the batch back
    #                               together). False restores the old
    #                               all-or-nothing batch lookup.
    merge_fanout: Optional[int] = None  # None = flat K-selection;
    #                               >= 2 = hierarchical tree merge
    measure: bool = True          # block per stage to record scan/merge
    #                               times (off = maximum async overlap)
    kernel_backend: Optional[str] = None  # override ChamVSConfig.backend
    #                               ("ref" | "pallas") so serving configs
    #                               can select the Pallas scan path
    kernel_interpret: Optional[bool] = None  # override ChamVSConfig.
    #                               interpret (Pallas interpret mode)
    kernel_fused: Optional[bool] = None  # override ChamVSConfig.fused:
    #                               one fused chamvs_scan dispatch per
    #                               wave (True) vs the staged per-shard
    #                               pipeline (False, the parity oracle)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n — the shape-bucketing unit shared by
    the query micro-batcher here and the serve KV pool's wave buckets."""
    b = 1
    while b < n:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# the pipeline stages, jitted once at module level (shared across
# service instances and the `search_single` one-shot path)
# ---------------------------------------------------------------------------

def _probe_stage(params: IVFPQParams, queries: jnp.ndarray,
                 cfg: ChamVSConfig) -> jnp.ndarray:
    """ChamVS.idx: pick the nprobe closest IVF lists per query. Shared
    by the fused and staged paths (parity requires identical probes),
    routed through the registry frontend when the config asks for the
    Pallas centroid scan."""
    spec = cfg.kernel_spec()
    if spec.backend == "pallas":
        _, probe_ids = ivf_index_scan(queries, params.coarse_centroids,
                                      cfg.nprobe, spec=spec)
    else:
        _, probe_ids = ivfpq.scan_ivf_index(params, queries, cfg.nprobe)
    return probe_ids


@functools.partial(jax.jit, static_argnames=("cfg", "kk"))
def _scan_stage(params: IVFPQParams, shards: Tuple[IVFPQShard, ...],
                queries: jnp.ndarray, *, cfg: ChamVSConfig, kk: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """STAGED scan: centroid scan + Python loop of per-shard IVF/PQ
    scans + per-shard top-kk — one chamvs dispatch per shard. Kept as
    the parity oracle for ``_scan_stage_fused``.

    Returns stacked candidates (dists [S, nq, kk], ids [S, nq, kk])."""
    probe_ids = _probe_stage(params, queries, cfg)
    per = [shard_search(params, s, queries, probe_ids, cfg, kk)
           for s in shards]
    return (jnp.stack([p[0] for p in per]),
            jnp.stack([p[1] for p in per]))


@functools.partial(jax.jit, static_argnames=("cfg", "kk"))
def _scan_stage_fused(params: IVFPQParams, stacked: IVFPQShard,
                      queries: jnp.ndarray, *, cfg: ChamVSConfig, kk: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """FUSED scan (the serving default): centroid scan + ONE
    ``chamvs_scan`` dispatch covering ADC + streaming top-kk for every
    shard in the ``stack_shards``-packed stack — no materialized
    [B, n] distance matrix, no per-shard dispatch loop, no separate
    top-k pass. Same return contract as ``_scan_stage``."""
    probe_ids = _probe_stage(params, queries, cfg)
    return fused_shard_scan(params, stacked, queries, probe_ids, cfg, kk)


@functools.partial(jax.jit, static_argnames=("k", "fanout"))
def _merge_stage(dists: jnp.ndarray, ids: jnp.ndarray, *, k: int,
                 fanout: Optional[int]
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return merge_lib.merge_topk(dists, ids, k, fanout=fanout)


class LocalPipeline:
    """Single-process scan/merge over a list of shards.

    ``cfg.fused`` picks the scan flavor: the fused single-dispatch
    ``chamvs_scan`` over a ``stack_shards``-packed stack (default), or
    the staged per-shard loop (the parity oracle). The packed stack is
    a second copy of the code tables — it IS the fused path's physical
    layout (one contiguous [S, ...] allocation the single dispatch
    scans), priced once per service; ``chamvs.search_single`` memoizes
    its service so one-shot callers don't re-pack per call. Deployments
    that cannot afford the copy run ``fused=False``.
    """

    row_multiple = 1    # no constraint on the batched row count

    def __init__(self, params: IVFPQParams, shards: List[IVFPQShard],
                 cfg: ChamVSConfig):
        self.params = params
        self.shards = tuple(shards)
        self.stacked = stack_shards(list(shards)) if cfg.fused else None
        self.cfg = cfg
        self.kk = cfg.k_prime(len(self.shards))

    @property
    def k(self) -> int:
        return self.cfg.k

    @property
    def scan_dispatches(self) -> int:
        """ChamVS scan kernel dispatches per flush: ONE for the fused
        path regardless of shard count, one per shard when staged."""
        return 1 if self.cfg.fused else max(1, len(self.shards))

    def scan(self, queries: jnp.ndarray):
        if self.cfg.fused:
            return _scan_stage_fused(self.params, self.stacked, queries,
                                     cfg=self.cfg, kk=self.kk)
        return _scan_stage(self.params, self.shards, queries,
                           cfg=self.cfg, kk=self.kk)

    def merge(self, candidates, fanout: Optional[int]):
        d, i = candidates
        return _merge_stage(d, i, k=self.cfg.k, fanout=fanout)


class RouterPipeline:
    """Scan/merge over a retrieval mesh via a ``ShardRouter``. The merge
    happens in-network inside the shard_map graph, so the merge stage is
    a pass-through (its time is accounted under scan and
    ``ServiceConfig.merge_fanout`` does not apply)."""

    scan_dispatches = 1   # the whole in-graph search is one dispatch

    def __init__(self, router, params: IVFPQParams,
                 shards: List[IVFPQShard]):
        self.router = router
        self.cfg = router.cfg
        # flushed batches must divide over the mesh's query-split columns
        self.row_multiple = router.query_size
        self.db_params = router.place_params(params)
        self.db_shard = router.place_shards(shards)

    @property
    def k(self) -> int:
        return self.cfg.k

    def scan(self, queries: jnp.ndarray):
        return self.router.search(self.db_params, self.db_shard, queries)

    def merge(self, candidates, fanout: Optional[int]):
        return candidates


# ---------------------------------------------------------------------------
# futures + the service
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _InFlight:
    """One row-range of the in-flight request table."""
    ticket: int
    nrows: int
    submit_t: float
    result_d: Optional[jnp.ndarray] = None   # [nrows, K] once complete
    result_i: Optional[jnp.ndarray] = None
    kernel_rows: int = -1                    # rows the kernel must serve
    #                                          (< nrows on a partial
    #                                          cache hit); -1 = nrows
    stitch: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
    #                                          (dists, ids, hit mask) of
    #                                          the cached rows to merge
    #                                          with the kernel rows


class SearchHandle:
    """Future for one submitted query batch.

    ``result()`` forces a flush if the batch is still queued, so a
    handle can always be resolved — the scheduler simply resolves late
    (after dispatching the next wave's decodes) to get overlap."""

    def __init__(self, service: "RetrievalService", entry: _InFlight):
        self._service = service
        self._entry = entry

    @property
    def ticket(self) -> int:
        return self._entry.ticket

    def done(self) -> bool:
        return self._entry.result_d is not None

    def result(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if not self.done():
            self._service.flush()
        assert self._entry.result_d is not None
        self._service._retire(self._entry)
        return self._entry.result_d, self._entry.result_i

    def cancel(self) -> None:
        """Drop the handle without consuming its result (speculation
        points discarded by a rollback or a cancelled request). A still-
        pending batch is computed and thrown away at the next flush —
        abandoned results must not wedge the in-flight table."""
        self._service._retire(self._entry)


class RetrievalService:
    """Deadline-batched, cached, instrumented front door to ChamVS."""

    def __init__(self, pipeline, config: Optional[ServiceConfig] = None):
        self.pipeline = pipeline
        self.config = config or ServiceConfig()
        self.stats = RetrievalStats()
        self.tracer = NULL_TRACER   # engine.set_tracer swaps a live one in
        self.cache: Optional[QueryCache] = (
            QueryCache(self.config.cache_entries,
                       quant=self.config.cache_quant,
                       partial=self.config.cache_partial)
            if self.config.cache_entries > 0 else None)
        self._inflight: Dict[int, _InFlight] = {}
        self._pending: List[Tuple[_InFlight, jnp.ndarray]] = []
        self._pending_rows = 0
        self._next_ticket = 0

    # -- constructors -------------------------------------------------------

    @classmethod
    def local(cls, params: IVFPQParams, shards: List[IVFPQShard],
              cfg: ChamVSConfig, config: Optional[ServiceConfig] = None
              ) -> "RetrievalService":
        """Single-process service (tests, builds, monolithic serving).
        ``ServiceConfig.kernel_backend`` / ``kernel_interpret`` override
        the corresponding ``ChamVSConfig`` fields, so a deployment config
        can select the Pallas scan path without rebuilding the search
        config by hand."""
        if config is not None:
            cfg = cfg.with_kernel(config.kernel_backend,
                                  config.kernel_interpret,
                                  config.kernel_fused)
        return cls(LocalPipeline(params, shards, cfg), config=config)

    @classmethod
    def distributed(cls, router, params: IVFPQParams,
                    shards: List[IVFPQShard],
                    config: Optional[ServiceConfig] = None
                    ) -> "RetrievalService":
        """Service over a retrieval mesh (one memory node per device).
        The kernel config is baked into the router at construction, so
        ``ServiceConfig`` kernel overrides cannot apply here — reject
        them loudly rather than silently serving ref-scan numbers."""
        if config is not None and (config.kernel_backend is not None or
                                   config.kernel_interpret is not None or
                                   config.kernel_fused is not None):
            raise ValueError(
                "ServiceConfig.kernel_backend/kernel_interpret/"
                "kernel_fused cannot override a distributed pipeline — "
                "the ShardRouter owns its ChamVSConfig; build the router "
                "with cfg.with_kernel(...) instead")
        return cls(RouterPipeline(router, params, shards), config=config)

    # -- the in-flight request table ---------------------------------------

    @property
    def num_inflight(self) -> int:
        return len(self._inflight)

    @property
    def num_pending_rows(self) -> int:
        return self._pending_rows

    def _retire(self, entry: _InFlight) -> None:
        self._inflight.pop(entry.ticket, None)

    # -- submission ---------------------------------------------------------

    def submit(self, queries: jnp.ndarray) -> SearchHandle:
        """Enqueue a [B, d] query batch; returns a future.

        A full-batch cache hit completes the handle immediately (no
        kernel). Otherwise the rows join the pending micro-batch, which
        flushes on ``max_batch`` / ``deadline_s`` / ``flush()``."""
        q = jnp.asarray(queries, jnp.float32)
        if q.ndim != 2:
            raise ValueError(f"queries must be [B, d], got {q.shape}")
        now = time.perf_counter()
        entry = _InFlight(ticket=self._next_ticket, nrows=q.shape[0],
                          submit_t=now)
        self._next_ticket += 1
        self._inflight[entry.ticket] = entry
        self.stats.record_submit(entry.nrows)

        q_kernel = q
        if self.cache is not None:
            stale0 = self.cache.stale
            hit = self.cache.get_batch(np.asarray(q))
            self.stats.cache_stale += self.cache.stale - stale0
            if hit is not None and len(hit) == 2:
                # all-or-nothing full hit (either cache mode)
                entry.result_d = jnp.asarray(hit[0])
                entry.result_i = jnp.asarray(hit[1])
                self.stats.cache_hits += entry.nrows
                self.stats.queue_wait.add(0.0)
                return SearchHandle(self, entry)
            if hit is not None:
                # partial per-row hit: serve the cached rows now, send
                # ONLY the missed rows to the kernel; flush stitches
                dists, ids, mask = hit
                nhit = int(mask.sum())
                if nhit == entry.nrows:
                    entry.result_d = jnp.asarray(dists)
                    entry.result_i = jnp.asarray(ids)
                    self.stats.cache_hits += entry.nrows
                    self.stats.queue_wait.add(0.0)
                    return SearchHandle(self, entry)
                entry.stitch = (dists, ids, mask)
                entry.kernel_rows = entry.nrows - nhit
                q_kernel = q[jnp.asarray(np.flatnonzero(~mask))]
                self.stats.cache_hits += nhit
                self.stats.cache_misses += entry.kernel_rows
            else:
                self.stats.cache_misses += entry.nrows
        if entry.kernel_rows < 0:
            entry.kernel_rows = entry.nrows

        self._pending.append((entry, q_kernel))
        self._pending_rows += entry.kernel_rows
        if self._pending_rows >= self.config.max_batch:
            self.flush()
        else:
            self.poll(now)
        return SearchHandle(self, entry)

    def poll(self, now: Optional[float] = None) -> None:
        """Deadline check: flush if the oldest pending row has waited
        longer than ``deadline_s``. Call from any serving loop tick."""
        if not self._pending or self.config.deadline_s <= 0.0:
            return
        now = time.perf_counter() if now is None else now
        if now - self._pending[0][0].submit_t >= self.config.deadline_s:
            self.flush()

    # -- the batched dispatch ----------------------------------------------

    def _bucket(self, n: int) -> int:
        b = next_pow2(n) if self.config.bucket_pow2 else n
        # distributed pipelines query-split over the TP columns, which
        # requires the row count to divide evenly
        mult = getattr(self.pipeline, "row_multiple", 1)
        if b % mult:
            b += mult - b % mult
        return b

    def flush(self) -> None:
        """Coalesce every pending row into one scan+merge dispatch and
        complete the corresponding in-flight entries."""
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        nrows, self._pending_rows = self._pending_rows, 0

        batch = (pending[0][1] if len(pending) == 1
                 else jnp.concatenate([q for _, q in pending], axis=0))
        pad = self._bucket(nrows) - nrows
        if pad:
            batch = jnp.pad(batch, ((0, pad), (0, 0)))

        measure = self.config.measure
        tr = self.tracer
        t0 = time.perf_counter()
        for entry, _ in pending:   # queue wait ends when the batch launches
            self.stats.queue_wait.add(t0 - entry.submit_t)
        if tr.enabled:
            # retroactive span: the wait started when the OLDEST pending
            # row was submitted, which predates this call site
            oldest = pending[0][0].submit_t
            tr.complete("retrieval.queue_wait", "retrieval", oldest,
                        t0 - oldest, args={"rows": nrows,
                                           "entries": len(pending)})
        # NOTE: with measure=False the scan/merge spans time only the
        # async dispatch (jax returns before the kernel finishes); with
        # measure=True the block_until_ready makes them true stage times
        with tr.span("retrieval.scan", "retrieval",
                     args={"rows": nrows} if tr.enabled else None):
            candidates = self.pipeline.scan(batch)
            if measure:
                jax.block_until_ready(candidates)
        t1 = time.perf_counter()
        with tr.span("retrieval.merge", "retrieval"):
            dists, ids = self.pipeline.merge(candidates,
                                             self.config.merge_fanout)
            if measure:
                jax.block_until_ready((dists, ids))
        if measure:
            self.stats.scan.add(t1 - t0)
            self.stats.merge.add(time.perf_counter() - t1)
        self.stats.record_batch(
            nrows, dispatches=getattr(self.pipeline, "scan_dispatches", 1))

        offset = 0
        for entry, q in pending:
            kd = dists[offset:offset + entry.kernel_rows]
            ki = ids[offset:offset + entry.kernel_rows]
            if self.cache is not None:
                self.cache.put_batch(np.asarray(q), np.asarray(kd),
                                     np.asarray(ki))
            if entry.stitch is not None:
                # merge the cached rows with the kernel rows back into
                # submit order (host-side: the cached half already lives
                # on the host, and the cache insert above synced anyway)
                cd, ci, mask = entry.stitch
                full_d = np.array(cd)
                full_i = np.array(ci)
                miss = np.flatnonzero(~mask)
                full_d[miss] = np.asarray(kd)
                full_i[miss] = np.asarray(ki)
                entry.result_d = jnp.asarray(full_d)
                entry.result_i = jnp.asarray(full_i)
            else:
                entry.result_d, entry.result_i = kd, ki
            offset += entry.kernel_rows

    # -- speculation support ------------------------------------------------

    def stale_lookup(self, queries: jnp.ndarray
                     ) -> Optional[Tuple[jnp.ndarray, jnp.ndarray]]:
        """Any-generation cache lookup feeding speculative decode: the
        caller continues on these possibly-stale neighbors while the
        real search runs, so freshness is a quality hint, not a
        correctness requirement. None when any row is absent (or the
        cache is off)."""
        if self.cache is None:
            return None
        hit = self.cache.get_stale(np.asarray(queries, np.float32))
        if hit is None:
            return None
        return jnp.asarray(hit[0]), jnp.asarray(hit[1])

    def mark_cache_stale(self) -> None:
        """Generation-bump the result cache (quality knob changed):
        entries stop serving fresh lookups but remain speculation
        seeds. No-op without a cache."""
        if self.cache is not None:
            self.cache.mark_stale()

    # -- synchronous convenience -------------------------------------------

    def search(self, queries: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Blocking search: submit + flush + result (the legacy
        ``chamvs.search_single`` surface)."""
        return self.submit(queries).result()
