"""``RetrievalService`` — ChamVS as a standalone vector-search service.

The paper's disaggregation argument (§3) is that vector search deserves
its own service tier, scaled and scheduled independently of the LM.
This module is that tier in-process:

  * an **in-flight request table**: every ``submit()`` gets a ticket and
    a ``SearchHandle`` future, so callers (the serve scheduler) issue
    queries for one wave of sequences while the previous wave is still
    decoding;
  * **deadline-based micro-batching**: pending queries from many
    concurrent sequences coalesce into *one* batched IVF-scan/PQ-ADC/
    top-k dispatch, flushed when ``max_batch`` rows accumulate, when the
    oldest query's ``deadline_s`` expires, or explicitly at the end of a
    scheduler wave (RAGO, arXiv:2503.14649, shows this cross-request
    batching dominates RAG serving throughput);
  * an **LRU result cache** on quantized query vectors — a hit skips
    the kernel entirely;
  * **per-stage stats** (queue wait / scan / merge / gather) feeding the
    Fig. 9/10-style benchmark.

The search math itself lives in ``core/chamvs.py`` (kernel frontend)
and ``retrieval/merge.py`` (K-selection); this module only batches,
caches, and accounts. ``chamvs.search_single`` is a one-shot call into
this service, so there is exactly one search implementation.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ivfpq
from repro.core.chamvs import ChamVSConfig, shard_search, stack_shards
from repro.obs.trace import NULL_TRACER
from repro.core.ivfpq import IVFPQParams, IVFPQShard
from repro.kernels.chamvs_scan.ops import fused_shard_scan
from repro.kernels.ivf_scan.ops import ivf_index_scan
from repro.retrieval import merge as merge_lib
from repro.retrieval.cache import QueryCache
from repro.retrieval.chaos import ChaosInjector, FaultPlan, ScanHang
from repro.retrieval.replica import (EJECTED, HEALTHY, PROBATION,
                                     FailoverConfig, ReplicaGroup)
from repro.retrieval.stats import RetrievalStats


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Batching / caching knobs of one service instance."""
    max_batch: int = 64           # flush when this many rows are pending
    deadline_s: float = 0.0       # flush when the oldest row waited this
    #                               long (checked at submit/poll; 0 = only
    #                               max_batch or an explicit flush())
    bucket_pow2: bool = True      # pad batches to powers of two so jit
    #                               retraces O(log max_batch) shapes
    cache_entries: int = 0        # LRU result-cache entries (0 = off).
    #                               NOTE: the cache keys on host-side
    #                               query values, so enabling it syncs
    #                               each submit (and each flush, for the
    #                               insert) — it trades async overlap for
    #                               skipping whole kernel dispatches
    cache_quant: float = 1e-3     # query quantization step for cache keys
    cache_partial: bool = True    # per-row cache hits: cached rows are
    #                               served immediately and ONLY the
    #                               missed rows go to the kernel (the
    #                               flush stitches the batch back
    #                               together). False restores the old
    #                               all-or-nothing batch lookup.
    merge_fanout: Optional[int] = None  # None = flat K-selection;
    #                               >= 2 = hierarchical tree merge
    measure: bool = True          # block per stage to record scan/merge
    #                               times (off = maximum async overlap)
    kernel_backend: Optional[str] = None  # override ChamVSConfig.backend
    #                               ("ref" | "pallas") so serving configs
    #                               can select the Pallas scan path
    kernel_interpret: Optional[bool] = None  # override ChamVSConfig.
    #                               interpret (Pallas interpret mode)
    kernel_fused: Optional[bool] = None  # override ChamVSConfig.fused:
    #                               one fused chamvs_scan dispatch per
    #                               wave (True) vs the staged per-shard
    #                               pipeline (False, the parity oracle)
    failover: Optional[FailoverConfig] = None  # fault-tolerant dispatch:
    #                               replica groups + per-dispatch
    #                               deadlines + hedged re-dispatch +
    #                               partial results (repro.retrieval.
    #                               replica). None = the legacy direct
    #                               dispatch, bit-identical to before.
    #                               NOTE: deadline enforcement needs the
    #                               scan's real latency, so the FT layer
    #                               blocks per flush like measure=True


def next_pow2(n: int) -> int:
    """Smallest power of two >= n — the shape-bucketing unit shared by
    the query micro-batcher here and the serve KV pool's wave buckets."""
    b = 1
    while b < n:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# the pipeline stages, jitted once at module level (shared across
# service instances and the `search_single` one-shot path)
# ---------------------------------------------------------------------------

def _probe_stage(params: IVFPQParams, queries: jnp.ndarray,
                 cfg: ChamVSConfig) -> jnp.ndarray:
    """ChamVS.idx: pick the nprobe closest IVF lists per query. Shared
    by the fused and staged paths (parity requires identical probes),
    routed through the registry frontend when the config asks for the
    Pallas centroid scan."""
    spec = cfg.kernel_spec()
    if spec.backend == "pallas":
        _, probe_ids = ivf_index_scan(queries, params.coarse_centroids,
                                      cfg.nprobe, spec=spec)
    else:
        _, probe_ids = ivfpq.scan_ivf_index(params, queries, cfg.nprobe)
    return probe_ids


@functools.partial(jax.jit, static_argnames=("cfg", "kk"))
def _scan_stage(params: IVFPQParams, shards: Tuple[IVFPQShard, ...],
                queries: jnp.ndarray, *, cfg: ChamVSConfig, kk: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """STAGED scan: centroid scan + Python loop of per-shard IVF/PQ
    scans + per-shard top-kk — one chamvs dispatch per shard. Kept as
    the parity oracle for ``_scan_stage_fused``.

    Returns stacked candidates (dists [S, nq, kk], ids [S, nq, kk])."""
    probe_ids = _probe_stage(params, queries, cfg)
    per = [shard_search(params, s, queries, probe_ids, cfg, kk)
           for s in shards]
    return (jnp.stack([p[0] for p in per]),
            jnp.stack([p[1] for p in per]))


@functools.partial(jax.jit, static_argnames=("cfg", "kk"))
def _scan_stage_fused(params: IVFPQParams, stacked: IVFPQShard,
                      queries: jnp.ndarray, *, cfg: ChamVSConfig, kk: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """FUSED scan (the serving default): centroid scan + ONE
    ``chamvs_scan`` dispatch covering ADC + streaming top-kk for every
    shard in the ``stack_shards``-packed stack — no materialized
    [B, n] distance matrix, no per-shard dispatch loop, no separate
    top-k pass. Same return contract as ``_scan_stage``."""
    probe_ids = _probe_stage(params, queries, cfg)
    return fused_shard_scan(params, stacked, queries, probe_ids, cfg, kk)


@functools.partial(jax.jit, static_argnames=("k", "fanout"))
def _merge_stage(dists: jnp.ndarray, ids: jnp.ndarray, *, k: int,
                 fanout: Optional[int]
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return merge_lib.merge_topk(dists, ids, k, fanout=fanout)


class LocalPipeline:
    """Single-process scan/merge over a list of shards.

    ``cfg.fused`` picks the scan flavor: the fused single-dispatch
    ``chamvs_scan`` over a ``stack_shards``-packed stack (default), or
    the staged per-shard loop (the parity oracle). The packed stack is
    a second copy of the code tables — it IS the fused path's physical
    layout (one contiguous [S, ...] allocation the single dispatch
    scans), priced once per service; ``chamvs.search_single`` memoizes
    its service so one-shot callers don't re-pack per call. Deployments
    that cannot afford the copy run ``fused=False``.
    """

    row_multiple = 1    # no constraint on the batched row count

    def __init__(self, params: IVFPQParams, shards: List[IVFPQShard],
                 cfg: ChamVSConfig):
        self.params = params
        self.shards = tuple(shards)
        self.stacked = stack_shards(list(shards)) if cfg.fused else None
        self.cfg = cfg
        self.kk = cfg.k_prime(len(self.shards))

    @property
    def k(self) -> int:
        return self.cfg.k

    @property
    def scan_dispatches(self) -> int:
        """ChamVS scan kernel dispatches per flush: ONE for the fused
        path regardless of shard count, one per shard when staged."""
        return 1 if self.cfg.fused else max(1, len(self.shards))

    @property
    def fault_domains(self) -> int:
        """Independent failure domains of this pipeline: each shard can
        fail on its own (candidates stay per-shard until the merge)."""
        return max(1, len(self.shards))

    def scan(self, queries: jnp.ndarray):
        if self.cfg.fused:
            return _scan_stage_fused(self.params, self.stacked, queries,
                                     cfg=self.cfg, kk=self.kk)
        return _scan_stage(self.params, self.shards, queries,
                           cfg=self.cfg, kk=self.kk)

    def merge(self, candidates, fanout: Optional[int]):
        d, i = candidates
        return _merge_stage(d, i, k=self.cfg.k, fanout=fanout)


class RouterPipeline:
    """Scan/merge over a retrieval mesh via a ``ShardRouter``. The merge
    happens in-network inside the shard_map graph, so the merge stage is
    a pass-through (its time is accounted under scan and
    ``ServiceConfig.merge_fanout`` does not apply)."""

    scan_dispatches = 1   # the whole in-graph search is one dispatch
    fault_domains = 1     # the in-graph search merges in-network, so
    #                       the whole mesh fails (or answers) as one
    #                       domain — partial results degrade to
    #                       total loss here

    def __init__(self, router, params: IVFPQParams,
                 shards: List[IVFPQShard]):
        self.router = router
        self.cfg = router.cfg
        # flushed batches must divide over the mesh's query-split columns
        self.row_multiple = router.query_size
        self.db_params = router.place_params(params)
        self.db_shard = router.place_shards(shards)

    @property
    def k(self) -> int:
        return self.cfg.k

    def scan(self, queries: jnp.ndarray):
        return self.router.search(self.db_params, self.db_shard, queries)

    def merge(self, candidates, fanout: Optional[int]):
        return candidates


# ---------------------------------------------------------------------------
# futures + the service
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _InFlight:
    """One row-range of the in-flight request table."""
    ticket: int
    nrows: int
    submit_t: float
    result_d: Optional[jnp.ndarray] = None   # [nrows, K] once complete
    result_i: Optional[jnp.ndarray] = None
    kernel_rows: int = -1                    # rows the kernel must serve
    #                                          (< nrows on a partial
    #                                          cache hit); -1 = nrows
    stitch: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
    #                                          (dists, ids, hit mask) of
    #                                          the cached rows to merge
    #                                          with the kernel rows
    partial: bool = False                    # served from a live subset
    #                                          of the fault domains (a
    #                                          shard was down past the
    #                                          deadline): exact top-k
    #                                          over the survivors only
    live_frac: float = 1.0                   # fraction of fault domains
    #                                          that contributed


class SearchHandle:
    """Future for one submitted query batch.

    ``result()`` forces a flush if the batch is still queued, so a
    handle can always be resolved — the scheduler simply resolves late
    (after dispatching the next wave's decodes) to get overlap."""

    def __init__(self, service: "RetrievalService", entry: _InFlight):
        self._service = service
        self._entry = entry

    @property
    def ticket(self) -> int:
        return self._entry.ticket

    @property
    def partial(self) -> bool:
        """True when the result covers only the surviving fault domains
        (exact top-k over the live subset — see ``_dispatch_scan``).
        Meaningful once ``done()``; consumers use it to count quality
        impact and to skip seeding speculation with degraded results."""
        return self._entry.partial

    @property
    def live_fraction(self) -> float:
        return self._entry.live_frac

    def done(self) -> bool:
        return self._entry.result_d is not None

    def result(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if not self.done():
            self._service.flush()
        assert self._entry.result_d is not None
        self._service._retire(self._entry)
        return self._entry.result_d, self._entry.result_i

    def cancel(self) -> None:
        """Drop the handle without consuming its result (speculation
        points discarded by a rollback or a cancelled request). A still-
        pending batch is computed and thrown away at the next flush —
        abandoned results must not wedge the in-flight table."""
        self._service._retire(self._entry)


class RetrievalService:
    """Deadline-batched, cached, instrumented front door to ChamVS."""

    def __init__(self, pipeline, config: Optional[ServiceConfig] = None):
        self.pipeline = pipeline
        self.config = config or ServiceConfig()
        self.stats = RetrievalStats()
        self.tracer = NULL_TRACER   # engine.set_tracer swaps a live one in
        self.cache: Optional[QueryCache] = (
            QueryCache(self.config.cache_entries,
                       quant=self.config.cache_quant,
                       partial=self.config.cache_partial)
            if self.config.cache_entries > 0 else None)
        self._inflight: Dict[int, _InFlight] = {}
        self._pending: List[Tuple[_InFlight, jnp.ndarray]] = []
        self._pending_rows = 0
        self._next_ticket = 0
        # -- fault tolerance (replica failover / deadlines / chaos) ----
        self.replicas: Optional[ReplicaGroup] = None
        self.chaos: Optional[ChaosInjector] = None
        self._degraded_partial = False    # degrade-ladder rung: serve
        #                                   the live subset immediately,
        #                                   no hedging or retries
        if self.config.failover is not None:
            self.replicas = ReplicaGroup(
                getattr(pipeline, "fault_domains", 1),
                self.config.failover,
                on_transition=self._on_replica_transition)

    # -- fault tolerance ----------------------------------------------------

    def _on_replica_transition(self, shard: int, replica: int,
                               old: str, new: str) -> None:
        if new == EJECTED:
            self.stats.ft_ejections += 1
            if self.tracer.enabled:
                self.tracer.instant("retrieval.eject", "retrieval",
                                    args={"shard": shard,
                                          "replica": replica, "from": old})
        elif old == PROBATION and new == HEALTHY:
            self.stats.ft_recoveries += 1
            if self.tracer.enabled:
                self.tracer.instant("retrieval.recover", "retrieval",
                                    args={"shard": shard,
                                          "replica": replica})

    def install_chaos(self, plan) -> ChaosInjector:
        """Arm a ``FaultPlan`` (or a path to its JSON) at this service's
        scan boundary. Chaos requires the fault-tolerant dispatch loop,
        so a replica group is created on demand (single-replica: every
        fault beyond retries degrades to partial results)."""
        if isinstance(plan, str):
            plan = FaultPlan.load(plan)
        if isinstance(plan, FaultPlan):
            injector = ChaosInjector(plan)
        else:
            injector = plan
        if self.replicas is None:
            self.replicas = ReplicaGroup(
                getattr(self.pipeline, "fault_domains", 1),
                FailoverConfig(replicas=1),
                on_transition=self._on_replica_transition)
        self.chaos = injector
        return injector

    def set_degraded_partial(self, flag: bool) -> None:
        """Degrade-ladder hook ("partial-retrieval" rung): when set, the
        dispatch loop gives every domain ONE attempt and serves whatever
        subset answered — shedding hedges, retries, and tail waits. A
        no-op unless the fault-tolerant layer is active."""
        self._degraded_partial = bool(flag)

    # -- constructors -------------------------------------------------------

    @classmethod
    def local(cls, params: IVFPQParams, shards: List[IVFPQShard],
              cfg: ChamVSConfig, config: Optional[ServiceConfig] = None
              ) -> "RetrievalService":
        """Single-process service (tests, builds, monolithic serving).
        ``ServiceConfig.kernel_backend`` / ``kernel_interpret`` override
        the corresponding ``ChamVSConfig`` fields, so a deployment config
        can select the Pallas scan path without rebuilding the search
        config by hand."""
        if config is not None:
            cfg = cfg.with_kernel(config.kernel_backend,
                                  config.kernel_interpret,
                                  config.kernel_fused)
        return cls(LocalPipeline(params, shards, cfg), config=config)

    @classmethod
    def distributed(cls, router, params: IVFPQParams,
                    shards: List[IVFPQShard],
                    config: Optional[ServiceConfig] = None
                    ) -> "RetrievalService":
        """Service over a retrieval mesh (one memory node per device).
        The kernel config is baked into the router at construction, so
        ``ServiceConfig`` kernel overrides cannot apply here — reject
        them loudly rather than silently serving ref-scan numbers."""
        if config is not None and (config.kernel_backend is not None or
                                   config.kernel_interpret is not None or
                                   config.kernel_fused is not None):
            raise ValueError(
                "ServiceConfig.kernel_backend/kernel_interpret/"
                "kernel_fused cannot override a distributed pipeline — "
                "the ShardRouter owns its ChamVSConfig; build the router "
                "with cfg.with_kernel(...) instead")
        return cls(RouterPipeline(router, params, shards), config=config)

    # -- the in-flight request table ---------------------------------------

    @property
    def num_inflight(self) -> int:
        return len(self._inflight)

    @property
    def num_pending_rows(self) -> int:
        return self._pending_rows

    def _retire(self, entry: _InFlight) -> None:
        self._inflight.pop(entry.ticket, None)

    # -- submission ---------------------------------------------------------

    def submit(self, queries: jnp.ndarray) -> SearchHandle:
        """Enqueue a [B, d] query batch; returns a future.

        A full-batch cache hit completes the handle immediately (no
        kernel). Otherwise the rows join the pending micro-batch, which
        flushes on ``max_batch`` / ``deadline_s`` / ``flush()``."""
        q = jnp.asarray(queries, jnp.float32)
        if q.ndim != 2:
            raise ValueError(f"queries must be [B, d], got {q.shape}")
        now = time.perf_counter()
        entry = _InFlight(ticket=self._next_ticket, nrows=q.shape[0],
                          submit_t=now)
        self._next_ticket += 1
        self._inflight[entry.ticket] = entry
        self.stats.record_submit(entry.nrows)

        q_kernel = q
        if self.cache is not None:
            stale0 = self.cache.stale
            hit = self.cache.get_batch(np.asarray(q))
            self.stats.cache_stale += self.cache.stale - stale0
            if hit is not None and len(hit) == 2:
                # all-or-nothing full hit (either cache mode)
                entry.result_d = jnp.asarray(hit[0])
                entry.result_i = jnp.asarray(hit[1])
                self.stats.cache_hits += entry.nrows
                self.stats.queue_wait.add(0.0)
                return SearchHandle(self, entry)
            if hit is not None:
                # partial per-row hit: serve the cached rows now, send
                # ONLY the missed rows to the kernel; flush stitches
                dists, ids, mask = hit
                nhit = int(mask.sum())
                if nhit == entry.nrows:
                    entry.result_d = jnp.asarray(dists)
                    entry.result_i = jnp.asarray(ids)
                    self.stats.cache_hits += entry.nrows
                    self.stats.queue_wait.add(0.0)
                    return SearchHandle(self, entry)
                entry.stitch = (dists, ids, mask)
                entry.kernel_rows = entry.nrows - nhit
                q_kernel = q[jnp.asarray(np.flatnonzero(~mask))]
                self.stats.cache_hits += nhit
                self.stats.cache_misses += entry.kernel_rows
            else:
                self.stats.cache_misses += entry.nrows
        if entry.kernel_rows < 0:
            entry.kernel_rows = entry.nrows

        self._pending.append((entry, q_kernel))
        self._pending_rows += entry.kernel_rows
        if self._pending_rows >= self.config.max_batch:
            self.flush()
        else:
            self.poll(now)
        return SearchHandle(self, entry)

    def poll(self, now: Optional[float] = None) -> None:
        """Deadline check: flush if the oldest pending row has waited
        longer than ``deadline_s``. Call from any serving loop tick."""
        if not self._pending or self.config.deadline_s <= 0.0:
            return
        now = time.perf_counter() if now is None else now
        if now - self._pending[0][0].submit_t >= self.config.deadline_s:
            self.flush()

    # -- the batched dispatch ----------------------------------------------

    def _bucket(self, n: int) -> int:
        b = next_pow2(n) if self.config.bucket_pow2 else n
        # distributed pipelines query-split over the TP columns, which
        # requires the row count to divide evenly
        mult = getattr(self.pipeline, "row_multiple", 1)
        if b % mult:
            b += mult - b % mult
        return b

    def _dispatch_scan(self, batch: jnp.ndarray
                       ) -> Tuple[Optional[Tuple[jnp.ndarray, jnp.ndarray]],
                                  Optional[np.ndarray]]:
        """Fault-tolerant scan dispatch. Returns ``(candidates, live)``:
        ``live`` is ``None`` when the FT layer is inactive (the legacy
        direct dispatch, bit-identical to before), else a bool [S] over
        the pipeline's fault domains — False domains get masked to the
        padding sentinel before the merge (partial results).

        The loop is a synchronous, deterministic model of hedged
        dispatch: per round, every unresolved domain is assigned a
        replica via the health-aware ``ReplicaGroup.pick``; the chaos
        injector (if armed) decides the replica's fate. A hang costs the
        quantile-based hedge delay, then re-dispatches to the next
        replica (a *hedge*); a transient error retries with backoff up
        to ``max_retries`` before failing over; a crash fails over
        immediately and ejects. In-process all replicas answer from the
        same arrays, so the physical scan runs ONCE and a failover
        re-serves bit-identical candidates — the control plane (who is
        asked, when we give up, what latency is accounted and, under
        ``FaultPlan.realtime``, slept) is what is modeled. Domains
        still unresolved when the deadline is spent, or with every
        replica ejected, are reported dead in ``live``."""
        group = self.replicas
        if group is None:
            return self.pipeline.scan(batch), None
        cfg = group.cfg
        clock = group.clock
        realtime = self.chaos is not None and self.chaos.plan.realtime
        S = group.num_shards
        flush_idx = self.stats.num_batches
        stats = self.stats
        tr = self.tracer
        live = np.zeros(S, dtype=bool)
        candidates = None
        scan_s = 0.0
        spent = 0.0                     # modeled elapsed across rounds
        pending = set(range(S))
        tried: List[set] = [set() for _ in range(S)]
        retries = [0] * S
        attempts = [0] * S
        t_wall = clock()
        # bounded by construction, belt-and-braces against plan bugs
        guard = S * cfg.replicas * (cfg.max_retries + 2) + 4
        while pending and guard > 0:
            guard -= 1
            assign = [(s, group.pick(s, exclude=tried[s]))
                      for s in sorted(pending)]
            assign = [(s, r) for s, r in assign if r is not None]
            for s in pending - {s for s, _ in assign}:
                tried[s] = set(range(cfg.replicas))   # no target: dead
            pending = {s for s, _ in assign}
            if not assign:
                break
            if candidates is None:
                t0 = clock()
                candidates = self.pipeline.scan(batch)
                jax.block_until_ready(candidates)
                scan_s = clock() - t0
            hedge = group.hedge_delay_s()
            round_cost = 0.0
            for s, rid in assign:
                attempts[s] += 1
                fault = (self.chaos.outcome(flush_idx, s, rid,
                                            attempts[s])
                         if self.chaos is not None else None)
                kind = fault.kind if fault is not None else None
                if kind is None or kind == "slow":
                    lat = scan_s + (fault.slow_s if fault else 0.0)
                    if realtime and fault is not None:
                        group.sleep(min(fault.slow_s, cfg.sleep_cap_s))
                    late = (cfg.dispatch_deadline_s > 0.0 and
                            spent + lat > cfg.dispatch_deadline_s)
                    group.report(s, rid, "slow" if late else "ok",
                                 latency_s=lat)
                    if late:
                        stats.ft_timeouts += 1   # late success: result
                        #                          used, replica charged
                    live[s] = True
                    pending.discard(s)
                elif kind == "hang":
                    lat = hedge
                    stats.ft_timeouts += 1
                    stats.ft_hedges += 1
                    group.report(s, rid, "timeout")
                    tried[s].add(rid)
                    if tr.enabled:
                        tr.instant("retrieval.hedge", "retrieval",
                                   args={"shard": s, "replica": rid,
                                         "delay_us": hedge * 1e6})
                    if realtime:
                        group.sleep(min(hedge, cfg.sleep_cap_s))
                elif kind == "error":
                    lat = cfg.backoff_s * (2 ** retries[s])
                    stats.ft_retries += 1
                    group.report(s, rid, "error")
                    retries[s] += 1
                    if retries[s] > cfg.max_retries:
                        tried[s].add(rid)
                        retries[s] = 0
                    if realtime and lat > 0:
                        group.sleep(min(lat, cfg.sleep_cap_s))
                else:  # crash: fail fast, eject, fail over
                    lat = 0.0
                    stats.ft_crashes += 1
                    group.report(s, rid, "crash")
                    tried[s].add(rid)
                round_cost = max(round_cost, lat)
            spent += round_cost
            if self._degraded_partial:
                break   # partial-retrieval rung: one attempt per domain
            if cfg.dispatch_deadline_s > 0.0 and \
                    spent >= cfg.dispatch_deadline_s:
                break   # deadline spent: survivors only
        stats.ft_dispatch.add(clock() - t_wall)
        if not live.all() and not cfg.allow_partial:
            dead = [int(s) for s in np.flatnonzero(~live)]
            raise ScanHang(
                f"fault domains {dead} unresolved past the deadline and "
                "ServiceConfig.failover.allow_partial is False")
        return candidates, live

    def _fail_pending(self, pending: List[Tuple[_InFlight, jnp.ndarray]]
                      ) -> None:
        """A flush that raises must still complete its entries: fill the
        missing-neighbor sentinel (``knnlm_interpolate`` degrades to the
        bare LM distribution on it) and flag them partial, so handles
        stay resolvable and the in-flight table cannot wedge — callers
        that swallow the exception still drain cleanly."""
        k = self.pipeline.k
        for entry, _ in pending:
            if entry.result_d is None:
                entry.result_d = jnp.full((entry.nrows, k), jnp.inf,
                                          jnp.float32)
                entry.result_i = jnp.full((entry.nrows, k), -1, jnp.int32)
                entry.partial = True
                entry.live_frac = 0.0

    def flush(self) -> None:
        """Coalesce every pending row into one scan+merge dispatch and
        complete the corresponding in-flight entries."""
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        nrows, self._pending_rows = self._pending_rows, 0
        try:
            self._flush_batch(pending, nrows)
        except Exception:
            self._fail_pending(pending)
            raise

    def _flush_batch(self, pending: List[Tuple[_InFlight, jnp.ndarray]],
                     nrows: int) -> None:

        batch = (pending[0][1] if len(pending) == 1
                 else jnp.concatenate([q for _, q in pending], axis=0))
        pad = self._bucket(nrows) - nrows
        if pad:
            batch = jnp.pad(batch, ((0, pad), (0, 0)))

        measure = self.config.measure
        tr = self.tracer
        t0 = time.perf_counter()
        for entry, _ in pending:   # queue wait ends when the batch launches
            self.stats.queue_wait.add(t0 - entry.submit_t)
        if tr.enabled:
            # retroactive span: the wait started when the OLDEST pending
            # row was submitted, which predates this call site
            oldest = pending[0][0].submit_t
            tr.complete("retrieval.queue_wait", "retrieval", oldest,
                        t0 - oldest, args={"rows": nrows,
                                           "entries": len(pending)})
        # NOTE: with measure=False the scan/merge spans time only the
        # async dispatch (jax returns before the kernel finishes); with
        # measure=True the block_until_ready makes them true stage times
        # (the fault-tolerant dispatch always blocks: deadline/hedge
        # decisions need the scan's real latency)
        with tr.span("retrieval.scan", "retrieval",
                     args={"rows": nrows} if tr.enabled else None):
            candidates, live = self._dispatch_scan(batch)
            if measure and candidates is not None:
                jax.block_until_ready(candidates)
        t1 = time.perf_counter()
        partial = live is not None and not bool(live.all())
        live_frac = float(live.mean()) if live is not None else 1.0
        with tr.span("retrieval.merge", "retrieval"):
            if not partial:
                dists, ids = self.pipeline.merge(candidates,
                                                 self.config.merge_fanout)
            elif candidates is not None and bool(live.any()) and \
                    candidates[0].ndim == 3 and \
                    candidates[0].shape[0] == live.shape[0]:
                # per-shard candidate lists: mask the dead producers to
                # the (+inf, -1) padding sentinel, then the ordinary
                # K-selection IS the exact top-k over the live subset
                md, mi = merge_lib.mask_producers(
                    candidates[0], candidates[1], jnp.asarray(live))
                dists, ids = self.pipeline.merge(
                    (md, mi), self.config.merge_fanout)
            else:
                # total loss (or an in-graph-merged pipeline whose one
                # domain died): every row gets the missing-neighbor
                # sentinel; knnlm_interpolate degrades to the bare LM
                # distribution on it, so requests complete un-augmented
                n, k = batch.shape[0], self.pipeline.k
                dists = jnp.full((n, k), jnp.inf, jnp.float32)
                ids = jnp.full((n, k), -1, jnp.int32)
            if measure:
                jax.block_until_ready((dists, ids))
        if measure:
            self.stats.scan.add(t1 - t0)
            self.stats.merge.add(time.perf_counter() - t1)
        self.stats.record_batch(
            nrows, dispatches=getattr(self.pipeline, "scan_dispatches", 1))
        if partial:
            self.stats.ft_partial_flushes += 1
            self.stats.ft_partial_rows += nrows
            if tr.enabled:
                tr.instant("retrieval.partial", "retrieval",
                           args={"rows": nrows,
                                 "live": int(live.sum()),
                                 "domains": int(live.shape[0])})

        offset = 0
        for entry, q in pending:
            entry.partial = partial
            entry.live_frac = live_frac
            kd = dists[offset:offset + entry.kernel_rows]
            ki = ids[offset:offset + entry.kernel_rows]
            if self.cache is not None and not partial:
                # partial results never enter the cache: they would
                # outlive the fault and silently serve degraded
                # neighbors at full-quality lookups
                self.cache.put_batch(np.asarray(q), np.asarray(kd),
                                     np.asarray(ki))
            if entry.stitch is not None:
                # merge the cached rows with the kernel rows back into
                # submit order (host-side: the cached half already lives
                # on the host, and the cache insert above synced anyway)
                cd, ci, mask = entry.stitch
                full_d = np.array(cd)
                full_i = np.array(ci)
                miss = np.flatnonzero(~mask)
                full_d[miss] = np.asarray(kd)
                full_i[miss] = np.asarray(ki)
                entry.result_d = jnp.asarray(full_d)
                entry.result_i = jnp.asarray(full_i)
            else:
                entry.result_d, entry.result_i = kd, ki
            offset += entry.kernel_rows

    # -- speculation support ------------------------------------------------

    def stale_lookup(self, queries: jnp.ndarray
                     ) -> Optional[Tuple[jnp.ndarray, jnp.ndarray]]:
        """Any-generation cache lookup feeding speculative decode: the
        caller continues on these possibly-stale neighbors while the
        real search runs, so freshness is a quality hint, not a
        correctness requirement. None when any row is absent (or the
        cache is off)."""
        if self.cache is None:
            return None
        hit = self.cache.get_stale(np.asarray(queries, np.float32))
        if hit is None:
            return None
        return jnp.asarray(hit[0]), jnp.asarray(hit[1])

    def mark_cache_stale(self) -> None:
        """Generation-bump the result cache (quality knob changed):
        entries stop serving fresh lookups but remain speculation
        seeds. No-op without a cache."""
        if self.cache is not None:
            self.cache.mark_stale()

    # -- synchronous convenience -------------------------------------------

    def search(self, queries: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Blocking search: submit + flush + result (the legacy
        ``chamvs.search_single`` surface)."""
        return self.submit(queries).result()
