"""Request-queue scheduler with continuous batching.

Subsumes the old ``DisaggregatedRuntime.generate_pipelined`` round-robin:
``submit()`` enqueues a request at any time (including between steps —
new work joins the next ``step()``), ``step()`` advances every active
sequence one token in two phases:

  phase 1 — ONE ``decode_wave`` dispatch advances every active
     sequence over the engine's slotted ``KVCachePool`` (tokens [W],
     slots [W], positions [W]; W bucketed to powers of two, attention
     reads cropped to the wave's block-aligned valid prefix ``kv_len``
     — ``pool.stats.blocks_skipped/blocks_total`` record the ragged-
     wave savings, ``decode_compiles`` the graph churn). jax
     dispatch is async, so on a disaggregated deployment the wave's
     retrieval (phase 2) overlaps its decode on the other pool — the
     paper's batched GPU pool (§5) plus the multi-process ChamLM overlap
     (Fig. 12 throughput). (PoolTimes instrumentation blocks per pool
     step for measurement; build the backend with ``measure=False`` for
     maximum overlap. The per-sequence oracle — ``wave=False`` on the
     engine — instead dispatches one decode per sequence.)
  phase 2a — issue every due sequence's retrieval query. With an
     ``AsyncRetriever`` the queries only *enqueue* on the
     ``RetrievalService`` (each returns a ``SearchHandle`` future) while
     the phase-1 decode is still in flight; synchronous retrievers get
     one batched ``search`` over the wave's due rows.
  phase 2b — one ``flush_searches()``: the whole wave's queries
     coalesce into a single batched IVF-scan/PQ-ADC/top-k dispatch.
  phase 2c — resolve + integrate + sample, batched over the wave (one
     ``resolve``/interpolate over all due rows, one argmax over all
     greedy rows); per-request ``rng`` sampling stays per-sequence.

Sequences finish independently (continuous batching): a request that was
submitted later, or that asks for fewer steps, completes without waiting
for the rest of the batch — and frees its KV-pool slots for the next
queued request. Admission consults ``engine.can_admit`` (fixed-capacity
pools defer requests until slots free up) in strict FIFO order.
"""
from __future__ import annotations

import time
from collections import deque
from typing import TYPE_CHECKING, Dict, List, Optional

import numpy as np

from repro.runtime.fault_tolerance import StragglerMonitor
from repro.serve.api import RalmRequest, RalmResponse

if TYPE_CHECKING:  # avoid a circular import; the engine owns its scheduler
    from repro.serve.engine import RalmEngine


class RalmScheduler:
    """FIFO admission + lockstep two-phase stepping over active
    sequences. ``max_active`` bounds sequences in flight (admission
    control); ``None`` admits everything immediately."""

    def __init__(self, engine: "RalmEngine",
                 max_active: Optional[int] = None):
        self.engine = engine
        self.max_active = max_active
        self.queue: deque = deque()
        self.active: list = []
        self._next_id = 0
        self._issued: set = set()
        # wave-duration outlier detection (rolling-median rule from
        # repro.runtime.fault_tolerance, reused verbatim): a wave that
        # takes >2x the recent median usually means a retrieval stall
        # or a KV-pool growth — worth a counter + a trace instant
        self.straggler = StragglerMonitor(threshold=2.0, window=32)
        self.straggler_events = 0
        self._wave_idx = 0

    # ------------------------------------------------------------------
    def submit(self, request: RalmRequest) -> int:
        """Enqueue a request; returns its id. Prefill happens at
        admission (inside ``step``), not here — but a request that can
        never be admitted (more rows than the fixed KV pool holds) is
        rejected now rather than wedging the FIFO queue later."""
        self.engine.check_admissible(request)
        if request.request_id is None:
            request.request_id = self._next_id
        elif request.request_id in self._issued:
            raise ValueError(
                f"request_id {request.request_id} already issued")
        self._issued.add(request.request_id)
        self._next_id = max(self._next_id, request.request_id) + 1
        if request.trace_id is None:
            # the observability flow id linking this request's spans
            # across tracks; request_id is already unique per engine
            request.trace_id = request.request_id
        if request.times.arrival is None:
            request.times.arrival = time.perf_counter()
        self.queue.append(request)
        return request.request_id

    def cancel(self, request_id: int) -> bool:
        """Abort a request: a queued one is dropped immediately (no
        response will be produced for it); an active one is flagged and
        cleaned up — slots released, response emitted with
        ``cancelled=True`` — at the next ``step()``. Returns whether the
        id named a live request. Call from the thread that runs
        ``step()`` (the scheduler is not locked)."""
        for req in self.queue:
            if req.request_id == request_id:
                self.queue.remove(req)
                return True
        for seq in self.active:
            if seq.request.request_id == request_id:
                seq.request.cancelled = True
                return True
        return False

    def _admit(self) -> None:
        while self.queue and (self.max_active is None or
                              len(self.active) < self.max_active):
            if not self.engine.can_admit(self.queue[0]):
                break   # strict FIFO: a deferred head blocks later work
            self.active.append(self.engine.start(self.queue.popleft()))

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.active)

    @property
    def num_active(self) -> int:
        return len(self.active)

    # -- queue observability (the gateway's backpressure signal) -------------

    @property
    def queued_requests(self) -> int:
        """Requests admitted into the FIFO but not yet started. The old
        surface only ever exposed ``queue[0]`` implicitly through
        ``step()``; backpressure thresholds need the depth itself."""
        return len(self.queue)

    def queue_age_max_s(self, now: Optional[float] = None) -> float:
        """Age of the oldest queued request (0.0 when empty) — the
        head-of-line wait a newly arriving request is signing up behind."""
        if not self.queue:
            return 0.0
        now = time.perf_counter() if now is None else now
        oldest = min((r.times.arrival for r in self.queue
                      if r.times.arrival is not None), default=now)
        return max(0.0, now - oldest)

    def tenant_depths(self) -> Dict[str, int]:
        """Queued-request count per tenant (active sequences excluded:
        they already hold slots)."""
        depths: Dict[str, int] = {}
        for req in self.queue:
            depths[req.tenant] = depths.get(req.tenant, 0) + 1
        return depths

    def queue_stats(self, now: Optional[float] = None) -> Dict[str, object]:
        """One observable snapshot for /statsz and the degrade policy."""
        return dict(
            queued_requests=self.queued_requests,
            active_requests=self.num_active,
            active_rows=sum(seq.cur.shape[0] for seq in self.active),
            queue_age_max_s=self.queue_age_max_s(now),
            tenant_depth=self.tenant_depths(),
        )

    # ------------------------------------------------------------------
    def step(self) -> List[RalmResponse]:
        """Advance every active sequence one token; returns the requests
        that completed on this step."""
        self._admit()
        finished: List[RalmResponse] = []
        # a steps<=0 request is complete at admission: prompt only
        already_done = [s for s in self.active if s.done]
        self.active = [s for s in self.active if not s.done]
        for seq in already_done:
            self.engine.release(seq)
            finished.append(self._response(seq))
        if self.engine.wave:
            return finished + self._step_wave()
        # --- per-sequence oracle path (wave=False) ---
        # phase 1: dispatch decode for every sequence (async)
        pending = [(seq, *self.engine.dispatch_decode(seq))
                   for seq in self.active]
        # phase 2a: issue every sequence's retrieval query (futures)
        searches = [self.engine.dispatch_search(seq, hidden)
                    for seq, _, hidden in pending]
        # phase 2b: one coalesced kernel dispatch for the whole wave
        self.engine.flush_searches()
        # phase 2c: resolve + integrate + sample (overlaps phase-1 work
        # still in flight on the other pool)
        still_active = []
        for (seq, logits, hidden), search in zip(pending, searches):
            self.engine.finish_step(seq, logits, hidden, search=search)
            if seq.done:
                finished.append(self._response(seq))
            else:
                still_active.append(seq)
        self.active = still_active
        return finished

    def _step_wave(self) -> List[RalmResponse]:
        """Wave-batched step body: one dispatch per phase for the whole
        active set (see the module docstring for the phases). The phase
        spans all land on the "wave" track, nested under one sched.step
        span per wave, so a Perfetto timeline shows decode / search /
        finish as adjacent slices of each step."""
        tr = self.engine.tracer
        t_wave = time.perf_counter()
        with tr.span("sched.step", "wave",
                     args={"active": len(self.active)}
                     if tr.enabled else None):
            decoded = self.engine.dispatch_wave(self.active)
            if self.engine.speculate_k > 0:
                # speculation harvest: verify points whose real search
                # has had its waves to land — AFTER the next decode is
                # dispatched (the overlap that hides the scan) and
                # BEFORE the search phase (so an accepted point's real
                # neighbors seed this wave's speculations)
                self.engine.spec_harvest(self.active, decoded)
            with tr.span("wave.search", "wave"):
                searches = self.engine.dispatch_search_wave(
                    self.active, decoded)
                self.engine.flush_searches()
            with tr.span("wave.finish", "wave"):
                self.engine.finish_wave(self.active, decoded, searches)
        if self.active:
            self._record_wave(time.perf_counter() - t_wave)
        finished: List[RalmResponse] = []
        still_active = []
        for seq in self.active:
            if seq.done:
                if seq.spec_points:
                    # settle outstanding speculation before the response
                    # leaves the system (forced verify; discard when
                    # cancelled) — the parity guarantee is per-response
                    self.engine.spec_finalize(seq)
                self.engine.release(seq)   # slots free for queued work
                finished.append(self._response(seq))
            else:
                still_active.append(seq)
        self.active = still_active
        return finished

    def _record_wave(self, duration_s: float) -> None:
        """Feed one wave's wall time into the straggler monitor; an
        outlier (>threshold x the rolling median — the monitor needs a
        few waves of history first) bumps the counter the metrics
        adapter exports and drops a trace instant."""
        self._wave_idx += 1
        event = self.straggler.record(self._wave_idx, duration_s)
        if event is None:
            return
        self.straggler_events += 1
        tr = self.engine.tracer
        if tr.enabled:
            tr.instant("sched.straggler", "wave",
                       args={"wave": event.step,
                             "duration_ms": event.duration * 1e3,
                             "median_ms": event.median * 1e3,
                             "ratio": event.ratio})

    @staticmethod
    def _response(seq) -> RalmResponse:
        seq.request.times.finish = time.perf_counter()
        return RalmResponse(
            request_id=seq.request.request_id,
            tokens=np.asarray(seq.tokens()),
            steps=seq.step, trace=seq.request.trace,
            tenant=seq.request.tenant,
            cancelled=seq.request.cancelled,
            times=seq.request.times,
            partial_steps=seq.request.partial_steps)

    def run(self) -> List[RalmResponse]:
        """Drain the queue: step until nothing is queued or active."""
        out: List[RalmResponse] = []
        while self.has_work:
            out.extend(self.step())
        return out
