"""``KVCachePool`` — one preallocated, slotted KV cache for every active
sequence.

Before this pool, each ``SequenceState`` owned a private per-sequence
cache pytree and the scheduler paid one LM dispatch *per sequence* per
wave. The pool makes the whole wave one batch: every cache leaf carries
a pooled batch dim of ``capacity + 1`` slot rows (the extra row is a
scratch slot that absorbs wave padding), admission assigns a sequence's
prompt rows to free slots, prefill scatters its ragged-length KV into
them, and completion frees them for reuse. ``transformer.decode_wave``
then advances any subset of slots as a single dispatch.

Wave sizes are bucketed to powers of two (the same shape-bucketing the
``RetrievalService`` applies to query batches) so continuous batching —
where the active row count changes every step — compiles O(log capacity)
decode graphs instead of one per wave size. Padding rows all point at
the scratch slot: they gather/scatter only don't-care state and their
outputs are dropped, so they never perturb live slots.

The pool grows on demand (slot rows double; the sequence axis extends to
the longest admitted request) unless constructed with a fixed capacity,
in which case admission defers until completions free slots — the
admission-control behavior the scheduler exposes as ``max_active`` does
for request counts, here in units of KV slot rows.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.obs.trace import NULL_TRACER
from repro.retrieval.service import next_pow2

__all__ = ["KVCachePool", "PoolStats", "next_pow2"]


@dataclasses.dataclass
class PoolStats:
    """Slot-lifecycle accounting (benchmarks + tests)."""
    allocs: int = 0              # slot rows handed out
    releases: int = 0            # slot rows returned
    high_water: int = 0          # max slot rows in use at once
    slot_grows: int = 0          # capacity doublings
    seq_grows: int = 0           # sequence-axis extensions
    waves: int = 0               # decode waves dispatched
    wave_rows: int = 0           # live rows across all waves
    rewinds: int = 0             # speculation rollbacks (slot-row groups)
    rewound_tokens: int = 0      # KV positions logically discarded
    buckets: set = dataclasses.field(default_factory=set)  # compiled W's
    # length-aware decode attention (ragged-wave savings + jit churn)
    blocks_total: int = 0        # seq blocks a full-pool read would touch
    blocks_skipped: int = 0      # blocks cropped past the wave's max pos
    compiled: set = dataclasses.field(default_factory=set)
    #                            # distinct (wave bucket, kv_len,
    #                            # capacity, max_seq) decode graphs —
    #                            # the recompile observable; pool shape
    #                            # is part of the key because growth
    #                            # events retrace every bucket

    def mean_wave(self) -> float:
        return self.wave_rows / self.waves if self.waves else 0.0

    @property
    def decode_compiles(self) -> int:
        """Distinct decode-wave graph keys traced so far. Continuous
        batching must keep this O(log capacity * max_seq/seq_block),
        not O(waves) — asserted in tests/test_decode_attn.py."""
        return len(self.compiled)

    def skip_fraction(self) -> float:
        return (self.blocks_skipped / self.blocks_total
                if self.blocks_total else 0.0)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(pool: Any, rows: Any, slots: jnp.ndarray) -> Any:
    """Write per-request cache rows (batch dim B) into pool slot rows.

    Leaves are [n_layers, P, ...] vs [n_layers, B, ...]; the pool arg is
    donated so XLA updates the slots in place."""
    return jax.tree.map(
        lambda p, r: p.at[:, slots].set(r.astype(p.dtype)), pool, rows)


class KVCachePool:
    """Slotted decode-cache pool owned by the engine (one per deployment).

    Slot ids are stable for a sequence's lifetime: ``alloc`` hands out the
    lowest free ids (deterministic reuse, which the tests rely on),
    ``release`` returns them. Index ``capacity`` is the scratch slot."""

    def __init__(self, cfg: ModelConfig, capacity: int, max_seq: int,
                 enc_len: int = 0, fixed: bool = False, seq_block: int = 1):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if seq_block < 1:
            raise ValueError(f"seq_block must be >= 1, got {seq_block}")
        self.cfg = cfg
        self.capacity = capacity
        self.seq_block = seq_block           # seq-axis alignment quantum:
        #                                      attention reads are cropped
        #                                      to multiples of this, so the
        #                                      axis itself must be aligned
        self.max_seq = self._align(max_seq)
        self.enc_len = enc_len
        self.fixed = fixed                   # no auto-grow when True
        self.caches = tf.init_cache(cfg, capacity + 1, self.max_seq,
                                    enc_len=enc_len)
        self.enc: Optional[jnp.ndarray] = None   # [P+1, S_enc, d], lazy
        self._free: List[int] = list(range(capacity))
        self.stats = PoolStats()
        self.tracer = NULL_TRACER    # engine.set_tracer swaps a live one in

    # -- slot lifecycle -----------------------------------------------------

    @property
    def scratch(self) -> int:
        return self.capacity

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, n: int) -> np.ndarray:
        """Claim ``n`` slot rows (lowest free ids first)."""
        if n > len(self._free):
            raise RuntimeError(
                f"KVCachePool exhausted: want {n} rows, {len(self._free)} "
                f"free of {self.capacity} (admission should have deferred)")
        self._free.sort()
        slots, self._free = self._free[:n], self._free[n:]
        self.stats.allocs += n
        self.stats.high_water = max(self.stats.high_water, self.num_used)
        if self.tracer.enabled:
            self.tracer.instant("kvpool.alloc", "kvpool",
                                args={"rows": n, "used": self.num_used,
                                      "capacity": self.capacity})
        return np.asarray(slots, np.int32)

    def release(self, slots: np.ndarray) -> None:
        self._free.extend(int(s) for s in slots)
        self.stats.releases += len(slots)
        if self.tracer.enabled:
            self.tracer.instant("kvpool.release", "kvpool",
                                args={"rows": len(slots),
                                      "used": self.num_used,
                                      "capacity": self.capacity})

    def rewind(self, slots: np.ndarray, keep_len: int,
               old_len: int) -> None:
        """Logically rewind ``slots`` from ``old_len`` valid KV
        positions back to ``keep_len`` (speculation rollback) — WITHOUT
        touching device memory.

        For full-length (linear) caches this is free by construction:
        decode attention derives validity from the row's *position*
        (slot index i is read iff i < kv_len and i <= pos — see
        ``kernels.decode_attn.ref.decode_validity``), so the stale
        suffix above ``keep_len`` is never read once the sequence's
        position moves back, and the replayed decodes overwrite it
        index-for-index. Ring (sliding-window) caches alias positions
        modulo the window, so a rewind deeper than one step would leave
        stale entries *inside* the live window where validity cannot
        mask them — rejected here; the engine caps speculation depth at
        1 for windowed models. Recurrent state (RWKV/SSM blocks) cannot
        be rewound at all: the state update is not invertible and old
        states are not retained.
        """
        if not (0 < keep_len <= old_len <= self.max_seq):
            raise ValueError(
                f"rewind wants 0 < keep_len <= old_len <= max_seq, got "
                f"keep_len={keep_len} old_len={old_len} "
                f"max_seq={self.max_seq}")
        dropped = old_len - keep_len
        if self.cfg.ssm_state > 0 or self.cfg.block in ("rwkv6", "hybrid"):
            raise ValueError(
                "KV rewind is undefined for recurrent-state blocks "
                f"(block={self.cfg.block!r}, ssm_state="
                f"{self.cfg.ssm_state}) — gate speculation off for "
                "this model")
        if dropped > 1 and self.cfg.window > 0 and \
                "local" in self.cfg.pattern_classes():
            raise ValueError(
                f"ring (window={self.cfg.window}) caches alias positions "
                f"modulo the window: rewinding {dropped} steps would "
                "leave stale rows inside the live window — speculation "
                "depth must be 1 for windowed models")
        self.stats.rewinds += 1
        self.stats.rewound_tokens += dropped * len(slots)
        if self.tracer.enabled:
            self.tracer.instant("kvpool.rewind", "kvpool",
                                args={"rows": len(slots),
                                      "keep_len": keep_len,
                                      "dropped": dropped})

    # -- wave shape bucketing ----------------------------------------------

    def _align(self, n: int) -> int:
        """Round ``n`` up to the pool's seq-block quantum."""
        b = self.seq_block
        return -(-n // b) * b

    def attn_len(self, max_pos: int, bucket: int) -> int:
        """Static attention length for one wave: the block-aligned valid
        prefix covering every row's position. The engine passes it into
        the jitted ``decode_wave`` so full-cache attention reads crop to
        ``kv_len`` instead of the pool's padded ``max_seq`` — the
        length-aware half of the decode-attention kernel's contract
        (the kernel's per-row-tile skip refines it further inside one
        dispatch). Also the bookkeeping point for the ragged-wave
        savings (``blocks_skipped``) and the jit-churn observable
        (``compiled`` keys are (wave bucket, kv_len) pairs)."""
        kv_len = min(self._align(max_pos + 1), self.max_seq)
        nb_full = self.max_seq // self.seq_block
        self.stats.blocks_total += nb_full
        self.stats.blocks_skipped += nb_full - kv_len // self.seq_block
        key = (bucket, kv_len, self.capacity, self.max_seq)
        if key not in self.stats.compiled:
            self.stats.compiled.add(key)
            # a new graph key means jit will trace+compile a fresh
            # decode_wave variant on this step — the recompile stall is
            # worth a mark in the trace
            if self.tracer.enabled:
                self.tracer.instant(
                    "jit.decode_compile", "kernels",
                    args={"bucket": bucket, "kv_len": kv_len,
                          "capacity": self.capacity,
                          "max_seq": self.max_seq,
                          "graphs": len(self.stats.compiled)})
        return kv_len

    def bucket(self, n: int) -> int:
        """Pow2 wave-size bucket: bounds jit recompiles under continuous
        batching to O(log capacity) decode graphs."""
        b = next_pow2(n)
        self.stats.buckets.add(b)
        return b

    def pad_wave(self, tokens: jnp.ndarray, slots: np.ndarray,
                 positions: np.ndarray):
        """Pad a W-row wave to its pow2 bucket. Pad rows carry token 0 at
        position 0 against the scratch slot — they compute garbage that is
        sliced off and scatter only into the scratch row. ``tokens`` stays
        on device (no host sync); slots/positions are host arrays."""
        w = len(slots)
        self.stats.waves += 1
        self.stats.wave_rows += w
        pad = self.bucket(w) - w
        if pad:
            tokens = jnp.pad(tokens,
                             [(0, pad)] + [(0, 0)] * (tokens.ndim - 1))
            slots = np.concatenate(
                [slots, np.full((pad,), self.scratch, np.int32)])
            positions = np.concatenate(
                [positions, np.zeros((pad,), positions.dtype)])
        return tokens, slots, positions

    # -- prefill / encoder-state rows --------------------------------------

    def write_prefill(self, slots: np.ndarray, caches: Any) -> None:
        """Scatter a prefilled request's cache rows into its slots. The
        request cache must be built with the pool's ``max_seq`` so leaf
        shapes line up (the engine's ``start`` guarantees this)."""
        self.caches = _scatter_rows(self.caches, caches,
                                    jnp.asarray(slots))

    def write_enc(self, slots: np.ndarray, rows: jnp.ndarray) -> None:
        """Per-slot encoder states (encdec/RETRO): [B, S_enc, d] rows.

        All slots share one pooled enc buffer, so every write must keep
        the row shape of the first one — a silent reinit here would wipe
        other live slots' states. Widths diverge only in the degenerate
        RETRO config ``rag.k * rag.chunk_len < 8`` (prefill's neutral
        encoder floor is 8 tokens); that config needs ``wave=False``."""
        if self.enc is None:
            self.enc = jnp.zeros((self.capacity + 1,) + rows.shape[1:],
                                 rows.dtype)
        elif self.enc.shape[1:] != rows.shape[1:]:
            raise ValueError(
                f"pooled enc rows must keep shape {self.enc.shape[1:]}, "
                f"got {rows.shape[1:]} — heterogeneous encoder widths "
                "(rag.k * rag.chunk_len < 8) need the per-sequence path "
                "(wave=False)")
        self.enc = self.enc.at[jnp.asarray(slots)].set(rows)

    def gather_enc(self, slots: np.ndarray) -> Optional[jnp.ndarray]:
        return None if self.enc is None else self.enc[jnp.asarray(slots)]

    # -- growth -------------------------------------------------------------

    def grow_slots(self, new_capacity: int) -> None:
        """Double-style capacity growth: pad every leaf's slot axis. The
        old scratch row becomes a normal (garbage, free) slot — harmless,
        prefill rewrites whole rows at admission."""
        if self.fixed:
            raise RuntimeError("fixed-capacity pool cannot grow")
        if new_capacity <= self.capacity:
            return
        delta = new_capacity - self.capacity

        def pad_slots(a):
            widths = [(0, 0)] * a.ndim
            widths[1] = (0, delta)
            return jnp.pad(a, widths)

        self.caches = jax.tree.map(pad_slots, self.caches)
        if self.enc is not None:
            self.enc = jnp.pad(self.enc,
                               [(0, delta)] + [(0, 0)] * (self.enc.ndim - 1))
        self._free.extend(range(self.capacity, new_capacity))
        self.capacity = new_capacity
        self.stats.slot_grows += 1

    def grow_seq(self, new_max_seq: int) -> None:
        """Extend the sequence axis of full-length (non-ring) K/V leaves
        so longer requests fit. Written prefixes keep their positions
        (slot i of a full cache always holds absolute position i). The
        new length stays seq-block aligned."""
        new_max_seq = self._align(new_max_seq)
        if new_max_seq <= self.max_seq:
            return
        delta = new_max_seq - self.max_seq
        for cls, c in self.caches["classes"].items():
            ring = (cls == "local" and self.cfg.window > 0)
            if ring or "k" not in c:
                continue
            for key in ("k", "v"):
                a = c[key]
                widths = [(0, 0)] * a.ndim
                widths[2] = (0, delta)
                c[key] = jnp.pad(a, widths)
        self.max_seq = new_max_seq
        self.stats.seq_grows += 1
