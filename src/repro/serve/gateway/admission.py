"""Per-tenant admission control for the serving front door.

The engine already has the *inner* admission loop: ``engine.can_admit``
defers a request until KV-pool slots free up, in strict FIFO order.
That protects the pool, but it is the wrong layer for multi-tenant
traffic — one chatty tenant fills the FIFO and everyone else queues
behind it, and nothing ever says "no" to a client, so overload turns
into unbounded queue growth instead of backpressure.

This module is the *outer* loop, the one the paper's CPU coordinator
(§3) would run at the front door:

  * ``TokenBucket`` / ``TenantQuota`` — a per-tenant request-rate
    quota. An empty bucket is a **429** with ``Retry-After`` (the
    client is over its contract; shedding it protects everyone else);
  * queue-depth backpressure — when the total backlog (gateway pending
    + scheduler queue + active) exceeds ``max_queue_depth``, new work
    gets a **503** + ``Retry-After`` (the *system* is saturated;
    admitting more only grows tail latency — RAGO's TTFT-under-SLO
    lens says reject early);
  * per-tenant **fair dequeue** — accepted requests wait in per-tenant
    queues and are released to the scheduler round-robin across
    tenants, so the engine's strict-FIFO inner queue stays short and a
    burst from one tenant cannot monopolize admission order.

Pure host-side bookkeeping: no jax, no threads of its own. The gateway
calls ``offer()`` from its HTTP handlers and ``take()`` from the
scheduler step loop; callers serialize access (the step loop is the
only consumer, handlers the only producers — a single lock in the
gateway covers both).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

from repro.serve.api import RalmRequest


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Request-rate contract of one tenant class.

    ``rate`` requests/second refill, up to ``burst`` banked. ``rate <=
    0`` means unmetered (admission is then bounded only by the global
    queue depth)."""
    rate: float = 0.0
    burst: float = 1.0


class TokenBucket:
    """Classic token bucket over a monotonic clock (injectable for
    tests). ``try_take`` either spends a token or reports how long
    until one is available (the 429's Retry-After)."""

    def __init__(self, quota: TenantQuota,
                 clock: Callable[[], float] = time.monotonic):
        self.quota = quota
        self._clock = clock
        self._tokens = float(quota.burst)
        self._last = clock()

    def _refill(self, now: float) -> None:
        self._tokens = min(self.quota.burst,
                           self._tokens + (now - self._last)
                           * self.quota.rate)
        self._last = now

    def try_take(self) -> Optional[float]:
        """Spend one token. Returns ``None`` on success, else the
        seconds until the next token (>= 0) for Retry-After."""
        if self.quota.rate <= 0:
            return None                      # unmetered tenant
        now = self._clock()
        self._refill(now)
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return None
        return (1.0 - self._tokens) / self.quota.rate


@dataclasses.dataclass
class Verdict:
    """Outcome of ``offer()``: HTTP-shaped so the server maps it 1:1."""
    admitted: bool
    status: int = 200                 # 429 quota / 503 backpressure
    retry_after_s: float = 0.0
    reason: str = ""


class AdmissionController:
    """Front-door admission: quota check + backpressure bound at
    ``offer()``, per-tenant fair release at ``take()``."""

    def __init__(self, max_queue_depth: int = 64,
                 default_quota: Optional[TenantQuota] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.max_queue_depth = max_queue_depth
        self.default_quota = default_quota or TenantQuota()
        self.quotas = dict(quotas or {})
        self._clock = clock
        self._buckets: Dict[str, TokenBucket] = {}
        self._queues: Dict[str, deque] = {}
        self._rr: List[str] = []          # round-robin tenant rotation
        # counters surfaced on /statsz and in BENCH_serve.json
        self.admitted = 0
        self.rejected_quota = 0           # 429s
        self.rejected_capacity = 0        # 503s
        self.released = 0                 # handed to the scheduler

    # -- producer side (HTTP handlers) --------------------------------------

    def _bucket(self, tenant: str) -> TokenBucket:
        if tenant not in self._buckets:
            self._buckets[tenant] = TokenBucket(
                self.quotas.get(tenant, self.default_quota),
                clock=self._clock)
        return self._buckets[tenant]

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def tenant_pending(self) -> Dict[str, int]:
        return {t: len(q) for t, q in self._queues.items() if q}

    def offer(self, request: RalmRequest, in_system: int = 0) -> Verdict:
        """Admit-or-reject one arriving request. ``in_system`` is the
        scheduler-side load (queued + active requests) so the depth
        bound covers the whole pipeline, not just this controller's
        queues. On admission the request is parked in its tenant's
        queue until ``take()`` releases it."""
        wait = self._bucket(request.tenant).try_take()
        if wait is not None:
            self.rejected_quota += 1
            return Verdict(False, status=429, retry_after_s=wait,
                           reason=f"tenant {request.tenant!r} over quota")
        if self.pending + in_system >= self.max_queue_depth:
            self.rejected_capacity += 1
            # a half-full queue drains in roughly (depth x service
            # time); without a latency estimate, 1s is an honest floor
            return Verdict(False, status=503, retry_after_s=1.0,
                           reason="queue depth bound reached")
        if request.tenant not in self._queues:
            self._queues[request.tenant] = deque()
            self._rr.append(request.tenant)
        self._queues[request.tenant].append(request)
        self.admitted += 1
        return Verdict(True)

    # -- consumer side (the scheduler step loop) ----------------------------

    def take(self, fits: Callable[[RalmRequest], bool]
             ) -> Optional[RalmRequest]:
        """Release the next request in round-robin tenant order whose
        head passes ``fits`` (the caller's capacity check — e.g. free
        KV rows). A tenant whose head does not fit is skipped this
        round rather than blocking everyone (the strict-FIFO inner
        queue stays short, so head-of-line blocking lives only inside
        one tenant's own queue)."""
        for _ in range(len(self._rr)):
            tenant = self._rr[0]
            self._rr.append(self._rr.pop(0))       # rotate
            q = self._queues.get(tenant)
            if q and fits(q[0]):
                self.released += 1
                return q.popleft()
        return None

    def cancel(self, request_id) -> bool:
        """Drop a still-pending request (client hung up before release).
        Returns whether the id was found here; a released request is the
        scheduler's to cancel."""
        for q in self._queues.values():
            for req in q:
                if req.request_id == request_id:
                    q.remove(req)
                    return True
        return False

    def stats(self) -> Dict[str, object]:
        return dict(pending=self.pending,
                    tenant_pending=self.tenant_pending(),
                    admitted=self.admitted,
                    released=self.released,
                    rejected_quota=self.rejected_quota,
                    rejected_capacity=self.rejected_capacity)
