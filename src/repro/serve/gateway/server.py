"""The serving front door: a streaming HTTP gateway over ``RalmEngine``.

Chameleon's deployment story (paper §3, §6) is a CPU front-end
multiplexing many concurrent clients over disaggregated LM + ChamVS
tiers. Until now every entry point in this repo drove the scheduler
in-process with pre-built request lists; this module is the missing
network layer — stdlib-only (``asyncio`` + a minimal HTTP/1.1 parser,
no framework deps), OpenAI-compatible:

    POST /v1/completions   prompt as token ids (or text via the toy
                           byte codec), ``stream: true`` for SSE chunks
                           terminated by ``data: [DONE]``
    GET  /v1/models        the one deployed model
    GET  /healthz          liveness (incl. the step thread)
    GET  /statsz           scheduler queue depths/ages, admission and
                           degrade counters, pool + retrieval + kernel
                           stats, plus a snapshot of the metrics
                           registry (the JSON view of /metricsz)
    GET  /metricsz         Prometheus text exposition of the same
                           registry (repro.obs: TTFT/TPOT/queue-wait
                           histograms with reservoir p50/p95/p99, pool /
                           retrieval / admission / degrade families)
    GET  /tracez           Chrome trace-event JSON export of the
                           engine's tracer buffer (?clear=1 drains it —
                           the per-load-level boundary the loadgen uses)

Architecture — two threads, one engine:

  * the **asyncio event loop** owns all sockets. Handlers parse
    requests, run admission control (429 on quota, 503 + Retry-After
    on queue-depth backpressure — see ``admission.py``), park accepted
    work with the admission controller, and stream tokens out of
    per-request queues;
  * the **step-loop thread** owns the engine and all jax work. Each
    iteration it drains cancellations, releases admitted requests to
    the scheduler in per-tenant fair order (only as many as there are
    free KV rows, so the engine's strict-FIFO inner queue stays
    short), ticks the degradation policy (``degrade.py``), and runs
    one ``scheduler.step()`` — one decode wave + one retrieval wave
    for every active sequence, exactly the batched path the perf PRs
    built. Tokens cross back via ``RalmRequest.on_token`` →
    ``loop.call_soon_threadsafe``.

A mid-stream client disconnect (EOF on the request socket or a failed
chunk write) cancels the request at the next wave: its KV slots are
released and the backlog moves up — a dead client never holds capacity.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels import registry as kernel_registry
from repro.obs.adapters import bind_gateway_metrics
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.serve.api import RalmRequest
from repro.serve.gateway.admission import (AdmissionController, TenantQuota,
                                           Verdict)
from repro.serve.gateway.degrade import DegradeConfig, DegradePolicy

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 413: "Payload Too Large",
            429: "Too Many Requests", 500: "Internal Server Error",
            503: "Service Unavailable"}


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    """Deployment knobs of one front door."""
    host: str = "127.0.0.1"
    port: int = 0                     # 0 = ephemeral (tests, benches)
    model_id: str = "chameleon-ralm"
    max_queue_depth: int = 64         # 503 bound over the whole pipeline
    default_quota: TenantQuota = TenantQuota()   # unmetered by default
    quotas: Tuple[Tuple[str, TenantQuota], ...] = ()
    degrade: Optional[DegradeConfig] = DegradeConfig()  # None = never shed
    max_tokens_cap: int = 256         # hard cap on requested max_tokens
    max_prompt_tokens: int = 2048     # hard cap on prompt length
    max_body_bytes: int = 1 << 20
    idle_sleep_s: float = 0.005       # step-thread wait when queue empty


@dataclasses.dataclass
class _Stream:
    """Per-in-flight-request bridge between the two threads."""
    rid: int
    tenant: str
    prompt_tokens: int
    max_tokens: int
    queue: "asyncio.Queue" = dataclasses.field(
        default_factory=asyncio.Queue)
    levels: Set[int] = dataclasses.field(default_factory=set)
    tokens: List[int] = dataclasses.field(default_factory=list)


class Gateway:
    """One HTTP front door over one ``RalmEngine``."""

    def __init__(self, engine, config: Optional[GatewayConfig] = None):
        self.engine = engine
        self.scheduler = engine.scheduler
        self.config = config or GatewayConfig()
        self.admission = AdmissionController(
            max_queue_depth=self.config.max_queue_depth,
            default_quota=self.config.default_quota,
            quotas=dict(self.config.quotas))
        self.policy: Optional[DegradePolicy] = (
            DegradePolicy(engine, self.config.degrade)
            if self.config.degrade is not None else None)
        self._lock = threading.Lock()
        self._streams: Dict[int, _Stream] = {}
        self._cancels: deque = deque()
        self._next_rid = 0
        self._work = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.base_events.Server] = None
        self.port: Optional[int] = None
        self._t_start = time.perf_counter()
        # counters for /statsz and the load harness
        self.completions = 0
        self.cancelled = 0
        self.disconnects = 0
        self.tokens_out = 0
        # observability plane: the engine's tracer (NULL when tracing is
        # off) + one metrics registry absorbing every stats object via
        # scrape-time collectors (repro.obs.adapters)
        self.tracer = getattr(engine, "tracer", NULL_TRACER)
        self.metrics = MetricsRegistry()
        self.ttft_hist = self.metrics.histogram(
            "ralm_ttft_seconds", "time to first token, server-side")
        self.tpot_hist = self.metrics.histogram(
            "ralm_tpot_seconds", "per-output-token time, server-side")
        self.queue_wait_hist = self.metrics.histogram(
            "ralm_queue_wait_seconds", "arrival -> admission wait")
        bind_gateway_metrics(self.metrics, self)

    # ------------------------------------------------------------------
    # step-loop thread: the only thread that touches the engine/jax
    # ------------------------------------------------------------------

    def _free_rows(self) -> Optional[int]:
        eng = self.engine
        if not eng.wave or eng.kv_slots is None:
            return None                       # auto-growing pool
        if eng.pool is None:
            return eng.kv_slots
        return eng.pool.num_free

    def _pump_admissions(self) -> None:
        """Release fair-ordered requests to the scheduler, at most as
        many as fit the free KV rows / ``max_active`` budget, so the
        scheduler's strict-FIFO queue never becomes the bottleneck."""
        free = self._free_rows()
        cap = self.scheduler.max_active
        budget = (None if cap is None else
                  cap - self.scheduler.num_active
                  - self.scheduler.queued_requests)
        while budget is None or budget > 0:
            with self._lock:
                req = self.admission.take(
                    lambda r: free is None or r.prompt.shape[0] <= free)
            if req is None:
                return
            if free is not None:
                free -= req.prompt.shape[0]
            if budget is not None:
                budget -= 1
            self.scheduler.submit(req)

    def _drain_cancels(self) -> None:
        while self._cancels:
            rid = self._cancels.popleft()
            with self._lock:
                dropped = self.admission.cancel(rid)
            if not dropped:
                self.scheduler.cancel(rid)

    def _queue_depth(self) -> int:
        return (self.admission.pending + self.scheduler.queued_requests)

    def _on_token(self, rid: int, step: int, toks: np.ndarray) -> None:
        """Runs on the step thread inside ``finish_wave``: record the
        level this token was produced at, then hand it to the event
        loop. A request whose stream is gone (disconnect) is silently
        dropped here; the cancel lands at the next step."""
        stream = self._streams.get(rid)
        if stream is None:
            return
        if self.policy is not None:
            stream.levels.add(self.policy.level)
        else:
            stream.levels.add(0)
        tok = int(toks[0])
        stream.tokens.append(tok)
        self.tokens_out += 1
        loop = self._loop
        if loop is not None:
            try:
                loop.call_soon_threadsafe(
                    stream.queue.put_nowait, ("tok", step, tok))
            except RuntimeError:       # loop shut down mid-stream
                pass

    def _finish(self, resp) -> None:
        with self._lock:
            stream = self._streams.pop(resp.request_id, None)
        if resp.cancelled:
            self.cancelled += 1
        else:
            self.completions += 1
        times = resp.times
        if times is not None:
            ttft = times.ttft_s()
            if ttft is not None:
                self.ttft_hist.observe(ttft)
            tpot = times.tpot_s(resp.steps)
            if tpot is not None:
                self.tpot_hist.observe(tpot)
            if times.admit is not None and times.arrival is not None:
                self.queue_wait_hist.observe(times.admit - times.arrival)
        if stream is None:
            return
        summary = dict(
            steps=resp.steps,
            cancelled=resp.cancelled,
            degrade_levels=sorted(stream.levels) or [
                self.policy.level if self.policy else 0],
            ttft_ms=(None if times is None or times.ttft_s() is None
                     else times.ttft_s() * 1e3),
            tpot_ms=(None if times is None or times.tpot_s(resp.steps)
                     is None else times.tpot_s(resp.steps) * 1e3),
            queue_wait_ms=(None if times is None or times.admit is None
                           or times.arrival is None
                           else (times.admit - times.arrival) * 1e3),
        )
        loop = self._loop
        if loop is not None:
            try:
                loop.call_soon_threadsafe(
                    stream.queue.put_nowait, ("done", summary))
            except RuntimeError:
                pass

    def _step_loop(self) -> None:
        while not self._stop.is_set():
            self._drain_cancels()
            self._pump_admissions()
            if self.policy is not None:
                self.policy.observe(self._queue_depth())
            if self.scheduler.has_work:
                for resp in self.scheduler.step():
                    self._finish(resp)
            else:
                self._work.wait(timeout=self.config.idle_sleep_s)
                self._work.clear()

    # ------------------------------------------------------------------
    # request intake (runs on the event loop thread)
    # ------------------------------------------------------------------

    def _encode_prompt(self, prompt) -> Optional[List[int]]:
        """OpenAI allows a string or a list of token ids. There is no
        real tokenizer at desk scale, so strings go through a toy byte
        codec (``ord(c) % vocab``) — documented, deterministic, and
        good enough to exercise the serving path."""
        vocab = self.engine.cfg.vocab_size
        if isinstance(prompt, str):
            ids = [ord(c) % vocab for c in prompt]
            return ids or None
        if isinstance(prompt, list) and len(prompt) == 1 and \
                isinstance(prompt[0], list):
            prompt = prompt[0]                 # [[ids]] — a batch of one
        if isinstance(prompt, list) and prompt and \
                all(isinstance(t, int) for t in prompt):
            if any(t < 0 or t >= vocab for t in prompt):
                return None
            return prompt
        return None

    def _make_request(self, body: dict, tenant: str
                      ) -> Tuple[Optional[RalmRequest], str]:
        ids = self._encode_prompt(body.get("prompt"))
        if ids is None:
            return None, ("prompt must be a non-empty string or a list "
                          "of in-vocab token ids")
        if len(ids) > self.config.max_prompt_tokens:
            return None, (f"prompt of {len(ids)} tokens exceeds the "
                          f"{self.config.max_prompt_tokens} cap")
        steps = body.get("max_tokens", 16)
        if not isinstance(steps, int) or steps < 1 or \
                steps > self.config.max_tokens_cap:
            return None, (f"max_tokens must be an int in [1, "
                          f"{self.config.max_tokens_cap}]")
        max_seq = self.engine.max_seq
        if max_seq is not None and len(ids) + steps > max_seq:
            return None, (f"prompt + max_tokens = {len(ids) + steps} "
                          f"exceeds the deployment context of {max_seq}")
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
        req = RalmRequest(
            prompt=jnp.asarray(np.asarray(ids, np.int32)[None]),
            steps=steps, request_id=rid, tenant=tenant,
            on_token=lambda step, toks, rid=rid:
                self._on_token(rid, step, toks))
        req.times.arrival = time.perf_counter()
        return req, ""

    def _offer(self, req: RalmRequest) -> Verdict:
        in_system = (self.scheduler.queued_requests +
                     self.scheduler.num_active)
        with self._lock:
            verdict = self.admission.offer(req, in_system=in_system)
            if verdict.admitted:
                self._streams[req.request_id] = _Stream(
                    rid=req.request_id, tenant=req.tenant,
                    prompt_tokens=req.prompt.shape[1],
                    max_tokens=req.steps)
        if verdict.admitted:
            self._work.set()
        return verdict

    # ------------------------------------------------------------------
    # HTTP plumbing
    # ------------------------------------------------------------------

    @staticmethod
    def _head(status: int, ctype: str = "application/json",
              length: Optional[int] = None, extra: str = "") -> bytes:
        head = (f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}\r\n"
                f"Content-Type: {ctype}\r\n"
                "Connection: close\r\n")
        if length is not None:
            head += f"Content-Length: {length}\r\n"
        return (head + extra + "\r\n").encode()

    def _write_json(self, writer, status: int, obj,
                    extra: str = "") -> None:
        payload = json.dumps(obj).encode()
        writer.write(self._head(status, length=len(payload), extra=extra)
                     + payload)

    def _error(self, writer, status: int, message: str,
               retry_after_s: float = 0.0) -> None:
        extra = (f"Retry-After: {max(1, int(np.ceil(retry_after_s)))}\r\n"
                 if status in (429, 503) else "")
        self._write_json(writer, status,
                         {"error": {"message": message,
                                    "type": _REASONS.get(status, ""),
                                    "code": status}}, extra=extra)

    async def _read_request(self, reader):
        line = await reader.readline()
        if not line:
            return None
        try:
            method, path, _ = line.decode("latin-1").split(None, 2)
        except ValueError:
            return None
        headers = {}
        while True:
            hline = await reader.readline()
            if hline in (b"\r\n", b"\n", b""):
                break
            if b":" in hline:
                k, v = hline.decode("latin-1").split(":", 1)
                headers[k.strip().lower()] = v.strip()
        length = int(headers.get("content-length", 0) or 0)
        if length > self.config.max_body_bytes:
            return method, path, headers, None
        body = await reader.readexactly(length) if length else b""
        return method, path, headers, body

    async def _handle(self, reader, writer) -> None:
        try:
            parsed = await self._read_request(reader)
            if parsed is None:
                return
            method, path, headers, body = parsed
            path, _, query = path.partition("?")
            if body is None:
                self._error(writer, 413, "request body too large")
            elif method == "GET" and path == "/healthz":
                alive = self._thread is not None and self._thread.is_alive()
                self._write_json(writer, 200 if alive else 503,
                                 {"status": "ok" if alive else "degraded",
                                  "step_thread_alive": alive})
            elif method == "GET" and path == "/statsz":
                self._write_json(writer, 200, self.stats())
            elif method == "GET" and path == "/metricsz":
                payload = self.metrics.render().encode()
                writer.write(self._head(
                    200, ctype="text/plain; version=0.0.4",
                    length=len(payload)) + payload)
            elif method == "GET" and path == "/tracez":
                doc = self.tracer.export()
                if "clear=1" in query.split("&"):
                    self.tracer.clear()
                self._write_json(writer, 200, doc)
            elif method == "GET" and path == "/v1/models":
                self._write_json(writer, 200, {
                    "object": "list",
                    "data": [{"id": self.config.model_id,
                              "object": "model", "owned_by": "repro"}]})
            elif method == "POST" and path == "/v1/completions":
                await self._handle_completion(reader, writer, headers,
                                              body)
            else:
                self._error(writer, 404, f"no route {method} {path}")
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        except Exception as e:                       # defensive: never 5xx
            try:                                     # with a dead socket
                self._error(writer, 500, f"{type(e).__name__}: {e}")
                await writer.drain()
            except (ConnectionError, RuntimeError):
                pass
        finally:
            try:
                writer.close()
            except RuntimeError:
                pass

    async def _handle_completion(self, reader, writer, headers,
                                 body: bytes) -> None:
        try:
            payload = json.loads(body.decode() or "{}")
        except (json.JSONDecodeError, UnicodeDecodeError):
            self._error(writer, 400, "request body is not valid JSON")
            return
        tenant = (headers.get("x-tenant") or payload.get("user")
                  or "default")
        req, msg = self._make_request(payload, str(tenant))
        if req is None:
            self._error(writer, 400, msg)
            return
        verdict = self._offer(req)
        if not verdict.admitted:
            self._error(writer, verdict.status, verdict.reason,
                        retry_after_s=verdict.retry_after_s)
            return
        stream = self._streams[req.request_id]
        if payload.get("stream", False):
            await self._stream_sse(reader, writer, stream)
        else:
            await self._respond_blocking(reader, writer, stream)

    def _chunk(self, stream: _Stream, text: str,
               finish_reason: Optional[str] = None,
               ralm: Optional[dict] = None) -> dict:
        choice = {"index": 0, "text": text, "finish_reason": finish_reason}
        out = {"id": f"cmpl-{stream.rid}", "object": "text_completion",
               "model": self.config.model_id, "choices": [choice]}
        if ralm is not None:
            out["ralm"] = ralm
        return out

    def _ralm_ext(self, stream: _Stream, summary: dict) -> dict:
        return dict(tenant=stream.tenant,
                    degrade_levels=summary["degrade_levels"],
                    ttft_ms=summary["ttft_ms"],
                    tpot_ms=summary["tpot_ms"],
                    queue_wait_ms=summary["queue_wait_ms"])

    def _disconnect(self, stream: _Stream) -> None:
        with self._lock:
            gone = self._streams.pop(stream.rid, None)
        if gone is not None:
            self.disconnects += 1
        self._cancels.append(stream.rid)
        self._work.set()

    async def _stream_sse(self, reader, writer, stream: _Stream) -> None:
        writer.write(self._head(200, ctype="text/event-stream",
                                extra="Cache-Control: no-cache\r\n"))
        await writer.drain()
        conn_watch = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                getter = asyncio.ensure_future(stream.queue.get())
                done, _ = await asyncio.wait(
                    {getter, conn_watch},
                    return_when=asyncio.FIRST_COMPLETED)
                if conn_watch in done:
                    data = conn_watch.result()
                    if data:                  # stray bytes: keep watching
                        conn_watch = asyncio.ensure_future(reader.read(1))
                        if getter not in done:
                            continue
                    else:                     # EOF: client went away
                        getter.cancel()
                        self._disconnect(stream)
                        return
                kind, *rest = getter.result()
                if kind == "tok":
                    _, tok = rest
                    writer.write(self._sse(self._chunk(stream, f" {tok}")))
                    try:
                        await writer.drain()
                    except ConnectionError:
                        self._disconnect(stream)
                        return
                else:                         # ("done", summary)
                    summary = rest[0]
                    reason = ("cancelled" if summary["cancelled"]
                              else "length")
                    writer.write(self._sse(self._chunk(
                        stream, "", finish_reason=reason,
                        ralm=self._ralm_ext(stream, summary))))
                    writer.write(b"data: [DONE]\n\n")
                    await writer.drain()
                    return
        finally:
            conn_watch.cancel()

    @staticmethod
    def _sse(obj: dict) -> bytes:
        return f"data: {json.dumps(obj)}\n\n".encode()

    async def _respond_blocking(self, reader, writer,
                                stream: _Stream) -> None:
        tokens: List[int] = []
        while True:
            kind, *rest = await stream.queue.get()
            if kind == "tok":
                tokens.append(rest[1])
                continue
            summary = rest[0]
            text = "".join(f" {t}" for t in tokens)
            out = self._chunk(
                stream, text,
                finish_reason=("cancelled" if summary["cancelled"]
                               else "length"),
                ralm=self._ralm_ext(stream, summary))
            out["usage"] = {
                "prompt_tokens": stream.prompt_tokens,
                "completion_tokens": len(tokens),
                "total_tokens": stream.prompt_tokens + len(tokens)}
            self._write_json(writer, 200, out)
            return

    # ------------------------------------------------------------------
    # stats + lifecycle
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        eng = self.engine
        out = dict(
            uptime_s=time.perf_counter() - self._t_start,
            completions=self.completions,
            cancelled=self.cancelled,
            disconnects=self.disconnects,
            tokens_out=self.tokens_out,
            scheduler=self.scheduler.queue_stats(),
        )
        with self._lock:
            out["admission"] = self.admission.stats()
        if self.policy is not None:
            out["degrade"] = self.policy.stats()
        if eng.pool is not None:
            ps = eng.pool.stats
            out["kv_pool"] = dict(capacity=eng.pool.capacity,
                                  used=eng.pool.num_used,
                                  high_water=ps.high_water,
                                  waves=ps.waves,
                                  decode_compiles=ps.decode_compiles,
                                  skip_fraction=ps.skip_fraction(),
                                  blocks_total=ps.blocks_total,
                                  blocks_skipped=ps.blocks_skipped)
        # degraded kernel routing must be visible in production, not
        # just under pytest: per-op pallas->ref fallback decisions
        out["kernels"] = dict(
            fallbacks=kernel_registry.fallback_counts(),
            fallback_total=kernel_registry.fallback_count())
        service = getattr(eng.retriever, "service", None)
        if service is not None:
            out["retrieval"] = service.stats.snapshot()
            replicas = getattr(service, "replicas", None)
            if replicas is not None:
                out["retrieval"]["fault"]["replicas"] = replicas.snapshot()
        straggler = getattr(self.scheduler, "straggler_events", None)
        if straggler is not None:
            out["scheduler"]["straggler_waves"] = straggler
        out["metrics"] = self.metrics.snapshot()
        return out

    async def start(self) -> str:
        """Bind + start serving on the running event loop; returns the
        base URL. Also starts the step-loop thread."""
        self._loop = asyncio.get_event_loop()
        self._server = await asyncio.start_server(
            self._handle, self.config.host, self.config.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._thread = threading.Thread(target=self._step_loop,
                                        name="gateway-step-loop",
                                        daemon=True)
        self._thread.start()
        return f"http://{self.config.host}:{self.port}"

    def start_background(self, timeout_s: float = 10.0) -> str:
        """Run the event loop on a dedicated thread (tests, benches,
        embedding the gateway next to other work). Returns the base
        URL once the socket is bound."""
        ready = threading.Event()
        url: List[str] = []

        def run():
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)

            async def main():
                url.append(await self.start())
                ready.set()
                async with self._server:
                    try:
                        await self._server.serve_forever()
                    except asyncio.CancelledError:
                        pass

            try:
                loop.run_until_complete(main())
            finally:
                loop.close()

        self._loop_thread = threading.Thread(target=run,
                                             name="gateway-http",
                                             daemon=True)
        self._loop_thread.start()
        if not ready.wait(timeout_s):
            raise RuntimeError("gateway failed to bind within "
                               f"{timeout_s}s")
        return url[0]

    def serve_forever(self) -> None:
        """Blocking entry point for launchers."""

        async def main():
            base = await self.start()
            print(f"[gateway] serving {self.config.model_id} at {base} "
                  f"(POST {base}/v1/completions)")
            async with self._server:
                await self._server.serve_forever()

        try:
            asyncio.run(main())
        except KeyboardInterrupt:
            pass
        finally:
            self.shutdown()

    def shutdown(self) -> None:
        self._stop.set()
        self._work.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
        loop, server = self._loop, self._server
        if loop is not None and server is not None and loop.is_running():
            loop.call_soon_threadsafe(server.close)
        thread = getattr(self, "_loop_thread", None)
        if thread is not None and loop is not None:
            # stop serve_forever() so the loop thread can exit
            for task in [t for t in (asyncio.all_tasks(loop)
                                     if loop.is_running() else [])]:
                loop.call_soon_threadsafe(task.cancel)
            thread.join(timeout=10.0)
