"""``repro.serve.gateway`` — the streaming HTTP front door.

Stdlib-only serving layer over ``RalmEngine``: OpenAI-compatible
``/v1/completions`` with SSE streaming, per-tenant admission control
(429/503 backpressure), and graceful retrieval-quality degradation
under load. See ``docs/serving.md`` ("The front door") for the tour::

    from repro.serve.gateway import Gateway, GatewayConfig

    gw = Gateway(engine, GatewayConfig(port=8000))
    gw.serve_forever()        # or gw.start_background() from tests
"""
from repro.serve.gateway.admission import (AdmissionController, TenantQuota,
                                           TokenBucket, Verdict)
from repro.serve.gateway.degrade import (DegradeConfig, DegradeLevel,
                                         DegradePolicy)
from repro.serve.gateway.server import Gateway, GatewayConfig

__all__ = [
    "AdmissionController", "DegradeConfig", "DegradeLevel",
    "DegradePolicy", "Gateway", "GatewayConfig", "TenantQuota",
    "TokenBucket", "Verdict",
]
