"""Graceful degradation: trade retrieval quality for serving capacity.

VectorLiteRAG (arXiv 2504.08930) makes the observation this module
encodes: when a RAG system saturates, the right first knob is not the
LM — it is retrieval *quality*. Scanning fewer IVF lists (``nprobe``)
cuts search cost almost linearly with a gentle recall slope, widening
the retrieval interval amortizes the search over more tokens, and in
extremis running the LM bare (kNN-off) sheds the whole retrieval tier.
All three preserve liveness: every admitted request still completes,
just with degraded augmentation — strictly better under overload than
unbounded queueing (RAGO's tail-latency lens) or hard-rejecting
already-admitted work.

``DegradePolicy`` owns a ladder of levels built from the engine's
baseline config:

    level 0   baseline                       (nprobe0, interval0, kNN on)
    level 1.. nprobe0/2, /4, ... min_nprobe  (cheaper scans)
    level  +1 interval0 * interval_factor    (retrieve less often)
    level  +1 partial-retrieval              (serve the live fault-domain
                                              subset, no hedges/retries —
                                              only with a fault-tolerant
                                              RetrievalService)
    level  +1 kNN off                        (rag.mode = "none")

The step loop calls ``observe(queue_depth)`` once per wave; sustained
pressure (``patience`` consecutive ticks above ``high_watermark``)
steps DOWN one level, sustained calm (``recovery`` ticks at or below
``low_watermark``) steps back UP one level. Hysteresis is deliberate:
the two watermarks plus the tick counts keep the policy from
oscillating on a bursty queue. Every transition is counted and
timestamped for /statsz and the load harness.

Applying a level mutates the live engine between waves (the policy
runs on the scheduler thread, so there is no race with a wave in
flight):

  * ``rag.interval`` / ``rag.mode`` — ``engine.rag`` is replaced
    (host-side arithmetic in ``_retrieval_due``; next wave sees it);
  * ``nprobe`` — the retriever pipeline's ``ChamVSConfig`` is replaced
    (it is a static jit argument, so each distinct level compiles its
    scan graph once, then hits the cache), and the service's query
    cache is dropped (cached results were produced at a different
    quality level).

Degradation is *system-wide and between-wave* by construction: all
rows of a wave share one coalesced scan dispatch, so quality is a
property of the wave, not the request. Requests served entirely inside
one level are greedy-reproducible in-process by pinning that level's
(nprobe, interval, mode) — the load harness exploits exactly that for
its parity check.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class DegradeLevel:
    """One rung: the complete retrieval-quality setting at this level."""
    name: str
    nprobe: int
    interval: int
    knn: bool                     # False = retrieval fully off
    partial: bool = False         # "partial-retrieval" rung: the
    #                               fault-tolerant dispatch gives every
    #                               domain ONE attempt and serves the
    #                               live subset — no hedges, retries, or
    #                               tail waits (needs service.replicas)

    def as_dict(self) -> Dict[str, object]:
        return dict(name=self.name, nprobe=self.nprobe,
                    interval=self.interval, knn=self.knn,
                    partial=self.partial)


@dataclasses.dataclass(frozen=True)
class DegradeConfig:
    """Ladder + hysteresis knobs."""
    high_watermark: int = 8       # queue depth that counts as pressure
    low_watermark: int = 1        # depth that counts as recovered
    patience: int = 3             # pressured ticks before stepping down
    recovery: int = 20            # calm ticks before stepping back up
    min_nprobe: int = 1           # floor of the nprobe rungs
    interval_factor: int = 4      # widen rag.interval by this much
    partial_rung: bool = True     # include the partial-retrieval rung
    #                               (skipped when the engine's service
    #                               has no fault-tolerant dispatch layer)
    knn_off_rung: bool = True     # include the final retrieval-off rung


class DegradePolicy:
    """Watches queue depth, walks the ladder, mutates the engine."""

    def __init__(self, engine, config: Optional[DegradeConfig] = None):
        self.engine = engine
        self.config = config or DegradeConfig()
        self._base_mode = engine.rag.mode   # restored on full recovery
        self.ladder = self._build_ladder()
        self.level = 0
        self._pressure_ticks = 0
        self._calm_ticks = 0
        # observability: every transition, plus aggregate counters
        self.transitions_down = 0
        self.transitions_up = 0
        self.ticks_at_level: List[int] = [0] * len(self.ladder)
        self.history: List[Dict[str, object]] = []

    # -- ladder construction ------------------------------------------------

    def _baseline(self) -> DegradeLevel:
        rag = self.engine.rag
        cfg = self._pipeline_cfg()
        return DegradeLevel(name="baseline",
                            nprobe=cfg.nprobe if cfg is not None else 0,
                            interval=max(1, rag.interval),
                            knn=rag.mode != "none")

    def _build_ladder(self) -> List[DegradeLevel]:
        base = self._baseline()
        ladder = [base]
        if not base.knn:              # engine already runs retrieval-free:
            return ladder             # nothing left to shed
        nprobe = base.nprobe
        while nprobe // 2 >= max(1, self.config.min_nprobe):
            nprobe //= 2
            ladder.append(DegradeLevel(
                name=f"nprobe/{base.nprobe // nprobe}", nprobe=nprobe,
                interval=base.interval, knn=True))
        widened = base.interval * self.config.interval_factor
        ladder.append(DegradeLevel(
            name=f"interval x{self.config.interval_factor}",
            nprobe=ladder[-1].nprobe, interval=widened, knn=True))
        if self.config.partial_rung and self._service_replicas():
            # cheaper than knn-off: keep retrieving, but serve whatever
            # fault domains answer on the first attempt (exact top-k
            # over the live subset) instead of hedging into the tail
            ladder.append(DegradeLevel(
                name="partial-retrieval", nprobe=ladder[-1].nprobe,
                interval=widened, knn=True, partial=True))
        if self.config.knn_off_rung:
            ladder.append(DegradeLevel(
                name="knn-off", nprobe=ladder[-1].nprobe,
                interval=widened, knn=False))
        return ladder

    # -- engine plumbing ----------------------------------------------------

    def _pipeline_cfg(self):
        """The live ``ChamVSConfig`` the searches run with, wherever the
        deployed retriever keeps it (service pipeline or local)."""
        ret = self.engine.retriever
        if ret is None:
            return None
        service = getattr(ret, "service", None)
        if service is not None:
            return service.pipeline.cfg
        return getattr(ret, "cfg", None)

    def _service(self):
        ret = self.engine.retriever
        return getattr(ret, "service", None) if ret is not None else None

    def _service_replicas(self) -> bool:
        """Whether the deployed service has the fault-tolerant dispatch
        layer (the partial-retrieval rung is meaningless without it)."""
        service = self._service()
        return service is not None and \
            getattr(service, "replicas", None) is not None

    def _set_nprobe(self, nprobe: int) -> None:
        ret = self.engine.retriever
        if ret is None or nprobe <= 0:
            return
        service = getattr(ret, "service", None)
        if service is not None:
            pipe = service.pipeline
            if pipe.cfg.nprobe != nprobe:
                pipe.cfg = dataclasses.replace(pipe.cfg, nprobe=nprobe)
                if service.cache is not None:
                    # cached neighbors were computed at another quality
                    # level; serving them fresh would silently undo the
                    # knob. A generation bump (not a drop) keeps them
                    # available as stale speculation seeds, which
                    # verification guards anyway.
                    mark = getattr(service, "mark_cache_stale", None)
                    if mark is not None:
                        mark()
                    else:  # pragma: no cover — pre-generation caches
                        service.cache = type(service.cache)(
                            service.config.cache_entries,
                            quant=service.config.cache_quant)
        elif getattr(ret, "cfg", None) is not None:
            if ret.cfg.nprobe != nprobe:
                ret.cfg = dataclasses.replace(ret.cfg, nprobe=nprobe)

    def apply(self, level_idx: int) -> None:
        """Point the engine at ``ladder[level_idx]`` (idempotent)."""
        level = self.ladder[level_idx]
        # a knn rung restores the baseline mode a deeper rung turned off
        new_mode = self._base_mode if level.knn else "none"
        rag = self.engine.rag
        changed = (rag.interval != level.interval or rag.mode != new_mode)
        cfg = self._pipeline_cfg()
        changed = changed or (cfg is not None and level.nprobe > 0
                              and cfg.nprobe != level.nprobe)
        service = self._service()
        if service is not None and \
                getattr(service, "_degraded_partial", False) != level.partial:
            changed = True
        if changed:
            # in-flight speculation points were issued under the OLD
            # quality: force-verify them with the math they speculated
            # under before any knob moves (getattr: test stubs pass
            # bare engine doubles)
            flush = getattr(self.engine, "flush_speculation", None)
            if flush is not None:
                flush()
        if rag.interval != level.interval or rag.mode != new_mode:
            self.engine.rag = dataclasses.replace(
                rag, interval=level.interval, mode=new_mode)
        self._set_nprobe(level.nprobe)
        if service is not None:
            set_partial = getattr(service, "set_degraded_partial", None)
            if set_partial is not None:
                set_partial(level.partial)

    # -- the per-wave tick --------------------------------------------------

    def observe(self, queue_depth: int,
                now: Optional[float] = None) -> bool:
        """One tick: account pressure/calm, maybe transition. Returns
        True when the level changed (the caller may want to log)."""
        self.ticks_at_level[self.level] += 1
        changed = False
        if queue_depth > self.config.high_watermark:
            self._pressure_ticks += 1
            self._calm_ticks = 0
            if (self._pressure_ticks >= self.config.patience
                    and self.level + 1 < len(self.ladder)):
                self.level += 1
                self.transitions_down += 1
                self._pressure_ticks = 0
                changed = True
        elif queue_depth <= self.config.low_watermark:
            self._calm_ticks += 1
            self._pressure_ticks = 0
            if self._calm_ticks >= self.config.recovery and self.level > 0:
                self.level -= 1
                self.transitions_up += 1
                self._calm_ticks = 0
                changed = True
        else:
            self._pressure_ticks = 0
            self._calm_ticks = 0
        if changed:
            self.apply(self.level)
            self.history.append(dict(
                t=time.perf_counter() if now is None else now,
                level=self.level, name=self.ladder[self.level].name,
                queue_depth=queue_depth))
            # getattr: test stubs pass bare engine doubles with no tracer
            tracer = getattr(self.engine, "tracer", None)
            if tracer is not None and tracer.enabled:
                tracer.instant(
                    "degrade.transition", "gateway",
                    args=dict(level=self.level,
                              name=self.ladder[self.level].name,
                              queue_depth=queue_depth))
        return changed

    def stats(self) -> Dict[str, object]:
        return dict(
            level=self.level,
            level_name=self.ladder[self.level].name,
            ladder=[lv.as_dict() for lv in self.ladder],
            transitions_down=self.transitions_down,
            transitions_up=self.transitions_up,
            ticks_at_level=list(self.ticks_at_level),
        )
