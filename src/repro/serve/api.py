"""The unified RALM serving surface (request/response types + the
``Retriever`` protocol).

Chameleon's system claim (paper §3) is that LM inference and vector
search are independent services behind a narrow boundary. This module is
that boundary as an API:

  * ``RalmRequest`` / ``RalmResponse`` — one generation request (a batch
    of prompts decoded in lockstep) and its result;
  * ``EngineConfig`` — everything needed to stand an engine up;
  * ``Retriever`` — the two-method protocol every retrieval service
    implements: ``search(queries) -> (dists, ids)`` (paper steps 1-8) and
    ``resolve(ids) -> payload`` (paper step 9, the vector-ID -> payload
    conversion, with missing-id masking folded in so no caller ever
    re-implements it);
  * ``LocalRetriever`` — single-process ChamVS (tests, examples, builds);
  * ``DistributedRetriever`` — ChamVS routed over a retrieval mesh (the
    paper's disaggregated memory nodes) via ``retrieval.ShardRouter``,
    including the sharded payload gather;
  * ``AsyncRetriever`` — the service-backed implementation: queries go
    through a ``repro.retrieval.RetrievalService``, so concurrent
    sequences' searches coalesce into one batched kernel dispatch and
    ``search_async`` returns a ``SearchHandle`` the scheduler can hold
    while decoding the next wave.

Everything in ``repro.serve`` speaks only this protocol; monolithic and
disaggregated deployments differ solely in which implementation is
plugged in.
"""
from __future__ import annotations

import dataclasses
import time
from typing import (Callable, List, Optional, Protocol, Tuple,
                    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import chamvs as chamvs_lib
from repro.core import rag as rag_lib
from repro.core.chamvs import ChamVSConfig
from repro.core.ivfpq import IVFPQParams, IVFPQShard
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig
from repro.retrieval.router import ShardRouter
from repro.retrieval.service import RetrievalService, SearchHandle


# ---------------------------------------------------------------------------
# request / response / config
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestTiming:
    """Wall-clock milestones of one request's serving lifetime, stamped
    by the scheduler/engine as the request moves through the system
    (``time.perf_counter()`` seconds — deltas are meaningful, absolutes
    are not):

      * ``arrival``     — entered the system (``submit()``, or earlier:
        the HTTP gateway stamps it at request parse, before admission
        control, so queueing under backpressure is visible);
      * ``admit``       — claimed KV slots + prefilled (``engine.start``);
      * ``first_token`` — first generated token materialized on the host
        (TTFT = first_token - arrival);
      * ``finish``      — final token emitted (TPOT = (finish -
        first_token) / (steps - 1) for steps > 1).
    """
    arrival: Optional[float] = None
    admit: Optional[float] = None
    first_token: Optional[float] = None
    finish: Optional[float] = None

    def ttft_s(self) -> Optional[float]:
        if self.arrival is None or self.first_token is None:
            return None
        return self.first_token - self.arrival

    def tpot_s(self, steps: int) -> Optional[float]:
        if self.first_token is None or self.finish is None or steps < 2:
            return None
        return (self.finish - self.first_token) / (steps - 1)


@dataclasses.dataclass
class RalmRequest:
    """One serving request: a prompt batch decoded in lockstep.

    ``trace``: optional list collecting per-step dicts (retrieved ids
    etc.) for benchmarks and tests, same contract as the old
    ``generate(..., trace=)``.

    ``tenant`` names the submitting client class for per-tenant
    admission accounting (quotas, fair dequeue, queue-depth stats) —
    purely an accounting label, it never changes the math.

    ``on_token`` is the streaming hook: called as ``on_token(step,
    tokens)`` with the host-materialized ``[B]`` int array of the
    step's sampled tokens, from the thread running the scheduler, the
    moment the step's wave completes. Setting it costs one host sync
    per wave (the tokens must leave the device), so leave it ``None``
    for throughput-only workloads.

    ``cancelled`` aborts the request at the next scheduler step (slots
    are released, the response is flagged); flip it via
    ``RalmScheduler.cancel`` — e.g. the gateway on a mid-stream client
    disconnect."""
    prompt: jnp.ndarray                  # [B, T0] int32
    steps: int
    greedy: bool = True
    rng: Optional[jax.Array] = None
    trace: Optional[list] = None
    request_id: Optional[int] = None     # assigned at submit()
    trace_id: Optional[int] = None       # observability flow id: defaults
    #                                      to request_id at submit(); links
    #                                      this request's spans/flow events
    #                                      across tracks in the trace
    tenant: str = "default"
    on_token: Optional[Callable[[int, np.ndarray], None]] = None
    cancelled: bool = False
    times: RequestTiming = dataclasses.field(default_factory=RequestTiming)
    partial_steps: int = 0               # decode steps served from a
    #                                      partial (live-subset) retrieval
    #                                      result — the per-request quality
    #                                      accounting of fault degradation


@dataclasses.dataclass
class RalmResponse:
    request_id: int
    tokens: np.ndarray                   # [B, T0 + steps]
    steps: int
    trace: Optional[list] = None
    tenant: str = "default"
    cancelled: bool = False
    times: Optional[RequestTiming] = None
    partial_steps: int = 0               # steps decoded on partial
    #                                      retrieval results (0 = full
    #                                      quality throughout)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Deployment shape of one RALM engine (the Fig. 13 knobs)."""
    model: ModelConfig
    rag: RagConfig
    max_seq: Optional[int] = None        # KV budget; default T0 + steps
    disaggregate: bool = False           # split devices into two pools
    lm_devices: int = 1                  # LM pool size (disaggregated)
    ret_devices: int = 1                 # retrieval pool size (")
    max_active: Optional[int] = None     # scheduler admission limit
    async_retrieval: bool = False        # route search through a
    #                                      RetrievalService (AsyncRetriever)
    retrieval_cache: int = 0             # service LRU cache entries (0=off)
    speculate_k: int = 0                 # speculative retrieval depth: max
    #                                      speculation points a sequence
    #                                      keeps outstanding (0 = off). A
    #                                      due row decodes ahead on its
    #                                      previous (stale) neighbors
    #                                      while the real search runs
    #                                      async; verification happens
    #                                      speculate_k waves later, off
    #                                      the critical path. Requires
    #                                      async_retrieval + wave_decode.
    speculate_verify: bool = True        # verify speculated tokens against
    #                                      the real neighbors and roll
    #                                      back on mismatch (greedy
    #                                      parity with speculation off).
    #                                      False trusts stale neighbors
    #                                      outright — bounded quality
    #                                      drift for zero rollback cost
    retrieval_measure: bool = True       # per-stage service timings; False
    #                                      drops the per-flush host blocks
    #                                      for maximum decode/search overlap
    wave_decode: bool = True             # one LM dispatch per wave over a
    #                                      slotted KVCachePool; False keeps
    #                                      the per-sequence oracle loop
    kv_slots: Optional[int] = None       # KV pool capacity in prompt rows;
    #                                      None = grow on demand, fixed
    #                                      values defer admission until
    #                                      completions free slots
    kernel_backend: Optional[str] = None  # override ChamVSConfig.backend
    #                                      ("ref" | "pallas") from the
    #                                      deployment config
    kernel_interpret: Optional[bool] = None  # override Pallas interpret
    #                                      mode (CPU containers need True)
    kernel_fused: Optional[bool] = None  # override ChamVSConfig.fused:
    #                                      ONE chamvs_scan dispatch per
    #                                      retrieval wave (True) vs the
    #                                      staged per-shard oracle (False)
    attn_backend: Optional[str] = None   # wave decode-attention kernel:
    #                                      None/"ref" = grouped einsum
    #                                      over the KV-head axis (CPU
    #                                      serving flavor), "pallas" =
    #                                      the streaming decode_attn
    #                                      kernel, "einsum" = the legacy
    #                                      full-materialization oracle
    attn_interpret: Optional[bool] = None  # Pallas interpret mode for
    #                                      the decode-attn kernel (CPU
    #                                      containers need True)
    trace: bool = False                  # enable the observability
    #                                      tracer (repro.obs): per-request
    #                                      spans across scheduler waves,
    #                                      retrieval stages, KV pool and
    #                                      kernels, exported as Chrome
    #                                      trace-event JSON
    trace_path: Optional[str] = None     # where RalmEngine.write_trace()
    #                                      saves the trace by default
    retrieval_deadline_s: float = 0.0    # per-dispatch retrieval latency
    #                                      budget: a fault domain still
    #                                      unresolved past it is dropped
    #                                      and the flush serves the exact
    #                                      top-k over the survivors
    #                                      (0 = wait indefinitely)
    hedge_quantile: float = 0.95         # latency quantile after which a
    #                                      hung dispatch is hedged to
    #                                      another replica
    shard_replicas: int = 1              # dispatch-target replicas per
    #                                      retrieval fault domain; > 1 (or
    #                                      a deadline/chaos plan) arms the
    #                                      fault-tolerant dispatch layer
    chaos_plan: Optional[str] = None     # path to a FaultPlan JSON to arm
    #                                      at the service's scan boundary
    #                                      (deterministic fault injection)
    attn_seq_block: int = 16             # KV-pool seq-axis alignment:
    #                                      per-wave attention reads crop
    #                                      to this quantum (kv_len), so
    #                                      ragged waves skip the pool's
    #                                      max_seq padding; bounds the
    #                                      extra decode-graph variants
    #                                      at max_seq / attn_seq_block


# ---------------------------------------------------------------------------
# the Retriever protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class Retriever(Protocol):
    """What the engine needs from a retrieval service — nothing more.

    ``resolve`` owns missing-id masking: ids < 0 come back as -1 tokens
    (kind="tokens") or PAD-0 chunks (kind="chunks"), so the decode loop
    never inspects ids itself."""

    def search(self, queries: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """[B, d] queries -> (dists [B, K], global ids [B, K])."""
        ...

    def resolve(self, ids: jnp.ndarray, kind: str = "tokens"
                ) -> jnp.ndarray:
        """[B, K] ids -> payload: next-tokens [B, K] (kNN-LM) or chunks
        [B, K, chunk_len] (RETRO), masked for missing ids."""
        ...


def _resolve_from_tables(payload_tokens, chunk_table, ids, kind,
                         gather=rag_lib.gather_payload):
    """Shared resolve() body: gather from the right table, mask missing
    ids exactly once (the old loops each re-implemented this)."""
    if kind == "tokens":
        if payload_tokens is None:
            raise ValueError("retriever has no payload_tokens table")
        toks = gather(payload_tokens, ids)
        return jnp.where(ids >= 0, toks, -1)
    if kind == "chunks":
        if chunk_table is None:
            raise ValueError("retriever has no chunk_table")
        chunks = gather(chunk_table, ids)
        return jnp.where((ids >= 0)[..., None], chunks, 0)
    raise ValueError(f"unknown payload kind: {kind!r}")


@dataclasses.dataclass
class LocalRetriever:
    """Single-process ChamVS over a list of shards (tests, examples,
    datastore builds). Field layout is the old ``RetrievalEngine``'s, so
    existing constructors keep working through the compat shim."""
    params: IVFPQParams
    shards: List[IVFPQShard]
    cfg: ChamVSConfig
    payload_tokens: Optional[jnp.ndarray] = None   # [N] next-token table
    chunk_table: Optional[jnp.ndarray] = None      # [N, chunk_len]
    query_proj: Optional[jnp.ndarray] = None       # [d_model, dq]

    def search(self, queries: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        q = queries.astype(jnp.float32)
        if self.query_proj is not None:
            q = q @ self.query_proj
        return chamvs_lib.search_single(self.params, self.shards, q,
                                        self.cfg)

    def resolve(self, ids: jnp.ndarray, kind: str = "tokens"
                ) -> jnp.ndarray:
        return _resolve_from_tables(self.payload_tokens, self.chunk_table,
                                    ids, kind)


class DistributedRetriever:
    """ChamVS over a retrieval mesh, routed by a ``ShardRouter``: the
    router owns shard/table placement, the in-graph broadcast + scan +
    merge for the query path, and the sharded payload gather (no host
    round-trip and no full-table all-gather — see ``build_gather``'s
    docstring)."""

    def __init__(self, mesh: Mesh, params: IVFPQParams,
                 shards: List[IVFPQShard], cfg: ChamVSConfig,
                 payload_tokens: Optional[jnp.ndarray] = None,
                 chunk_table: Optional[jnp.ndarray] = None,
                 query_proj: Optional[jnp.ndarray] = None,
                 db_axes: Tuple[str, ...] = ("data",),
                 query_axis: Optional[str] = None):
        self.mesh, self.cfg = mesh, cfg
        self.query_proj = query_proj
        self.router = ShardRouter(mesh, cfg, db_axes=db_axes,
                                  query_axis=query_axis)
        self.db_params = self.router.place_params(params)
        self.db_shard = self.router.place_shards(shards)
        self.payload_tokens = self.router.place_table(payload_tokens)
        self.chunk_table = self.router.place_table(chunk_table)

    def search(self, queries: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        q = jnp.asarray(queries, jnp.float32)
        if self.query_proj is not None:
            q = q @ self.query_proj
        return self.router.search(self.db_params, self.db_shard, q)

    def resolve(self, ids: jnp.ndarray, kind: str = "tokens"
                ) -> jnp.ndarray:
        def gather(table, ids):
            return self.router.gather(table, jnp.maximum(ids, 0))
        return _resolve_from_tables(self.payload_tokens, self.chunk_table,
                                    ids, kind, gather=gather)


@dataclasses.dataclass
class AsyncRetriever:
    """``Retriever`` backed by a ``repro.retrieval.RetrievalService``.

    ``search`` keeps the synchronous protocol (submit + flush + result);
    the extra surface is what the scheduler exploits:

      * ``search_async(queries) -> SearchHandle`` — enqueue without
        dispatching, so queries from every sequence in a wave coalesce;
      * ``flush()`` — run the coalesced batch as one kernel dispatch.

    Payload resolution is table-local like ``LocalRetriever``'s."""
    service: RetrievalService
    payload_tokens: Optional[jnp.ndarray] = None   # [N] next-token table
    chunk_table: Optional[jnp.ndarray] = None      # [N, chunk_len]
    query_proj: Optional[jnp.ndarray] = None       # [d_model, dq]

    def _project(self, queries: jnp.ndarray) -> jnp.ndarray:
        q = jnp.asarray(queries, jnp.float32)
        if self.query_proj is not None:
            q = q @ self.query_proj
        return q

    def search_async(self, queries: jnp.ndarray) -> SearchHandle:
        return self.service.submit(self._project(queries))

    def stale_lookup(self, queries: jnp.ndarray):
        """Any-generation cache probe: possibly-stale neighbors to seed
        speculative decode (None on a miss or without a cache)."""
        return self.service.stale_lookup(self._project(queries))

    def flush(self) -> None:
        self.service.flush()

    def search(self, queries: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.search_async(queries).result()

    def resolve(self, ids: jnp.ndarray, kind: str = "tokens"
                ) -> jnp.ndarray:
        if not self.service.config.measure:
            return _resolve_from_tables(self.payload_tokens,
                                        self.chunk_table, ids, kind)
        t0 = time.perf_counter()
        with self.service.tracer.span("retrieval.gather", "retrieval"):
            out = _resolve_from_tables(self.payload_tokens,
                                       self.chunk_table, ids, kind)
            jax.block_until_ready(out)
        self.service.stats.gather.add(time.perf_counter() - t0)
        return out
