"""The unified RALM serving surface (request/response types + the
``Retriever`` protocol).

Chameleon's system claim (paper §3) is that LM inference and vector
search are independent services behind a narrow boundary. This module is
that boundary as an API:

  * ``RalmRequest`` / ``RalmResponse`` — one generation request (a batch
    of prompts decoded in lockstep) and its result;
  * ``EngineConfig`` — everything needed to stand an engine up;
  * ``Retriever`` — the two-method protocol every retrieval service
    implements: ``search(queries) -> (dists, ids)`` (paper steps 1-8) and
    ``resolve(ids) -> payload`` (paper step 9, the vector-ID -> payload
    conversion, with missing-id masking folded in so no caller ever
    re-implements it);
  * ``LocalRetriever`` — single-process ChamVS (tests, examples, builds);
  * ``DistributedRetriever`` — ChamVS ``shard_map``-ed over a retrieval
    mesh (the paper's disaggregated memory nodes), including the
    sharded payload gather.

Everything in ``repro.serve`` speaks only this protocol; monolithic and
disaggregated deployments differ solely in which implementation is
plugged in.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import use_mesh
from repro.core import chamvs as chamvs_lib
from repro.core import rag as rag_lib
from repro.core.chamvs import ChamVSConfig
from repro.core.ivfpq import IVFPQParams, IVFPQShard
from repro.core.rag import RagConfig
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# request / response / config
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RalmRequest:
    """One serving request: a prompt batch decoded in lockstep.

    ``trace``: optional list collecting per-step dicts (retrieved ids
    etc.) for benchmarks and tests, same contract as the old
    ``generate(..., trace=)``."""
    prompt: jnp.ndarray                  # [B, T0] int32
    steps: int
    greedy: bool = True
    rng: Optional[jax.Array] = None
    trace: Optional[list] = None
    request_id: Optional[int] = None     # assigned at submit()


@dataclasses.dataclass
class RalmResponse:
    request_id: int
    tokens: np.ndarray                   # [B, T0 + steps]
    steps: int
    trace: Optional[list] = None


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Deployment shape of one RALM engine (the Fig. 13 knobs)."""
    model: ModelConfig
    rag: RagConfig
    max_seq: Optional[int] = None        # KV budget; default T0 + steps
    disaggregate: bool = False           # split devices into two pools
    lm_devices: int = 1                  # LM pool size (disaggregated)
    ret_devices: int = 1                 # retrieval pool size (")
    max_active: Optional[int] = None     # scheduler admission limit


# ---------------------------------------------------------------------------
# the Retriever protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class Retriever(Protocol):
    """What the engine needs from a retrieval service — nothing more.

    ``resolve`` owns missing-id masking: ids < 0 come back as -1 tokens
    (kind="tokens") or PAD-0 chunks (kind="chunks"), so the decode loop
    never inspects ids itself."""

    def search(self, queries: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """[B, d] queries -> (dists [B, K], global ids [B, K])."""
        ...

    def resolve(self, ids: jnp.ndarray, kind: str = "tokens"
                ) -> jnp.ndarray:
        """[B, K] ids -> payload: next-tokens [B, K] (kNN-LM) or chunks
        [B, K, chunk_len] (RETRO), masked for missing ids."""
        ...


def _resolve_from_tables(payload_tokens, chunk_table, ids, kind,
                         gather=rag_lib.gather_payload):
    """Shared resolve() body: gather from the right table, mask missing
    ids exactly once (the old loops each re-implemented this)."""
    if kind == "tokens":
        if payload_tokens is None:
            raise ValueError("retriever has no payload_tokens table")
        toks = gather(payload_tokens, ids)
        return jnp.where(ids >= 0, toks, -1)
    if kind == "chunks":
        if chunk_table is None:
            raise ValueError("retriever has no chunk_table")
        chunks = gather(chunk_table, ids)
        return jnp.where((ids >= 0)[..., None], chunks, 0)
    raise ValueError(f"unknown payload kind: {kind!r}")


@dataclasses.dataclass
class LocalRetriever:
    """Single-process ChamVS over a list of shards (tests, examples,
    datastore builds). Field layout is the old ``RetrievalEngine``'s, so
    existing constructors keep working through the compat shim."""
    params: IVFPQParams
    shards: List[IVFPQShard]
    cfg: ChamVSConfig
    payload_tokens: Optional[jnp.ndarray] = None   # [N] next-token table
    chunk_table: Optional[jnp.ndarray] = None      # [N, chunk_len]
    query_proj: Optional[jnp.ndarray] = None       # [d_model, dq]

    def search(self, queries: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        q = queries.astype(jnp.float32)
        if self.query_proj is not None:
            q = q @ self.query_proj
        return chamvs_lib.search_single(self.params, self.shards, q,
                                        self.cfg)

    def resolve(self, ids: jnp.ndarray, kind: str = "tokens"
                ) -> jnp.ndarray:
        return _resolve_from_tables(self.payload_tokens, self.chunk_table,
                                    ids, kind)


class DistributedRetriever:
    """ChamVS over a retrieval mesh: ``make_distributed_search`` for the
    query path and ``make_distributed_gather`` for payload resolution
    (both tables sharded over ``db_axes``, so no host round-trip and no
    full-table all-gather — see ``make_distributed_gather``'s docstring).
    """

    def __init__(self, mesh: Mesh, params: IVFPQParams,
                 shards: List[IVFPQShard], cfg: ChamVSConfig,
                 payload_tokens: Optional[jnp.ndarray] = None,
                 chunk_table: Optional[jnp.ndarray] = None,
                 query_proj: Optional[jnp.ndarray] = None,
                 db_axes: Tuple[str, ...] = ("data",),
                 query_axis: Optional[str] = None):
        self.mesh, self.cfg = mesh, cfg
        self.query_proj = query_proj
        num_shards = 1
        for a in db_axes:
            if a in mesh.axis_names:
                num_shards *= mesh.shape[a]
        assert len(shards) == num_shards, \
            f"one shard per memory node: {len(shards)} vs {num_shards}"
        stacked = chamvs_lib.stack_shards(shards)
        self.db_params = jax.device_put(params, NamedSharding(mesh, P()))
        self.db_shard = jax.device_put(
            stacked, NamedSharding(mesh, P(db_axes)))
        self._search = jax.jit(chamvs_lib.make_distributed_search(
            mesh, cfg, db_axes=db_axes, query_axis=query_axis))
        self._gather = jax.jit(
            chamvs_lib.make_distributed_gather(mesh, db_axes))
        self.payload_tokens = self._shard_table(payload_tokens, num_shards,
                                                db_axes)
        self.chunk_table = self._shard_table(chunk_table, num_shards,
                                             db_axes)

    def _shard_table(self, table, num_shards: int, db_axes):
        """Place a payload table across the memory nodes (pad the trailing
        rows so every node holds an equal slice; padded rows are never
        addressed because ids < N)."""
        if table is None:
            return None
        n = table.shape[0]
        rem = (-n) % num_shards
        if rem:
            pad = [(0, rem)] + [(0, 0)] * (table.ndim - 1)
            table = jnp.pad(table, pad)
        return jax.device_put(
            table, NamedSharding(self.mesh, P(db_axes)))

    def search(self, queries: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        q = jnp.asarray(queries, jnp.float32)
        if self.query_proj is not None:
            q = q @ self.query_proj
        with use_mesh(self.mesh):
            return self._search(self.db_params, self.db_shard, q)

    def resolve(self, ids: jnp.ndarray, kind: str = "tokens"
                ) -> jnp.ndarray:
        def gather(table, ids):
            with use_mesh(self.mesh):
                return self._gather(table, jnp.maximum(ids, 0))
        return _resolve_from_tables(self.payload_tokens, self.chunk_table,
                                    ids, kind, gather=gather)
