"""``DatastoreBuilder`` — the one place an IVF-PQ datastore is built.

The train-quantizers / build-shards / keep-payload-tables recipe used to
be copy-pasted (with drifting hyperparameters) across
``launch/serve.py``, ``examples/serve_ralm.py``,
``examples/quickstart.py`` and the system-test fixture. It lives here
now, in two flavors:

  * ``build(vectors, ...)`` — index an explicit vector set (quickstart,
    ANN benchmarks);
  * ``from_corpus(params, cfg, corpus, ...)`` — the kNN-LM datastore:
    run the LM over a token corpus and index *its own hidden states*,
    each keyed to the next token (paper §2.1, Khandelwal et al.).

The result is a ``Datastore`` that hands out ``Retriever``
implementations for either deployment shape.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.chamvs import ChamVSConfig
from repro.core.ivfpq import (IVFPQConfig, IVFPQParams, IVFPQShard,
                              build_shards, train_ivfpq)
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.retrieval.service import RetrievalService, ServiceConfig
from repro.serve.api import (AsyncRetriever, DistributedRetriever,
                             LocalRetriever)


@dataclasses.dataclass
class Datastore:
    """A built index + its payload tables."""
    params: IVFPQParams
    shards: List[IVFPQShard]
    index_cfg: IVFPQConfig
    payload_tokens: Optional[jnp.ndarray] = None   # [N] next-token table
    chunk_table: Optional[jnp.ndarray] = None      # [N, chunk_len]
    num_vectors: int = 0

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def search_config(self, nprobe: int = 32, k: int = 100,
                      backend: str = "ref", **kw) -> ChamVSConfig:
        return ChamVSConfig(ivfpq=self.index_cfg, nprobe=nprobe, k=k,
                            backend=backend, **kw)

    def retriever(self, search_cfg: ChamVSConfig,
                  query_proj: Optional[jnp.ndarray] = None
                  ) -> LocalRetriever:
        """Single-process ``Retriever`` over this datastore."""
        return LocalRetriever(params=self.params, shards=self.shards,
                              cfg=search_cfg,
                              payload_tokens=self.payload_tokens,
                              chunk_table=self.chunk_table,
                              query_proj=query_proj)

    def async_retriever(self, search_cfg: ChamVSConfig,
                        query_proj: Optional[jnp.ndarray] = None,
                        service_cfg: Optional[ServiceConfig] = None
                        ) -> AsyncRetriever:
        """Service-backed ``Retriever``: searches go through a
        ``RetrievalService`` (micro-batching + futures + optional result
        cache), so the scheduler coalesces concurrent sequences' queries
        into one batched kernel dispatch."""
        service = RetrievalService.local(self.params, self.shards,
                                         search_cfg, config=service_cfg)
        return AsyncRetriever(service=service,
                              payload_tokens=self.payload_tokens,
                              chunk_table=self.chunk_table,
                              query_proj=query_proj)

    def distributed_retriever(self, mesh: Mesh, search_cfg: ChamVSConfig,
                              query_proj: Optional[jnp.ndarray] = None,
                              db_axes: Tuple[str, ...] = ("data",)
                              ) -> DistributedRetriever:
        """``Retriever`` with the shards laid out over ``mesh`` (one
        memory node per device along ``db_axes``)."""
        return DistributedRetriever(
            mesh, self.params, self.shards, search_cfg,
            payload_tokens=self.payload_tokens,
            chunk_table=self.chunk_table, query_proj=query_proj,
            db_axes=db_axes)


@dataclasses.dataclass
class DatastoreBuilder:
    """Hyperparameters of the build, with the defaults the old call
    sites converged on. ``m=None`` derives the PQ sub-quantizer count
    from the dimension (``dim // 16``, floor 4)."""
    dim: int
    nlist: int = 8
    m: Optional[int] = None
    list_cap: int = 1024
    residual: bool = False
    num_shards: int = 2
    kmeans_iters: int = 8
    seed: int = 1

    def index_config(self) -> IVFPQConfig:
        m = self.m if self.m is not None else max(self.dim // 16, 4)
        return IVFPQConfig(dim=self.dim, nlist=self.nlist, m=m,
                           list_cap=self.list_cap, residual=self.residual)

    def build(self, vectors: np.ndarray,
              payload_tokens: Optional[jnp.ndarray] = None,
              chunk_table: Optional[jnp.ndarray] = None,
              train_vectors: Optional[np.ndarray] = None) -> Datastore:
        """Train quantizers (on ``train_vectors`` if given, else on the
        full set) and shard the database over ``num_shards`` memory
        nodes (partition scheme 1: every IVF list striped across all
        shards)."""
        vectors = np.asarray(vectors, np.float32)
        train = vectors if train_vectors is None else np.asarray(
            train_vectors, np.float32)
        icfg = self.index_config()
        params = train_ivfpq(jax.random.PRNGKey(self.seed),
                             jnp.asarray(train), icfg,
                             kmeans_iters=self.kmeans_iters)
        shards = build_shards(params, vectors, icfg,
                              num_shards=self.num_shards)
        return Datastore(
            params=params, shards=shards, index_cfg=icfg,
            payload_tokens=None if payload_tokens is None
            else jnp.asarray(payload_tokens),
            chunk_table=None if chunk_table is None
            else jnp.asarray(chunk_table),
            num_vectors=vectors.shape[0])

    # ------------------------------------------------------------------
    @staticmethod
    def corpus_keys(params, cfg: ModelConfig, corpus: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """kNN-LM keys: the LM's hidden state at every prefix of
        ``corpus`` [n_docs, doc_len], paired with the next token.
        Returns (keys [N, d_model], next_tokens [N])."""
        corpus = np.asarray(corpus, np.int32)
        _, _, hidden = tf.forward(params, cfg, tokens=jnp.asarray(corpus),
                                  mode="train", return_hidden=True)
        keys = np.asarray(hidden[:, :-1].astype(jnp.float32)).reshape(
            -1, cfg.d_model)
        nxt = corpus[:, 1:].reshape(-1)
        return keys, nxt

    def from_corpus(self, params, cfg: ModelConfig, corpus: np.ndarray
                    ) -> Datastore:
        """Build the kNN-LM datastore from the model's own hidden states
        over ``corpus`` (the flow every serving entry point used to
        hand-roll)."""
        assert self.dim == cfg.d_model, (self.dim, cfg.d_model)
        keys, nxt = self.corpus_keys(params, cfg, corpus)
        return self.build(keys, payload_tokens=jnp.asarray(nxt))
