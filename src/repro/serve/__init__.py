"""``repro.serve`` — the unified RALM serving API.

One ``Retriever`` protocol, one generation loop, pluggable
monolithic/disaggregated backends::

    from repro.serve import (DatastoreBuilder, RagConfig, RalmEngine)

    ds = DatastoreBuilder(dim=cfg.d_model).from_corpus(params, cfg, corpus)
    engine = RalmEngine.monolithic(
        params, cfg, rag, retriever=ds.retriever(ds.search_config(k=8)))
    tokens = engine.generate(prompt, steps=8)

See ``docs/serving.md`` for the API tour and the migration table from
the old entry points.
"""
from repro.core.rag import RagConfig
from repro.retrieval.service import (RetrievalService, SearchHandle,
                                     ServiceConfig)
from repro.serve.api import (AsyncRetriever, DistributedRetriever,
                             EngineConfig, LocalRetriever, RalmRequest,
                             RalmResponse, Retriever)
from repro.serve.datastore import Datastore, DatastoreBuilder
from repro.serve.engine import (DisaggregatedBackend, MonolithicBackend,
                                PoolTimes, RalmEngine, SequenceState)
from repro.serve.gateway import (AdmissionController, DegradeConfig,
                                 DegradePolicy, Gateway, GatewayConfig,
                                 TenantQuota)
from repro.serve.kvpool import KVCachePool, PoolStats
from repro.serve.scheduler import RalmScheduler

__all__ = [
    "AdmissionController", "AsyncRetriever", "Datastore",
    "DatastoreBuilder", "DegradeConfig", "DegradePolicy",
    "DisaggregatedBackend", "DistributedRetriever", "EngineConfig",
    "Gateway", "GatewayConfig", "KVCachePool", "LocalRetriever",
    "MonolithicBackend", "PoolStats", "PoolTimes", "RagConfig",
    "RalmEngine", "RalmRequest", "RalmResponse", "RalmScheduler",
    "RetrievalService", "Retriever", "SearchHandle", "SequenceState",
    "ServiceConfig", "TenantQuota",
]
