"""``RalmEngine`` — the single generation loop behind every entry point.

The decode -> retrieve -> interpolate -> sample step used to live in two
divergent copies (``core/generate.py`` and ``core/coordinator.py``; they
even disagreed on the step-0 retrieval query). It now lives here once,
split into the two phases the scheduler pipelines:

  * ``dispatch_decode(seq)`` — advance the LM one token (async dispatch);
  * ``finish_step(seq, ...)`` — retrieval + kNN-LM interpolation / RETRO
    re-encode + sampling.

Backends own the decode side of the boundary:

  * ``MonolithicBackend`` — one mesh / the default devices; decode and
    search share hardware (the paper's GPU-only baseline);
  * ``DisaggregatedBackend`` — the paper's split: an LM pool and a
    retrieval pool with independent meshes, plus ``PoolTimes`` measuring
    the per-pool step times that give the Fig. 13 optimal-ratio estimate.

Decode has two shapes. The default (``wave=True``) runs over a
``KVCachePool``: every active sequence's rows live in pooled cache
slots, and ``decode_wave`` advances the whole wave as ONE dispatch
(``tokens [W], slots [W], positions [W]``, W bucketed to powers of two
like the retrieval service's query batches). kNN interpolation and
greedy sampling batch the same way. The per-sequence path
(``wave=False``) is kept as the parity oracle — greedy outputs must be
token-identical between the two, including staggered admission and
ragged prompt lengths (tests/test_kvpool.py).

Retrieval is any object satisfying ``api.Retriever``; the engine never
looks past ``search``/``resolve``.

Step-0 correctness note: the first retrieval query is the *prefill*'s
last-position hidden state (exactly what the decode step would have
produced), so monolithic and disaggregated runs are token-identical
under greedy decoding — the old loops disagreed here (embedding
stand-in vs re-decoding the last prompt token).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import use_mesh
from repro.core import rag as rag_lib
from repro.kernels import registry
from repro.core.chamvs import ChamVSConfig
from repro.core.ivfpq import IVFPQParams, IVFPQShard
from repro.core.rag import RagConfig
from repro.launch.mesh import make_mesh_for
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serve.api import (DistributedRetriever, EngineConfig,
                             RalmRequest, RalmResponse, Retriever)
from repro.serve.kvpool import KVCachePool, next_pow2
from repro.serve.scheduler import RalmScheduler


@dataclasses.dataclass
class PoolTimes:
    """Per-pool step times (paper Fig. 13 instrumentation)."""
    decode_s: List[float] = dataclasses.field(default_factory=list)
    search_s: List[float] = dataclasses.field(default_factory=list)

    def optimal_ratio(self) -> float:
        """Paper Fig. 13: LM-pool units needed to saturate one retrieval
        engine = (retrieval throughput) / (decode throughput) per batch."""
        if not self.decode_s or not self.search_s:
            return float("nan")
        return float(np.median(self.decode_s) / np.median(self.search_s))


# ---------------------------------------------------------------------------
# decode backends
# ---------------------------------------------------------------------------

def _prefill(params, cfg: ModelConfig, rag: RagConfig,
             prompt: jnp.ndarray, max_seq: int):
    """Consume the prompt. Returns (caches, enc_states, last_logits [B,V],
    last_hidden [B,d]) — the hidden state at the last prompt position is
    the step-0 retrieval query."""
    B, T0 = prompt.shape
    caches = tf.init_cache(cfg, B, max_seq=max_seq, enc_len=0)
    enc_states = None
    if cfg.arch == "encdec":
        enc_len = rag.k * rag.chunk_len if rag.mode == "retro" else 0
        neutral = jnp.zeros((B, max(enc_len, 8)), jnp.int32)
        enc_states = tf.encode(params, cfg, tf.embed_tokens(params, neutral))
    pos = jnp.broadcast_to(jnp.arange(T0)[None], (B, T0))
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, T0))
    logits, caches, hidden = tf.forward(
        params, cfg, tokens=prompt, positions=pos, mode="prefill",
        caches=caches, enc_states=enc_states, return_hidden=True)
    last_logits = logits if logits.ndim == 2 else logits[:, -1]
    last_hidden = hidden if hidden.ndim == 2 else hidden[:, -1]
    return caches, enc_states, last_logits, last_hidden


@functools.partial(jax.jit, static_argnums=1,
                   static_argnames=("attn_spec",))
def _jit_decode(params, cfg: ModelConfig, caches, token, position,
                enc_states, *, attn_spec=None):
    """One shared jit cache for all backends/engines (``cfg`` is frozen
    and hashable), so repeatedly constructing engines — e.g. the
    ``generate()`` compat shim — never re-traces decode_step."""
    return tf.decode_step(params, cfg, caches, token, position,
                          enc_states=enc_states, return_hidden=True,
                          attn_spec=attn_spec)


@functools.partial(jax.jit, static_argnums=1, donate_argnums=(2,),
                   static_argnames=("kv_len", "attn_spec"))
def _jit_decode_wave(params, cfg: ModelConfig, caches, token, slots,
                     position, enc_states, *, kv_len=None, attn_spec=None):
    """One dispatch per wave over the slotted KV-cache pool. The pool
    caches are donated: the per-layer K/V writes land in place, so step
    cost is O(wave), not O(pool). Shared jit cache across engines, keyed
    on (cfg, wave bucket, pool shape, kv_len, attn_spec) — ``kv_len`` is
    the wave's block-aligned valid prefix (attention reads crop to it,
    see ``KVCachePool.attn_len``), ``attn_spec`` the static
    decode-attention kernel selection."""
    return tf.decode_wave(params, cfg, caches, token, slots, position,
                          enc_states=enc_states, return_hidden=True,
                          kv_len=kv_len, attn_spec=attn_spec)


class MonolithicBackend:
    """Decode on the default device set — LM and retrieval share
    hardware. No per-step blocking, so jax's async dispatch pipelines."""

    name = "monolithic"
    times: Optional[PoolTimes] = None

    def __init__(self, params, cfg: ModelConfig):
        self.params, self.cfg = params, cfg
        self.decode_dispatches = 0      # LM dispatch counter (tests/bench)

    def prefill(self, rag: RagConfig, prompt: jnp.ndarray, max_seq: int):
        return _prefill(self.params, self.cfg, rag, prompt, max_seq)

    def decode(self, caches, token, position, enc_states=None,
               attn_spec=None):
        self.decode_dispatches += 1
        return _jit_decode(self.params, self.cfg, caches, token, position,
                           enc_states, attn_spec=attn_spec)

    def decode_wave(self, caches, token, slots, position, enc_states=None,
                    kv_len=None, attn_spec=None):
        """Advance one wave of pooled slots: token/slots/position [W]."""
        self.decode_dispatches += 1
        return _jit_decode_wave(self.params, self.cfg, caches, token,
                                slots, position, enc_states,
                                kv_len=kv_len, attn_spec=attn_spec)

    def encode_chunks(self, chunks: jnp.ndarray) -> jnp.ndarray:
        """RETRO re-encode of retrieved chunk tokens [B, L] — LM-side
        work, so it lives on the backend like prefill/decode."""
        emb = tf.embed_tokens(self.params, chunks)
        return tf.encode(self.params, self.cfg, emb)


class DisaggregatedBackend:
    """The paper's split device set: an LM pool and a retrieval pool with
    independent meshes. The retrieval mesh is exposed for a
    ``DistributedRetriever`` to live on; ``PoolTimes`` records both
    pools' step times (decode here, search in the engine)."""

    name = "disaggregated"

    def __init__(self, params, cfg: ModelConfig,
                 lm_devices: int = 1, ret_devices: int = 1,
                 measure: bool = True):
        """``measure=True`` records PoolTimes (Fig. 13 ratio) — at the
        cost of a block_until_ready per pool step, which serializes the
        pools. Pass ``measure=False`` to let the scheduler's two-phase
        dispatch actually overlap decode and retrieval across batches."""
        devs = jax.devices()
        assert lm_devices + ret_devices <= len(devs), (
            lm_devices, ret_devices, len(devs))
        self.params, self.cfg = params, cfg
        self.decode_dispatches = 0
        self.times = PoolTimes() if measure else None
        # LM pool: pure data-parallel decode (each unit = one "GPU process")
        self.lm_mesh = make_mesh_for(devs[:lm_devices], data=lm_devices)
        # Retrieval pool: ChamVS memory nodes over their own mesh
        self.ret_mesh = make_mesh_for(
            devs[lm_devices:lm_devices + ret_devices], data=ret_devices)

    def prefill(self, rag: RagConfig, prompt: jnp.ndarray, max_seq: int):
        with use_mesh(self.lm_mesh):
            return _prefill(self.params, self.cfg, rag, prompt, max_seq)

    def decode(self, caches, token, position, enc_states=None,
               attn_spec=None):
        self.decode_dispatches += 1
        t0 = time.time()
        with use_mesh(self.lm_mesh):
            logits, caches, hidden = _jit_decode(
                self.params, self.cfg, caches, token, position, enc_states,
                attn_spec=attn_spec)
        if self.times is not None:
            logits.block_until_ready()
            self.times.decode_s.append(time.time() - t0)
        return logits, caches, hidden

    def decode_wave(self, caches, token, slots, position, enc_states=None,
                    kv_len=None, attn_spec=None):
        """One LM-pool dispatch for the whole wave (paper §5: the GPU
        pool batches inference across requests)."""
        self.decode_dispatches += 1
        t0 = time.time()
        with use_mesh(self.lm_mesh):
            logits, caches, hidden = _jit_decode_wave(
                self.params, self.cfg, caches, token, slots, position,
                enc_states, kv_len=kv_len, attn_spec=attn_spec)
        if self.times is not None:
            logits.block_until_ready()
            self.times.decode_s.append(time.time() - t0)
        return logits, caches, hidden

    def encode_chunks(self, chunks: jnp.ndarray) -> jnp.ndarray:
        """RETRO re-encode on the LM pool (encoder work belongs to the
        LM side of the pool split, like prefill's encoder pass)."""
        with use_mesh(self.lm_mesh):
            emb = tf.embed_tokens(self.params, chunks)
            return tf.encode(self.params, self.cfg, emb)


# ---------------------------------------------------------------------------
# per-request state + the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SequenceState:
    """One active request's decode state (owned by the scheduler).

    Wave mode: ``caches``/``enc_states`` are ``None`` — the KV lives in
    the engine's ``KVCachePool`` at rows ``slots`` (one per prompt row),
    claimed at admission and freed at completion."""
    request: RalmRequest
    caches: Any
    enc_states: Optional[jnp.ndarray]
    out: List[jnp.ndarray]
    cur: jnp.ndarray                     # [B, 1] last sampled token
    t0: int                              # prompt length
    logits0: Optional[jnp.ndarray]       # prefill logits (consumed at s=0)
    hidden0: Optional[jnp.ndarray]       # prefill hidden  (step-0 query)
    rng: Optional[jax.Array]
    step: int = 0
    slots: Optional[np.ndarray] = None   # pool rows (wave mode)

    @property
    def done(self) -> bool:
        return self.request.cancelled or self.step >= self.request.steps

    def tokens(self) -> jnp.ndarray:
        return jnp.concatenate(self.out, axis=1)


class RalmEngine:
    """Facade: one decode backend + one ``Retriever`` + the canonical
    generation step. All entry points (examples, launchers, the old
    ``generate``/``DisaggregatedRuntime`` shims) go through here."""

    def __init__(self, backend, retriever: Optional[Retriever] = None,
                 rag: Optional[RagConfig] = None,
                 max_seq: Optional[int] = None,
                 max_active: Optional[int] = None,
                 wave: bool = True, kv_slots: Optional[int] = None,
                 attn_backend: Optional[str] = None,
                 attn_interpret: Optional[bool] = None,
                 attn_seq_block: int = 16,
                 tracer: Optional[Tracer] = None):
        """``wave=True`` (default) decodes every active sequence in one
        dispatch per scheduler wave over a slotted ``KVCachePool``;
        ``wave=False`` keeps the per-sequence oracle loop (one dispatch
        per sequence, private caches). ``kv_slots`` fixes the pool
        capacity in rows — admission then defers until completions free
        slots; ``None`` lets the pool grow on demand.

        ``attn_backend`` selects the wave decode-attention kernel:
        ``"ref"`` (default — grouped einsum over the KV-head axis, the
        CPU serving flavor), ``"pallas"`` (the streaming
        ``kernels/decode_attn`` kernel; interpret mode per
        ``attn_interpret``, default True for CPU containers), or
        ``"einsum"`` (the legacy full-materialization oracle — "kernel
        off"). ``attn_seq_block`` is the pool's seq-axis alignment
        quantum: per wave the engine crops attention reads to the
        block-aligned valid prefix (``KVCachePool.attn_len``), so short
        waves stop paying for pool padding at the cost of O(max_seq /
        attn_seq_block) extra decode-graph variants."""
        self.backend = backend
        self.attn_spec = registry.KernelSpec(
            backend=attn_backend if attn_backend is not None else "ref",
            interpret=True if attn_interpret is None else attn_interpret)
        self.attn_seq_block = attn_seq_block
        self.retriever = retriever
        self.rag = rag if rag is not None else RagConfig(mode="none")
        self.cfg = backend.cfg
        if wave and self.rag.mode == "retro" and \
                self.cfg.arch == "encdec" and \
                self.rag.k * self.rag.chunk_len < 8:
            # the pooled enc buffer needs one width for all slots, but
            # prefill's neutral encoder floor is 8 tokens while re-encode
            # rows would be k*chunk_len wide — fail at construction, not
            # mid-generation inside write_enc
            raise ValueError(
                f"wave decode needs rag.k * rag.chunk_len >= 8 for RETRO "
                f"(got {self.rag.k} * {self.rag.chunk_len}); use "
                "wave=False for this config")
        self.max_seq = max_seq
        self.wave = wave
        self.kv_slots = kv_slots
        self.pool: Optional[KVCachePool] = None   # built at first admission
        self.times: Optional[PoolTimes] = getattr(backend, "times", None)
        self.scheduler = RalmScheduler(self, max_active=max_active)
        self._unclaimed: List[RalmResponse] = []
        self.tracer = NULL_TRACER
        self.trace_path: Optional[str] = None
        if tracer is not None:
            self.set_tracer(tracer)

    # -- observability ------------------------------------------------------

    def set_tracer(self, tracer: Tracer) -> None:
        """Install a tracer and propagate it to every component the
        engine owns a span site in: the retrieval service (scan/merge/
        queue-wait/gather) and the KV pool (alloc/release/recompile).
        Components created later (the lazy pool) pick it up at
        construction."""
        self.tracer = tracer
        service = getattr(self.retriever, "service", None)
        if service is not None:
            service.tracer = tracer
        if self.pool is not None:
            self.pool.tracer = tracer

    def write_trace(self, path: Optional[str] = None) -> str:
        """Dump the trace buffer as Chrome trace-event JSON. ``path``
        defaults to ``EngineConfig.trace_path`` or ``trace.json``."""
        path = path or self.trace_path or "trace.json"
        self.tracer.write(path)
        return path

    @property
    def decode_dispatches(self) -> int:
        """LM dispatches issued so far (wave mode: one per wave)."""
        return self.backend.decode_dispatches

    # -- constructors -------------------------------------------------------

    @classmethod
    def monolithic(cls, params, cfg: ModelConfig, rag: RagConfig,
                   retriever: Optional[Retriever] = None,
                   max_seq: Optional[int] = None, wave: bool = True,
                   kv_slots: Optional[int] = None,
                   attn_backend: Optional[str] = None,
                   attn_interpret: Optional[bool] = None,
                   attn_seq_block: int = 16) -> "RalmEngine":
        return cls(MonolithicBackend(params, cfg), retriever, rag,
                   max_seq=max_seq, wave=wave, kv_slots=kv_slots,
                   attn_backend=attn_backend, attn_interpret=attn_interpret,
                   attn_seq_block=attn_seq_block)

    @classmethod
    def disaggregated(cls, params, cfg: ModelConfig, rag: RagConfig,
                      db_params: IVFPQParams, db_shards: List[IVFPQShard],
                      search_cfg: ChamVSConfig,
                      payload_tokens: Optional[jnp.ndarray] = None,
                      chunk_table: Optional[jnp.ndarray] = None,
                      lm_devices: int = 1, ret_devices: int = 1,
                      query_proj: Optional[jnp.ndarray] = None,
                      max_seq: Optional[int] = None,
                      measure: bool = True, wave: bool = True,
                      kv_slots: Optional[int] = None,
                      attn_backend: Optional[str] = None,
                      attn_interpret: Optional[bool] = None,
                      attn_seq_block: int = 16) -> "RalmEngine":
        backend = DisaggregatedBackend(params, cfg, lm_devices=lm_devices,
                                       ret_devices=ret_devices,
                                       measure=measure)
        retriever = DistributedRetriever(
            backend.ret_mesh, db_params, db_shards, search_cfg,
            payload_tokens=payload_tokens, chunk_table=chunk_table,
            query_proj=query_proj)
        return cls(backend, retriever, rag, max_seq=max_seq, wave=wave,
                   kv_slots=kv_slots, attn_backend=attn_backend,
                   attn_interpret=attn_interpret,
                   attn_seq_block=attn_seq_block)

    @classmethod
    def from_config(cls, config: EngineConfig, params, datastore,
                    search_cfg: ChamVSConfig,
                    query_proj: Optional[jnp.ndarray] = None
                    ) -> "RalmEngine":
        """Stand an engine up from an ``EngineConfig`` + a built
        ``Datastore`` (see ``repro.serve.datastore``). Falls back to a
        monolithic engine (with a warning) when ``disaggregate`` is
        requested on a single-device host."""
        # plumb the search-kernel selection (Pallas vs ref, interpret
        # mode, fused vs staged scan) from the deployment config down to
        # ChamVSConfig — the registry KernelSpec everything routes with
        search_cfg = search_cfg.with_kernel(config.kernel_backend,
                                            config.kernel_interpret,
                                            config.kernel_fused)
        if config.disaggregate and len(jax.devices()) < 2:
            import warnings
            warnings.warn(
                "EngineConfig.disaggregate=True needs >= 2 devices; "
                f"found {len(jax.devices())} — falling back to a "
                "monolithic engine (no PoolTimes).", RuntimeWarning,
                stacklevel=2)
        if config.disaggregate and len(jax.devices()) >= 2 and \
                config.async_retrieval:
            import warnings
            warnings.warn(
                "EngineConfig.async_retrieval is not wired into the "
                "disaggregated path yet — falling back to the synchronous "
                "DistributedRetriever (no RetrievalService coalescing or "
                "cache).", RuntimeWarning, stacklevel=2)
        if config.disaggregate and len(jax.devices()) >= 2:
            eng = cls.disaggregated(
                params, config.model, config.rag, datastore.params,
                datastore.shards, search_cfg,
                payload_tokens=datastore.payload_tokens,
                chunk_table=datastore.chunk_table,
                lm_devices=config.lm_devices,
                ret_devices=config.ret_devices, query_proj=query_proj,
                max_seq=config.max_seq, wave=config.wave_decode,
                kv_slots=config.kv_slots,
                attn_backend=config.attn_backend,
                attn_interpret=config.attn_interpret,
                attn_seq_block=config.attn_seq_block)
        else:
            if config.retrieval_cache > 0 and not config.async_retrieval:
                import warnings
                warnings.warn(
                    "EngineConfig.retrieval_cache requires "
                    "async_retrieval=True (the cache lives in the "
                    "RetrievalService) — ignoring it.", RuntimeWarning,
                    stacklevel=2)
            if config.async_retrieval:
                from repro.retrieval.service import ServiceConfig
                retriever = datastore.async_retriever(
                    search_cfg, query_proj=query_proj,
                    service_cfg=ServiceConfig(
                        cache_entries=config.retrieval_cache,
                        measure=config.retrieval_measure))
            else:
                retriever = datastore.retriever(search_cfg,
                                                query_proj=query_proj)
            eng = cls.monolithic(params, config.model, config.rag,
                                 retriever=retriever,
                                 max_seq=config.max_seq,
                                 wave=config.wave_decode,
                                 kv_slots=config.kv_slots,
                                 attn_backend=config.attn_backend,
                                 attn_interpret=config.attn_interpret,
                                 attn_seq_block=config.attn_seq_block)
        eng.scheduler.max_active = config.max_active
        if config.trace:
            eng.set_tracer(Tracer(enabled=True))
        eng.trace_path = config.trace_path
        return eng

    # -- KV-cache pool admission (wave mode) --------------------------------

    def check_admissible(self, request: RalmRequest) -> None:
        """Reject-at-submit guard: a request that can NEVER fit the
        fixed-capacity pool must fail in ``submit()``, not poison the
        FIFO queue for everyone behind it when ``_admit`` reaches it."""
        if self.wave and self.kv_slots is not None and \
                request.prompt.shape[0] > self.kv_slots:
            raise ValueError(
                f"request batch of {request.prompt.shape[0]} rows can "
                f"never fit kv_slots={self.kv_slots}")

    def can_admit(self, request: RalmRequest) -> bool:
        """Admission check the scheduler consults before ``start``: a
        fixed-capacity pool defers requests until completions free
        enough slot rows (an auto-growing pool admits everything)."""
        if not self.wave or self.kv_slots is None:
            return True
        B = request.prompt.shape[0]
        return self.pool is None or self.pool.num_free >= B

    def _ensure_pool(self, rows: int, need_seq: int) -> KVCachePool:
        """Create the pool lazily (shapes depend on the first admitted
        request unless ``max_seq``/``kv_slots`` pin them) and grow it —
        slot rows double, the sequence axis extends — when an admission
        needs more than it has."""
        if self.pool is None:
            cap = (self.kv_slots if self.kv_slots is not None
                   else max(next_pow2(rows), 8))
            self.pool = KVCachePool(self.cfg, cap,
                                    self.max_seq or need_seq,
                                    fixed=self.kv_slots is not None,
                                    seq_block=self.attn_seq_block)
            self.pool.tracer = self.tracer
        pool = self.pool
        if self.max_seq is None and need_seq > pool.max_seq:
            pool.grow_seq(need_seq)
        if pool.num_free < rows:
            pool.grow_slots(max(pool.capacity * 2,
                                next_pow2(pool.num_used + rows)))
        return pool

    def release(self, seq: SequenceState) -> None:
        """Return a finished sequence's slot rows to the pool."""
        if seq.slots is not None and self.pool is not None:
            self.pool.release(seq.slots)
            seq.slots = None

    # -- the canonical step (called by the scheduler) -----------------------

    def start(self, request: RalmRequest) -> SequenceState:
        """Prefill a request into an active sequence. Wave mode: claim
        one pool slot per prompt row, prefill at the pool's ``max_seq``
        (so cache leaves line up slot-for-slot) and scatter the rows in;
        the request itself holds no cache."""
        B, T0 = request.prompt.shape
        request.times.admit = time.perf_counter()
        tr = self.tracer
        if tr.enabled:
            # retroactive span on the request track: the queue wait
            # started back at submit() (times.arrival), which predates
            # this call — plus the flow arrow Perfetto draws from here
            # to wherever this request's first token lands (see _emit)
            args = {"request_id": request.request_id,
                    "trace_id": request.trace_id, "tenant": request.tenant,
                    "rows": B}
            if request.times.arrival is not None:
                tr.complete("queue.wait", "requests",
                            request.times.arrival,
                            request.times.admit - request.times.arrival,
                            args=args)
            if request.trace_id is not None:
                tr.flow_start(request.trace_id)
        with tr.span("sched.admit", "requests",
                     args={"request_id": request.request_id,
                           "rows": B, "prompt_len": T0}
                     if tr.enabled else None):
            if self.wave:
                pool = self._ensure_pool(B, T0 + request.steps)
                slots = pool.alloc(B)
                caches, enc_states, logits0, hidden0 = \
                    self.backend.prefill(self.rag, request.prompt,
                                         pool.max_seq)
                pool.write_prefill(slots, caches)
                if enc_states is not None:
                    pool.write_enc(slots, enc_states)
                return SequenceState(
                    request=request, caches=None, enc_states=None,
                    out=[request.prompt], cur=request.prompt[:, -1:],
                    t0=T0, logits0=logits0, hidden0=hidden0,
                    rng=request.rng, slots=slots)
            max_seq = self.max_seq or (T0 + request.steps)
            caches, enc_states, logits0, hidden0 = self.backend.prefill(
                self.rag, request.prompt, max_seq)
            return SequenceState(
                request=request, caches=caches, enc_states=enc_states,
                out=[request.prompt], cur=request.prompt[:, -1:], t0=T0,
                logits0=logits0, hidden0=hidden0, rng=request.rng)

    def dispatch_decode(self, seq: SequenceState
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Phase 1: one LM step. At step 0 the prefill already produced
        both the logits and the retrieval query, so nothing runs."""
        if seq.step == 0:
            logits, hidden = seq.logits0, seq.hidden0
            seq.logits0 = seq.hidden0 = None
            return logits, hidden
        B = seq.cur.shape[0]
        position = jnp.full((B,), seq.t0 + seq.step - 1, jnp.int32)
        logits, seq.caches, hidden = self.backend.decode(
            seq.caches, seq.cur, position, enc_states=seq.enc_states,
            attn_spec=self.attn_spec)
        return logits, hidden

    def _search(self, queries: jnp.ndarray):
        t0 = time.time()
        dists, ids = self.retriever.search(queries)
        if self.times is not None:
            dists.block_until_ready()
            self.times.search_s.append(time.time() - t0)
        return dists, ids

    def _retrieval_due(self, step: int) -> bool:
        # pure host arithmetic (same semantics as rag.should_retrieve):
        # this runs in phase 2a while decodes are in flight, so it must
        # not touch the device
        return (self.retriever is not None and self.rag.mode != "none" and
                (self.rag.interval <= 1 or step % self.rag.interval == 0))

    def dispatch_search(self, seq: SequenceState, hidden: jnp.ndarray):
        """Phase 2a: issue this sequence's retrieval query, without
        dispatching the kernel. Returns a ``SearchHandle`` when the
        retriever batches asynchronously (``AsyncRetriever``), else
        ``None`` — the synchronous path searches inside ``finish_step``.
        """
        if not self._retrieval_due(seq.step):
            return None
        submit = getattr(self.retriever, "search_async", None)
        if submit is None:
            return None
        return submit(hidden)

    def flush_searches(self) -> None:
        """Phase 2b: coalesce every query issued by ``dispatch_search``
        into one batched kernel dispatch (no-op for sync retrievers)."""
        flush = getattr(self.retriever, "flush", None)
        if flush is not None:
            flush()

    def finish_step(self, seq: SequenceState, logits: jnp.ndarray,
                    hidden: jnp.ndarray, search=None) -> None:
        """Phase 2 (2c when async): retrieve (if due) + integrate +
        sample one token. ``search`` is the ``SearchHandle`` returned by
        ``dispatch_search``, if any."""
        s, rag = seq.step, self.rag
        log_or_prob = logits
        if self._retrieval_due(s):
            if search is not None:
                t0 = time.time()
                dists, ids = search.result()
                if self.times is not None:
                    dists.block_until_ready()
                    self.times.search_s.append(time.time() - t0)
            else:
                dists, ids = self._search(hidden)
            if seq.request.trace is not None:
                seq.request.trace.append(dict(step=s, ids=np.asarray(ids)))
            if rag.mode == "knnlm":
                toks = self.retriever.resolve(ids, kind="tokens")
                log_or_prob = rag_lib.knnlm_interpolate(
                    logits, dists, toks, rag.lam, rag.temperature)
            elif rag.mode == "retro" and self.cfg.arch == "encdec":
                B = seq.cur.shape[0]
                chunks = self.retriever.resolve(ids, kind="chunks")
                seq.enc_states = self.backend.encode_chunks(
                    chunks.reshape(B, -1))
        if seq.request.greedy or seq.rng is None:
            nxt = jnp.argmax(log_or_prob, axis=-1).astype(jnp.int32)
        else:
            seq.rng, k = jax.random.split(seq.rng)
            nxt = jax.random.categorical(k, log_or_prob).astype(jnp.int32)
        self._emit(seq, nxt)

    # -- the wave-batched step (one dispatch per phase per wave) ------------

    def dispatch_wave(self, seqs: List[SequenceState]
                      ) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
        """Phase 1, wave mode: ONE ``decode_wave`` dispatch advances every
        step>0 sequence (step-0 sequences consume their prefill outputs —
        nothing to run). Returns per-sequence (logits [B,V], hidden
        [B,d]) views sliced from the wave outputs."""
        outs: List = [None] * len(seqs)
        wave = []
        for i, seq in enumerate(seqs):
            if seq.step == 0:
                outs[i] = (seq.logits0, seq.hidden0)
                seq.logits0 = seq.hidden0 = None
            else:
                wave.append((i, seq))
        if not wave:
            return outs
        pool = self.pool
        tokens = jnp.concatenate([seq.cur for _, seq in wave], axis=0)
        slots = np.concatenate([seq.slots for _, seq in wave])
        positions = np.concatenate(
            [np.full(seq.cur.shape[0], seq.t0 + seq.step - 1, np.int32)
             for _, seq in wave])
        # the wave's positions are host arrays, so the block-aligned
        # valid prefix is known before dispatch: attention reads crop to
        # kv_len instead of the pool's padded max_seq (pad rows sit at
        # position 0 and never extend it)
        max_pos = int(positions.max())
        tokens, slots, positions = pool.pad_wave(tokens, slots, positions)
        kv_len = pool.attn_len(max_pos, bucket=len(slots))
        tr = self.tracer
        with tr.span("wave.decode", "wave",
                     args={"rows": len(wave), "bucket": len(slots),
                           "kv_len": kv_len} if tr.enabled else None):
            logits, pool.caches, hidden = self.backend.decode_wave(
                pool.caches, tokens, jnp.asarray(slots),
                jnp.asarray(positions), enc_states=pool.gather_enc(slots),
                kv_len=kv_len, attn_spec=self.attn_spec)
        off = 0
        for i, seq in wave:
            B = seq.cur.shape[0]
            outs[i] = (logits[off:off + B], hidden[off:off + B])
            off += B
        return outs

    def dispatch_search_wave(self, seqs: List[SequenceState],
                             decoded: List) -> List:
        """Phase 2a/2b, wave mode: issue every due sequence's retrieval
        query. Async retrievers coalesce via the service (flushed by the
        scheduler's ``flush_searches``); synchronous retrievers get their
        rows concatenated into ONE batched ``search`` here."""
        searches: List = [None] * len(seqs)
        due = [i for i, seq in enumerate(seqs)
               if self._retrieval_due(seq.step)]
        if not due:
            return searches
        submit = getattr(self.retriever, "search_async", None)
        if submit is not None:
            for i in due:
                searches[i] = submit(decoded[i][1])
            return searches
        queries = jnp.concatenate([decoded[i][1] for i in due], axis=0)
        dists, ids = self._search(queries)
        off = 0
        for i in due:
            B = decoded[i][1].shape[0]
            searches[i] = (dists[off:off + B], ids[off:off + B])
            off += B
        return searches

    def finish_wave(self, seqs: List[SequenceState], decoded: List,
                    searches: List) -> None:
        """Phase 2c, wave mode: integrate + sample for the whole wave in
        batched dispatches — one ``resolve`` + one ``knnlm_interpolate``
        over all due rows, one RETRO re-encode over all due chunks, one
        greedy argmax over every greedy row. Per-request ``rng`` sampling
        stays per-sequence (each request owns an independent key chain,
        so batching it would change the sampled tokens)."""
        rag = self.rag
        rows: List[jnp.ndarray] = []
        knn = []                # (row_idx, logits, dists, ids)
        retro = []              # (seq, chunks [B, k*chunk_len])
        for seq, out, search in zip(seqs, decoded, searches):
            logits, hidden = out
            if search is not None:
                if hasattr(search, "result"):      # async SearchHandle
                    t0 = time.time()
                    dists, ids = search.result()
                    if self.times is not None:
                        dists.block_until_ready()
                        self.times.search_s.append(time.time() - t0)
                else:                              # pre-sliced sync batch
                    dists, ids = search
                if seq.request.trace is not None:
                    seq.request.trace.append(
                        dict(step=seq.step, ids=np.asarray(ids)))
                if rag.mode == "knnlm":
                    knn.append((len(rows), logits, dists, ids))
                elif rag.mode == "retro" and self.cfg.arch == "encdec":
                    retro.append((seq, ids))
            rows.append(logits)
        if knn:
            logits_cat = jnp.concatenate([e[1] for e in knn], axis=0)
            dists_cat = jnp.concatenate([e[2] for e in knn], axis=0)
            ids_cat = jnp.concatenate([e[3] for e in knn], axis=0)
            toks = self.retriever.resolve(ids_cat, kind="tokens")
            mixed = rag_lib.knnlm_interpolate(
                logits_cat, dists_cat, toks, rag.lam, rag.temperature)
            off = 0
            for idx, logits, _, _ in knn:
                B = logits.shape[0]
                rows[idx] = mixed[off:off + B]
                off += B
        if retro:
            # one chunk resolve + one re-encode over every due row, like
            # the knnlm branch above
            chunks = self.retriever.resolve(
                jnp.concatenate([ids for _, ids in retro], axis=0),
                kind="chunks")
            W = chunks.shape[0]
            enc = self.backend.encode_chunks(chunks.reshape(W, -1))
            off = 0
            for seq, _ in retro:
                B = seq.cur.shape[0]
                self.pool.write_enc(seq.slots, enc[off:off + B])
                off += B
        greedy = [i for i, seq in enumerate(seqs)
                  if seq.request.greedy or seq.rng is None]
        if greedy:
            nxt_cat = jnp.argmax(
                jnp.concatenate([rows[i] for i in greedy], axis=0),
                axis=-1).astype(jnp.int32)
            off = 0
            for i in greedy:
                B = rows[i].shape[0]
                self._emit(seqs[i], nxt_cat[off:off + B])
                off += B
        for i, seq in enumerate(seqs):
            if seq.request.greedy or seq.rng is None:
                continue
            seq.rng, k = jax.random.split(seq.rng)
            self._emit(seq, jax.random.categorical(
                k, rows[i]).astype(jnp.int32))

    def _emit(self, seq: SequenceState, nxt: jnp.ndarray) -> None:
        seq.cur = nxt[:, None]
        seq.out.append(seq.cur)
        req = seq.request
        first = req.times.first_token is None
        if req.on_token is not None:
            # the streaming hook needs host tokens, which forces the
            # wave's device work to complete here — one sync per wave
            # (the first row's asarray blocks; the rest are free). The
            # first-token timestamp is taken AFTER the sync so TTFT
            # measures token availability, not dispatch.
            host = np.asarray(nxt)
            if first:
                req.times.first_token = time.perf_counter()
            req.on_token(seq.step, host)
        elif first:
            # no streaming consumer: stamp dispatch time (approximate —
            # jax async dispatch means the value may still be in flight)
            req.times.first_token = time.perf_counter()
        if first and req.trace_id is not None and self.tracer.enabled:
            # close the TTFT flow arrow opened at admission: Perfetto
            # draws queue.wait -> the wave that produced the first token
            self.tracer.flow_end(req.trace_id, track="wave",
                                 t_s=req.times.first_token)
        seq.step += 1

    # -- serving API --------------------------------------------------------

    def submit(self, request: RalmRequest) -> int:
        return self.scheduler.submit(request)

    def step(self) -> List[RalmResponse]:
        return self.scheduler.step()

    def run(self) -> List[RalmResponse]:
        """Drain the scheduler; includes any responses that completed
        during an interleaved ``generate()`` call."""
        out = self._unclaimed + self.scheduler.run()
        self._unclaimed = []
        return out

    def generate(self, prompt: jnp.ndarray, steps: int, *,
                 greedy: bool = True, rng: Optional[jax.Array] = None,
                 trace: Optional[list] = None) -> jnp.ndarray:
        """Synchronous convenience: one request, run to completion.
        Other in-flight requests also advance; their responses are held
        for the next ``run()`` call, not discarded."""
        rid = self.submit(RalmRequest(prompt=jnp.asarray(prompt),
                                      steps=steps, greedy=greedy, rng=rng,
                                      trace=trace))
        result = None
        for resp in self.scheduler.run():
            if resp.request_id == rid:
                result = resp
            else:
                self._unclaimed.append(resp)
        if result is None:  # pragma: no cover
            raise RuntimeError("request did not complete")
        return jnp.asarray(result.tokens)

    def generate_batches(self, prompts: List[jnp.ndarray], steps: int
                         ) -> List[np.ndarray]:
        """Pipelined convenience: several request batches in flight at
        once (the old ``generate_pipelined``). Results in submit order."""
        rids = [self.submit(RalmRequest(prompt=jnp.asarray(p), steps=steps))
                for p in prompts]
        by_id = {r.request_id: r.tokens for r in self.run()}
        return [np.asarray(by_id[rid]) for rid in rids]
