"""``RalmEngine`` — the single generation loop behind every entry point.

The decode -> retrieve -> interpolate -> sample step used to live in two
divergent copies (``core/generate.py`` and ``core/coordinator.py``; they
even disagreed on the step-0 retrieval query). It now lives here once,
split into the two phases the scheduler pipelines:

  * ``dispatch_decode(seq)`` — advance the LM one token (async dispatch);
  * ``finish_step(seq, ...)`` — retrieval + kNN-LM interpolation / RETRO
    re-encode + sampling.

Backends own the decode side of the boundary:

  * ``MonolithicBackend`` — one mesh / the default devices; decode and
    search share hardware (the paper's GPU-only baseline);
  * ``DisaggregatedBackend`` — the paper's split: an LM pool and a
    retrieval pool with independent meshes, plus ``PoolTimes`` measuring
    the per-pool step times that give the Fig. 13 optimal-ratio estimate.

Decode has two shapes. The default (``wave=True``) runs over a
``KVCachePool``: every active sequence's rows live in pooled cache
slots, and ``decode_wave`` advances the whole wave as ONE dispatch
(``tokens [W], slots [W], positions [W]``, W bucketed to powers of two
like the retrieval service's query batches). kNN interpolation and
greedy sampling batch the same way. The per-sequence path
(``wave=False``) is kept as the parity oracle — greedy outputs must be
token-identical between the two, including staggered admission and
ragged prompt lengths (tests/test_kvpool.py).

Retrieval is any object satisfying ``api.Retriever``; the engine never
looks past ``search``/``resolve``.

Step-0 correctness note: the first retrieval query is the *prefill*'s
last-position hidden state (exactly what the decode step would have
produced), so monolithic and disaggregated runs are token-identical
under greedy decoding — the old loops disagreed here (embedding
stand-in vs re-decoding the last prompt token).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import use_mesh
from repro.core import rag as rag_lib
from repro.kernels import registry
from repro.core.chamvs import ChamVSConfig
from repro.core.ivfpq import IVFPQParams, IVFPQShard
from repro.core.rag import RagConfig
from repro.launch.mesh import make_mesh_for
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serve.api import (DistributedRetriever, EngineConfig,
                             RalmRequest, RalmResponse, Retriever)
from repro.serve.kvpool import KVCachePool, next_pow2
from repro.serve.scheduler import RalmScheduler


@dataclasses.dataclass
class PoolTimes:
    """Per-pool step times (paper Fig. 13 instrumentation)."""
    decode_s: List[float] = dataclasses.field(default_factory=list)
    search_s: List[float] = dataclasses.field(default_factory=list)

    def optimal_ratio(self) -> float:
        """Paper Fig. 13: LM-pool units needed to saturate one retrieval
        engine = (retrieval throughput) / (decode throughput) per batch."""
        if not self.decode_s or not self.search_s:
            return float("nan")
        return float(np.median(self.decode_s) / np.median(self.search_s))


# ---------------------------------------------------------------------------
# decode backends
# ---------------------------------------------------------------------------

def _prefill(params, cfg: ModelConfig, rag: RagConfig,
             prompt: jnp.ndarray, max_seq: int):
    """Consume the prompt. Returns (caches, enc_states, last_logits [B,V],
    last_hidden [B,d]) — the hidden state at the last prompt position is
    the step-0 retrieval query."""
    B, T0 = prompt.shape
    caches = tf.init_cache(cfg, B, max_seq=max_seq, enc_len=0)
    enc_states = None
    if cfg.arch == "encdec":
        enc_len = rag.k * rag.chunk_len if rag.mode == "retro" else 0
        neutral = jnp.zeros((B, max(enc_len, 8)), jnp.int32)
        enc_states = tf.encode(params, cfg, tf.embed_tokens(params, neutral))
    pos = jnp.broadcast_to(jnp.arange(T0)[None], (B, T0))
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, T0))
    logits, caches, hidden = tf.forward(
        params, cfg, tokens=prompt, positions=pos, mode="prefill",
        caches=caches, enc_states=enc_states, return_hidden=True)
    last_logits = logits if logits.ndim == 2 else logits[:, -1]
    last_hidden = hidden if hidden.ndim == 2 else hidden[:, -1]
    return caches, enc_states, last_logits, last_hidden


@functools.partial(jax.jit, static_argnums=1,
                   static_argnames=("attn_spec",))
def _jit_decode(params, cfg: ModelConfig, caches, token, position,
                enc_states, *, attn_spec=None):
    """One shared jit cache for all backends/engines (``cfg`` is frozen
    and hashable), so repeatedly constructing engines — e.g. the
    ``generate()`` compat shim — never re-traces decode_step."""
    return tf.decode_step(params, cfg, caches, token, position,
                          enc_states=enc_states, return_hidden=True,
                          attn_spec=attn_spec)


@functools.partial(jax.jit, static_argnums=1, donate_argnums=(2,),
                   static_argnames=("kv_len", "attn_spec"))
def _jit_decode_wave(params, cfg: ModelConfig, caches, token, slots,
                     position, enc_states, *, kv_len=None, attn_spec=None):
    """One dispatch per wave over the slotted KV-cache pool. The pool
    caches are donated: the per-layer K/V writes land in place, so step
    cost is O(wave), not O(pool). Shared jit cache across engines, keyed
    on (cfg, wave bucket, pool shape, kv_len, attn_spec) — ``kv_len`` is
    the wave's block-aligned valid prefix (attention reads crop to it,
    see ``KVCachePool.attn_len``), ``attn_spec`` the static
    decode-attention kernel selection."""
    return tf.decode_wave(params, cfg, caches, token, slots, position,
                          enc_states=enc_states, return_hidden=True,
                          kv_len=kv_len, attn_spec=attn_spec)


class MonolithicBackend:
    """Decode on the default device set — LM and retrieval share
    hardware. No per-step blocking, so jax's async dispatch pipelines."""

    name = "monolithic"
    times: Optional[PoolTimes] = None

    def __init__(self, params, cfg: ModelConfig):
        self.params, self.cfg = params, cfg
        self.decode_dispatches = 0      # LM dispatch counter (tests/bench)

    def prefill(self, rag: RagConfig, prompt: jnp.ndarray, max_seq: int):
        return _prefill(self.params, self.cfg, rag, prompt, max_seq)

    def decode(self, caches, token, position, enc_states=None,
               attn_spec=None):
        self.decode_dispatches += 1
        return _jit_decode(self.params, self.cfg, caches, token, position,
                           enc_states, attn_spec=attn_spec)

    def decode_wave(self, caches, token, slots, position, enc_states=None,
                    kv_len=None, attn_spec=None):
        """Advance one wave of pooled slots: token/slots/position [W]."""
        self.decode_dispatches += 1
        return _jit_decode_wave(self.params, self.cfg, caches, token,
                                slots, position, enc_states,
                                kv_len=kv_len, attn_spec=attn_spec)

    def encode_chunks(self, chunks: jnp.ndarray) -> jnp.ndarray:
        """RETRO re-encode of retrieved chunk tokens [B, L] — LM-side
        work, so it lives on the backend like prefill/decode."""
        emb = tf.embed_tokens(self.params, chunks)
        return tf.encode(self.params, self.cfg, emb)


class DisaggregatedBackend:
    """The paper's split device set: an LM pool and a retrieval pool with
    independent meshes. The retrieval mesh is exposed for a
    ``DistributedRetriever`` to live on; ``PoolTimes`` records both
    pools' step times (decode here, search in the engine)."""

    name = "disaggregated"

    def __init__(self, params, cfg: ModelConfig,
                 lm_devices: int = 1, ret_devices: int = 1,
                 measure: bool = True):
        """``measure=True`` records PoolTimes (Fig. 13 ratio) — at the
        cost of a block_until_ready per pool step, which serializes the
        pools. Pass ``measure=False`` to let the scheduler's two-phase
        dispatch actually overlap decode and retrieval across batches."""
        devs = jax.devices()
        assert lm_devices + ret_devices <= len(devs), (
            lm_devices, ret_devices, len(devs))
        self.params, self.cfg = params, cfg
        self.decode_dispatches = 0
        self.times = PoolTimes() if measure else None
        # LM pool: pure data-parallel decode (each unit = one "GPU process")
        self.lm_mesh = make_mesh_for(devs[:lm_devices], data=lm_devices)
        # Retrieval pool: ChamVS memory nodes over their own mesh
        self.ret_mesh = make_mesh_for(
            devs[lm_devices:lm_devices + ret_devices], data=ret_devices)

    def prefill(self, rag: RagConfig, prompt: jnp.ndarray, max_seq: int):
        with use_mesh(self.lm_mesh):
            return _prefill(self.params, self.cfg, rag, prompt, max_seq)

    def decode(self, caches, token, position, enc_states=None,
               attn_spec=None):
        self.decode_dispatches += 1
        t0 = time.time()
        with use_mesh(self.lm_mesh):
            logits, caches, hidden = _jit_decode(
                self.params, self.cfg, caches, token, position, enc_states,
                attn_spec=attn_spec)
        if self.times is not None:
            logits.block_until_ready()
            self.times.decode_s.append(time.time() - t0)
        return logits, caches, hidden

    def decode_wave(self, caches, token, slots, position, enc_states=None,
                    kv_len=None, attn_spec=None):
        """One LM-pool dispatch for the whole wave (paper §5: the GPU
        pool batches inference across requests)."""
        self.decode_dispatches += 1
        t0 = time.time()
        with use_mesh(self.lm_mesh):
            logits, caches, hidden = _jit_decode_wave(
                self.params, self.cfg, caches, token, slots, position,
                enc_states, kv_len=kv_len, attn_spec=attn_spec)
        if self.times is not None:
            logits.block_until_ready()
            self.times.decode_s.append(time.time() - t0)
        return logits, caches, hidden

    def encode_chunks(self, chunks: jnp.ndarray) -> jnp.ndarray:
        """RETRO re-encode on the LM pool (encoder work belongs to the
        LM side of the pool split, like prefill's encoder pass)."""
        with use_mesh(self.lm_mesh):
            emb = tf.embed_tokens(self.params, chunks)
            return tf.encode(self.params, self.cfg, emb)


# ---------------------------------------------------------------------------
# per-request state + the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpecPoint:
    """One outstanding speculation: a retrieval-due step that decoded
    ahead on stale neighbors while the real search runs async.

    Everything needed to verify later — and to roll back on a mismatch
    — is captured at emit time: the pre-interpolation LM logits (so the
    verification interpolation is bit-identical to what the baseline
    would have computed), the token actually emitted from the stale
    mix, and the ``seq.out`` length before that emit (the truncation
    watermark)."""
    step: int                      # the due step that speculated
    handle: Any                    # SearchHandle of the real search
    logits: jnp.ndarray            # [B, V] LM logits at `step`
    emitted: jnp.ndarray           # [B, 1] token emitted from stale mix
    out_len: int                   # len(seq.out) BEFORE the emit
    age: int = 0                   # waves since issue; verified when
    #                                age reaches the speculation depth


class _SpecIssue:
    """Phase-2a marker for a speculated row: ``finish_wave`` integrates
    the stale ``(dists, ids)`` instead of blocking on ``handle`` (the
    real search, resolved by ``spec_harvest`` 1..k waves later)."""

    __slots__ = ("handle", "dists", "ids")

    def __init__(self, handle, dists, ids):
        self.handle = handle
        self.dists = dists
        self.ids = ids


@dataclasses.dataclass
class SequenceState:
    """One active request's decode state (owned by the scheduler).

    Wave mode: ``caches``/``enc_states`` are ``None`` — the KV lives in
    the engine's ``KVCachePool`` at rows ``slots`` (one per prompt row),
    claimed at admission and freed at completion."""
    request: RalmRequest
    caches: Any
    enc_states: Optional[jnp.ndarray]
    out: List[jnp.ndarray]
    cur: jnp.ndarray                     # [B, 1] last sampled token
    t0: int                              # prompt length
    logits0: Optional[jnp.ndarray]       # prefill logits (consumed at s=0)
    hidden0: Optional[jnp.ndarray]       # prefill hidden  (step-0 query)
    rng: Optional[jax.Array]
    step: int = 0
    slots: Optional[np.ndarray] = None   # pool rows (wave mode)
    last_neighbors: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
    #                                      most recent VERIFIED (dists,
    #                                      ids) — the stale neighbors
    #                                      the next due step speculates
    #                                      with
    spec_points: List[SpecPoint] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.request.cancelled or self.step >= self.request.steps

    def tokens(self) -> jnp.ndarray:
        return jnp.concatenate(self.out, axis=1)


class RalmEngine:
    """Facade: one decode backend + one ``Retriever`` + the canonical
    generation step. All entry points (examples, launchers, the old
    ``generate``/``DisaggregatedRuntime`` shims) go through here."""

    def __init__(self, backend, retriever: Optional[Retriever] = None,
                 rag: Optional[RagConfig] = None,
                 max_seq: Optional[int] = None,
                 max_active: Optional[int] = None,
                 wave: bool = True, kv_slots: Optional[int] = None,
                 attn_backend: Optional[str] = None,
                 attn_interpret: Optional[bool] = None,
                 attn_seq_block: int = 16,
                 tracer: Optional[Tracer] = None,
                 speculate_k: int = 0,
                 speculate_verify: bool = True):
        """``wave=True`` (default) decodes every active sequence in one
        dispatch per scheduler wave over a slotted ``KVCachePool``;
        ``wave=False`` keeps the per-sequence oracle loop (one dispatch
        per sequence, private caches). ``kv_slots`` fixes the pool
        capacity in rows — admission then defers until completions free
        slots; ``None`` lets the pool grow on demand.

        ``attn_backend`` selects the wave decode-attention kernel:
        ``"ref"`` (default — grouped einsum over the KV-head axis, the
        CPU serving flavor), ``"pallas"`` (the streaming
        ``kernels/decode_attn`` kernel; interpret mode per
        ``attn_interpret``, default True for CPU containers), or
        ``"einsum"`` (the legacy full-materialization oracle — "kernel
        off"). ``attn_seq_block`` is the pool's seq-axis alignment
        quantum: per wave the engine crops attention reads to the
        block-aligned valid prefix (``KVCachePool.attn_len``), so short
        waves stop paying for pool padding at the cost of O(max_seq /
        attn_seq_block) extra decode-graph variants."""
        self.backend = backend
        self.attn_spec = registry.KernelSpec(
            backend=attn_backend if attn_backend is not None else "ref",
            interpret=True if attn_interpret is None else attn_interpret)
        self.attn_seq_block = attn_seq_block
        self.retriever = retriever
        self.rag = rag if rag is not None else RagConfig(mode="none")
        self.cfg = backend.cfg
        if wave and self.rag.mode == "retro" and \
                self.cfg.arch == "encdec" and \
                self.rag.k * self.rag.chunk_len < 8:
            # the pooled enc buffer needs one width for all slots, but
            # prefill's neutral encoder floor is 8 tokens while re-encode
            # rows would be k*chunk_len wide — fail at construction, not
            # mid-generation inside write_enc
            raise ValueError(
                f"wave decode needs rag.k * rag.chunk_len >= 8 for RETRO "
                f"(got {self.rag.k} * {self.rag.chunk_len}); use "
                "wave=False for this config")
        self.max_seq = max_seq
        self.wave = wave
        self.kv_slots = kv_slots
        # -- speculative retrieval (RaLMSpec, arXiv 2401.14021) --------
        self.speculate_k = int(speculate_k)
        self.speculate_verify = speculate_verify
        if self.speculate_k > 0:
            import warnings
            if not wave:
                warnings.warn(
                    "speculate_k > 0 requires wave decode (the "
                    "per-sequence oracle path is the thing speculation "
                    "verifies against) — disabling speculation.",
                    RuntimeWarning, stacklevel=2)
                self.speculate_k = 0
            elif self.cfg.ssm_state > 0 or \
                    self.cfg.block in ("rwkv6", "hybrid"):
                warnings.warn(
                    f"speculate_k > 0 is unsupported for recurrent-state "
                    f"blocks (block={self.cfg.block!r}, ssm_state="
                    f"{self.cfg.ssm_state}): the state update cannot be "
                    "rewound on rollback — disabling speculation.",
                    RuntimeWarning, stacklevel=2)
                self.speculate_k = 0
        # verification depth in waves. Ring (sliding-window) caches
        # alias KV positions modulo the window, so only a depth-1
        # rollback rewrites exactly the slots it invalidated — deeper
        # speculation is clamped for windowed models (see
        # KVCachePool.rewind).
        self._spec_depth = self.speculate_k
        if self.speculate_k > 0 and self.cfg.window > 0 and \
                "local" in self.cfg.pattern_classes():
            self._spec_depth = 1
        self._local_spec_stats = None    # fallback when no service
        self.pool: Optional[KVCachePool] = None   # built at first admission
        self.times: Optional[PoolTimes] = getattr(backend, "times", None)
        self.scheduler = RalmScheduler(self, max_active=max_active)
        self._unclaimed: List[RalmResponse] = []
        self.tracer = NULL_TRACER
        self.trace_path: Optional[str] = None
        if tracer is not None:
            self.set_tracer(tracer)

    # -- observability ------------------------------------------------------

    def set_tracer(self, tracer: Tracer) -> None:
        """Install a tracer and propagate it to every component the
        engine owns a span site in: the retrieval service (scan/merge/
        queue-wait/gather) and the KV pool (alloc/release/recompile).
        Components created later (the lazy pool) pick it up at
        construction."""
        self.tracer = tracer
        service = getattr(self.retriever, "service", None)
        if service is not None:
            service.tracer = tracer
        if self.pool is not None:
            self.pool.tracer = tracer

    def write_trace(self, path: Optional[str] = None) -> str:
        """Dump the trace buffer as Chrome trace-event JSON. ``path``
        defaults to ``EngineConfig.trace_path`` or ``trace.json``."""
        path = path or self.trace_path or "trace.json"
        self.tracer.write(path)
        return path

    @property
    def decode_dispatches(self) -> int:
        """LM dispatches issued so far (wave mode: one per wave)."""
        return self.backend.decode_dispatches

    # -- constructors -------------------------------------------------------

    @classmethod
    def monolithic(cls, params, cfg: ModelConfig, rag: RagConfig,
                   retriever: Optional[Retriever] = None,
                   max_seq: Optional[int] = None, wave: bool = True,
                   kv_slots: Optional[int] = None,
                   attn_backend: Optional[str] = None,
                   attn_interpret: Optional[bool] = None,
                   attn_seq_block: int = 16,
                   speculate_k: int = 0,
                   speculate_verify: bool = True) -> "RalmEngine":
        return cls(MonolithicBackend(params, cfg), retriever, rag,
                   max_seq=max_seq, wave=wave, kv_slots=kv_slots,
                   attn_backend=attn_backend, attn_interpret=attn_interpret,
                   attn_seq_block=attn_seq_block,
                   speculate_k=speculate_k,
                   speculate_verify=speculate_verify)

    @classmethod
    def disaggregated(cls, params, cfg: ModelConfig, rag: RagConfig,
                      db_params: IVFPQParams, db_shards: List[IVFPQShard],
                      search_cfg: ChamVSConfig,
                      payload_tokens: Optional[jnp.ndarray] = None,
                      chunk_table: Optional[jnp.ndarray] = None,
                      lm_devices: int = 1, ret_devices: int = 1,
                      query_proj: Optional[jnp.ndarray] = None,
                      max_seq: Optional[int] = None,
                      measure: bool = True, wave: bool = True,
                      kv_slots: Optional[int] = None,
                      attn_backend: Optional[str] = None,
                      attn_interpret: Optional[bool] = None,
                      attn_seq_block: int = 16) -> "RalmEngine":
        backend = DisaggregatedBackend(params, cfg, lm_devices=lm_devices,
                                       ret_devices=ret_devices,
                                       measure=measure)
        retriever = DistributedRetriever(
            backend.ret_mesh, db_params, db_shards, search_cfg,
            payload_tokens=payload_tokens, chunk_table=chunk_table,
            query_proj=query_proj)
        return cls(backend, retriever, rag, max_seq=max_seq, wave=wave,
                   kv_slots=kv_slots, attn_backend=attn_backend,
                   attn_interpret=attn_interpret,
                   attn_seq_block=attn_seq_block)

    @classmethod
    def from_config(cls, config: EngineConfig, params, datastore,
                    search_cfg: ChamVSConfig,
                    query_proj: Optional[jnp.ndarray] = None
                    ) -> "RalmEngine":
        """Stand an engine up from an ``EngineConfig`` + a built
        ``Datastore`` (see ``repro.serve.datastore``). Falls back to a
        monolithic engine (with a warning) when ``disaggregate`` is
        requested on a single-device host."""
        # plumb the search-kernel selection (Pallas vs ref, interpret
        # mode, fused vs staged scan) from the deployment config down to
        # ChamVSConfig — the registry KernelSpec everything routes with
        search_cfg = search_cfg.with_kernel(config.kernel_backend,
                                            config.kernel_interpret,
                                            config.kernel_fused)
        if config.disaggregate and len(jax.devices()) < 2:
            import warnings
            warnings.warn(
                "EngineConfig.disaggregate=True needs >= 2 devices; "
                f"found {len(jax.devices())} — falling back to a "
                "monolithic engine (no PoolTimes).", RuntimeWarning,
                stacklevel=2)
        if config.disaggregate and len(jax.devices()) >= 2 and \
                config.async_retrieval:
            import warnings
            warnings.warn(
                "EngineConfig.async_retrieval is not wired into the "
                "disaggregated path yet — falling back to the synchronous "
                "DistributedRetriever (no RetrievalService coalescing or "
                "cache).", RuntimeWarning, stacklevel=2)
        if config.disaggregate and len(jax.devices()) >= 2:
            if config.speculate_k > 0:
                import warnings
                warnings.warn(
                    "EngineConfig.speculate_k is not wired into the "
                    "disaggregated path (the synchronous "
                    "DistributedRetriever has no async handles) — "
                    "speculation stays off.", RuntimeWarning,
                    stacklevel=2)
            eng = cls.disaggregated(
                params, config.model, config.rag, datastore.params,
                datastore.shards, search_cfg,
                payload_tokens=datastore.payload_tokens,
                chunk_table=datastore.chunk_table,
                lm_devices=config.lm_devices,
                ret_devices=config.ret_devices, query_proj=query_proj,
                max_seq=config.max_seq, wave=config.wave_decode,
                kv_slots=config.kv_slots,
                attn_backend=config.attn_backend,
                attn_interpret=config.attn_interpret,
                attn_seq_block=config.attn_seq_block)
        else:
            if config.retrieval_cache > 0 and not config.async_retrieval:
                import warnings
                warnings.warn(
                    "EngineConfig.retrieval_cache requires "
                    "async_retrieval=True (the cache lives in the "
                    "RetrievalService) — ignoring it.", RuntimeWarning,
                    stacklevel=2)
            speculate_k = config.speculate_k
            if speculate_k > 0 and not config.async_retrieval:
                import warnings
                warnings.warn(
                    "EngineConfig.speculate_k requires "
                    "async_retrieval=True (speculation hides the "
                    "RetrievalService's async scan behind decode; a "
                    "synchronous retriever has nothing to hide) — "
                    "disabling speculation.", RuntimeWarning,
                    stacklevel=2)
                speculate_k = 0
            ft_wanted = (config.shard_replicas > 1 or
                         config.retrieval_deadline_s > 0.0 or
                         config.chaos_plan is not None)
            if ft_wanted and not config.async_retrieval:
                import warnings
                warnings.warn(
                    "EngineConfig retrieval fault-tolerance knobs "
                    "(shard_replicas / retrieval_deadline_s / chaos_plan) "
                    "require async_retrieval=True (the dispatch loop "
                    "lives in the RetrievalService) — ignoring them.",
                    RuntimeWarning, stacklevel=2)
            if config.async_retrieval:
                from repro.retrieval.replica import FailoverConfig
                from repro.retrieval.service import ServiceConfig
                failover = None
                if ft_wanted:
                    failover = FailoverConfig(
                        replicas=max(1, config.shard_replicas),
                        dispatch_deadline_s=config.retrieval_deadline_s,
                        hedge_quantile=config.hedge_quantile)
                retriever = datastore.async_retriever(
                    search_cfg, query_proj=query_proj,
                    service_cfg=ServiceConfig(
                        cache_entries=config.retrieval_cache,
                        measure=config.retrieval_measure,
                        failover=failover))
                if config.chaos_plan is not None:
                    retriever.service.install_chaos(config.chaos_plan)
            else:
                retriever = datastore.retriever(search_cfg,
                                                query_proj=query_proj)
            eng = cls.monolithic(params, config.model, config.rag,
                                 retriever=retriever,
                                 max_seq=config.max_seq,
                                 wave=config.wave_decode,
                                 kv_slots=config.kv_slots,
                                 attn_backend=config.attn_backend,
                                 attn_interpret=config.attn_interpret,
                                 attn_seq_block=config.attn_seq_block,
                                 speculate_k=speculate_k,
                                 speculate_verify=config.speculate_verify)
        eng.scheduler.max_active = config.max_active
        if config.trace:
            eng.set_tracer(Tracer(enabled=True))
        eng.trace_path = config.trace_path
        return eng

    # -- KV-cache pool admission (wave mode) --------------------------------

    def check_admissible(self, request: RalmRequest) -> None:
        """Reject-at-submit guard: a request that can NEVER fit the
        fixed-capacity pool must fail in ``submit()``, not poison the
        FIFO queue for everyone behind it when ``_admit`` reaches it."""
        if self.wave and self.kv_slots is not None and \
                request.prompt.shape[0] > self.kv_slots:
            raise ValueError(
                f"request batch of {request.prompt.shape[0]} rows can "
                f"never fit kv_slots={self.kv_slots}")

    def can_admit(self, request: RalmRequest) -> bool:
        """Admission check the scheduler consults before ``start``: a
        fixed-capacity pool defers requests until completions free
        enough slot rows (an auto-growing pool admits everything)."""
        if not self.wave or self.kv_slots is None:
            return True
        B = request.prompt.shape[0]
        return self.pool is None or self.pool.num_free >= B

    def _ensure_pool(self, rows: int, need_seq: int) -> KVCachePool:
        """Create the pool lazily (shapes depend on the first admitted
        request unless ``max_seq``/``kv_slots`` pin them) and grow it —
        slot rows double, the sequence axis extends — when an admission
        needs more than it has."""
        if self.pool is None:
            cap = (self.kv_slots if self.kv_slots is not None
                   else max(next_pow2(rows), 8))
            self.pool = KVCachePool(self.cfg, cap,
                                    self.max_seq or need_seq,
                                    fixed=self.kv_slots is not None,
                                    seq_block=self.attn_seq_block)
            self.pool.tracer = self.tracer
        pool = self.pool
        if self.max_seq is None and need_seq > pool.max_seq:
            pool.grow_seq(need_seq)
        if pool.num_free < rows:
            pool.grow_slots(max(pool.capacity * 2,
                                next_pow2(pool.num_used + rows)))
        return pool

    def release(self, seq: SequenceState) -> None:
        """Return a finished sequence's slot rows to the pool."""
        if seq.spec_points:
            # safety net — the scheduler settles points via
            # spec_finalize before releasing; anything left here
            # (e.g. a cancelled request) is discarded unverified
            stats = self.spec_stats
            for p in seq.spec_points:
                cancel = getattr(p.handle, "cancel", None)
                if cancel is not None:
                    cancel()
                stats.spec_discarded += 1
            seq.spec_points.clear()
        if seq.slots is not None and self.pool is not None:
            self.pool.release(seq.slots)
            seq.slots = None

    # -- the canonical step (called by the scheduler) -----------------------

    def start(self, request: RalmRequest) -> SequenceState:
        """Prefill a request into an active sequence. Wave mode: claim
        one pool slot per prompt row, prefill at the pool's ``max_seq``
        (so cache leaves line up slot-for-slot) and scatter the rows in;
        the request itself holds no cache."""
        B, T0 = request.prompt.shape
        request.times.admit = time.perf_counter()
        tr = self.tracer
        if tr.enabled:
            # retroactive span on the request track: the queue wait
            # started back at submit() (times.arrival), which predates
            # this call — plus the flow arrow Perfetto draws from here
            # to wherever this request's first token lands (see _emit)
            args = {"request_id": request.request_id,
                    "trace_id": request.trace_id, "tenant": request.tenant,
                    "rows": B}
            if request.times.arrival is not None:
                tr.complete("queue.wait", "requests",
                            request.times.arrival,
                            request.times.admit - request.times.arrival,
                            args=args)
            if request.trace_id is not None:
                tr.flow_start(request.trace_id)
        with tr.span("sched.admit", "requests",
                     args={"request_id": request.request_id,
                           "rows": B, "prompt_len": T0}
                     if tr.enabled else None):
            if self.wave:
                pool = self._ensure_pool(B, T0 + request.steps)
                slots = pool.alloc(B)
                caches, enc_states, logits0, hidden0 = \
                    self.backend.prefill(self.rag, request.prompt,
                                         pool.max_seq)
                pool.write_prefill(slots, caches)
                if enc_states is not None:
                    pool.write_enc(slots, enc_states)
                return SequenceState(
                    request=request, caches=None, enc_states=None,
                    out=[request.prompt], cur=request.prompt[:, -1:],
                    t0=T0, logits0=logits0, hidden0=hidden0,
                    rng=request.rng, slots=slots)
            max_seq = self.max_seq or (T0 + request.steps)
            caches, enc_states, logits0, hidden0 = self.backend.prefill(
                self.rag, request.prompt, max_seq)
            return SequenceState(
                request=request, caches=caches, enc_states=enc_states,
                out=[request.prompt], cur=request.prompt[:, -1:], t0=T0,
                logits0=logits0, hidden0=hidden0, rng=request.rng)

    def dispatch_decode(self, seq: SequenceState
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Phase 1: one LM step. At step 0 the prefill already produced
        both the logits and the retrieval query, so nothing runs."""
        if seq.step == 0:
            logits, hidden = seq.logits0, seq.hidden0
            seq.logits0 = seq.hidden0 = None
            return logits, hidden
        B = seq.cur.shape[0]
        position = jnp.full((B,), seq.t0 + seq.step - 1, jnp.int32)
        logits, seq.caches, hidden = self.backend.decode(
            seq.caches, seq.cur, position, enc_states=seq.enc_states,
            attn_spec=self.attn_spec)
        return logits, hidden

    def _search(self, queries: jnp.ndarray):
        t0 = time.time()
        dists, ids = self.retriever.search(queries)
        if self.times is not None:
            dists.block_until_ready()
            self.times.search_s.append(time.time() - t0)
        return dists, ids

    def _retrieval_due(self, step: int) -> bool:
        # pure host arithmetic (same semantics as rag.should_retrieve):
        # this runs in phase 2a while decodes are in flight, so it must
        # not touch the device
        return (self.retriever is not None and self.rag.mode != "none" and
                (self.rag.interval <= 1 or step % self.rag.interval == 0))

    def dispatch_search(self, seq: SequenceState, hidden: jnp.ndarray):
        """Phase 2a: issue this sequence's retrieval query, without
        dispatching the kernel. Returns a ``SearchHandle`` when the
        retriever batches asynchronously (``AsyncRetriever``), else
        ``None`` — the synchronous path searches inside ``finish_step``.
        """
        if not self._retrieval_due(seq.step):
            return None
        submit = getattr(self.retriever, "search_async", None)
        if submit is None:
            return None
        return submit(hidden)

    def flush_searches(self) -> None:
        """Phase 2b: coalesce every query issued by ``dispatch_search``
        into one batched kernel dispatch (no-op for sync retrievers)."""
        flush = getattr(self.retriever, "flush", None)
        if flush is not None:
            flush()

    def finish_step(self, seq: SequenceState, logits: jnp.ndarray,
                    hidden: jnp.ndarray, search=None) -> None:
        """Phase 2 (2c when async): retrieve (if due) + integrate +
        sample one token. ``search`` is the ``SearchHandle`` returned by
        ``dispatch_search``, if any."""
        s, rag = seq.step, self.rag
        log_or_prob = logits
        if self._retrieval_due(s):
            if search is not None:
                t0 = time.time()
                dists, ids = search.result()
                if getattr(search, "partial", False):
                    seq.request.partial_steps += 1
                if self.times is not None:
                    dists.block_until_ready()
                    self.times.search_s.append(time.time() - t0)
            else:
                dists, ids = self._search(hidden)
            if seq.request.trace is not None:
                seq.request.trace.append(dict(step=s, ids=np.asarray(ids)))
            if rag.mode == "knnlm":
                toks = self.retriever.resolve(ids, kind="tokens")
                log_or_prob = rag_lib.knnlm_interpolate(
                    logits, dists, toks, rag.lam, rag.temperature)
            elif rag.mode == "retro" and self.cfg.arch == "encdec":
                B = seq.cur.shape[0]
                chunks = self.retriever.resolve(ids, kind="chunks")
                seq.enc_states = self.backend.encode_chunks(
                    chunks.reshape(B, -1))
        if seq.request.greedy or seq.rng is None:
            nxt = jnp.argmax(log_or_prob, axis=-1).astype(jnp.int32)
        else:
            seq.rng, k = jax.random.split(seq.rng)
            nxt = jax.random.categorical(k, log_or_prob).astype(jnp.int32)
        self._emit(seq, nxt)

    # -- the wave-batched step (one dispatch per phase per wave) ------------

    def dispatch_wave(self, seqs: List[SequenceState]
                      ) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
        """Phase 1, wave mode: ONE ``decode_wave`` dispatch advances every
        step>0 sequence (step-0 sequences consume their prefill outputs —
        nothing to run). Returns per-sequence (logits [B,V], hidden
        [B,d]) views sliced from the wave outputs."""
        outs: List = [None] * len(seqs)
        wave = []
        for i, seq in enumerate(seqs):
            if seq.step == 0:
                outs[i] = (seq.logits0, seq.hidden0)
                seq.logits0 = seq.hidden0 = None
            else:
                wave.append((i, seq))
        if not wave:
            return outs
        pool = self.pool
        tokens = jnp.concatenate([seq.cur for _, seq in wave], axis=0)
        slots = np.concatenate([seq.slots for _, seq in wave])
        positions = np.concatenate(
            [np.full(seq.cur.shape[0], seq.t0 + seq.step - 1, np.int32)
             for _, seq in wave])
        # the wave's positions are host arrays, so the block-aligned
        # valid prefix is known before dispatch: attention reads crop to
        # kv_len instead of the pool's padded max_seq (pad rows sit at
        # position 0 and never extend it)
        max_pos = int(positions.max())
        tokens, slots, positions = pool.pad_wave(tokens, slots, positions)
        kv_len = pool.attn_len(max_pos, bucket=len(slots))
        tr = self.tracer
        with tr.span("wave.decode", "wave",
                     args={"rows": len(wave), "bucket": len(slots),
                           "kv_len": kv_len} if tr.enabled else None):
            logits, pool.caches, hidden = self.backend.decode_wave(
                pool.caches, tokens, jnp.asarray(slots),
                jnp.asarray(positions), enc_states=pool.gather_enc(slots),
                kv_len=kv_len, attn_spec=self.attn_spec)
        off = 0
        for i, seq in wave:
            B = seq.cur.shape[0]
            outs[i] = (logits[off:off + B], hidden[off:off + B])
            off += B
        return outs

    def dispatch_search_wave(self, seqs: List[SequenceState],
                             decoded: List) -> List:
        """Phase 2a/2b, wave mode: issue every due sequence's retrieval
        query. Async retrievers coalesce via the service (flushed by the
        scheduler's ``flush_searches``); synchronous retrievers get their
        rows concatenated into ONE batched ``search`` here."""
        searches: List = [None] * len(seqs)
        due = [i for i, seq in enumerate(seqs)
               if self._retrieval_due(seq.step)]
        if not due:
            return searches
        submit = getattr(self.retriever, "search_async", None)
        if submit is not None:
            issued = 0
            for i in due:
                seq = seqs[i]
                if self._spec_eligible(seq):
                    src = self._spec_source(seq, decoded[i][1])
                    if src is not None:
                        # fire-and-forget: the real search coalesces
                        # into this wave's flush; decode continues on
                        # the stale neighbors; spec_harvest verifies
                        # 1..k waves later, off the critical path
                        searches[i] = _SpecIssue(submit(decoded[i][1]),
                                                 src[0], src[1])
                        self.spec_stats.spec_issued += 1
                        issued += 1
                        continue
                searches[i] = submit(decoded[i][1])
            if issued and self.tracer.enabled:
                self.tracer.instant("spec.issue", "wave",
                                    args={"points": issued})
            return searches
        queries = jnp.concatenate([decoded[i][1] for i in due], axis=0)
        dists, ids = self._search(queries)
        off = 0
        for i in due:
            B = decoded[i][1].shape[0]
            searches[i] = (dists[off:off + B], ids[off:off + B])
            off += B
        return searches

    def finish_wave(self, seqs: List[SequenceState], decoded: List,
                    searches: List) -> None:
        """Phase 2c, wave mode: integrate + sample for the whole wave in
        batched dispatches — one ``resolve`` + one ``knnlm_interpolate``
        over all due rows, one RETRO re-encode over all due chunks, one
        greedy argmax over every greedy row. Per-request ``rng`` sampling
        stays per-sequence (each request owns an independent key chain,
        so batching it would change the sampled tokens)."""
        rag = self.rag
        rows: List[jnp.ndarray] = []
        knn = []                # (row_idx, logits, dists, ids)
        retro = []              # (seq, chunks [B, k*chunk_len])
        spec_new = []           # (seq, _SpecIssue, logits)
        for seq, out, search in zip(seqs, decoded, searches):
            logits, hidden = out
            if search is not None:
                if isinstance(search, _SpecIssue):
                    # speculated row: integrate the STALE neighbors now
                    # (no result() — the real search stays in flight);
                    # the trace entry waits for verification, which
                    # records the real ids
                    knn.append((len(rows), logits,
                                jnp.asarray(search.dists),
                                jnp.asarray(search.ids)))
                    spec_new.append((seq, search, logits))
                    rows.append(logits)
                    continue
                if hasattr(search, "result"):      # async SearchHandle
                    t0 = time.time()
                    dists, ids = search.result()
                    if self.times is not None:
                        dists.block_until_ready()
                        self.times.search_s.append(time.time() - t0)
                else:                              # pre-sliced sync batch
                    dists, ids = search
                partial = getattr(search, "partial", False)
                if partial:
                    seq.request.partial_steps += 1
                if seq.request.trace is not None:
                    seq.request.trace.append(
                        dict(step=seq.step, ids=np.asarray(ids)))
                if rag.mode == "knnlm":
                    knn.append((len(rows), logits, dists, ids))
                    if self.speculate_k > 0 and not partial:
                        # a non-speculated due row still refreshes the
                        # seed the NEXT due step speculates with (a
                        # partial result would seed speculation with
                        # degraded neighbors — keep the last full set)
                        seq.last_neighbors = (dists, ids)
                elif rag.mode == "retro" and self.cfg.arch == "encdec":
                    retro.append((seq, ids))
            rows.append(logits)
        if knn:
            logits_cat = jnp.concatenate([e[1] for e in knn], axis=0)
            dists_cat = jnp.concatenate([e[2] for e in knn], axis=0)
            ids_cat = jnp.concatenate([e[3] for e in knn], axis=0)
            toks = self.retriever.resolve(ids_cat, kind="tokens")
            mixed = rag_lib.knnlm_interpolate(
                logits_cat, dists_cat, toks, rag.lam, rag.temperature)
            off = 0
            for idx, logits, _, _ in knn:
                B = logits.shape[0]
                rows[idx] = mixed[off:off + B]
                off += B
        if retro:
            # one chunk resolve + one re-encode over every due row, like
            # the knnlm branch above
            chunks = self.retriever.resolve(
                jnp.concatenate([ids for _, ids in retro], axis=0),
                kind="chunks")
            W = chunks.shape[0]
            enc = self.backend.encode_chunks(chunks.reshape(W, -1))
            off = 0
            for seq, _ in retro:
                B = seq.cur.shape[0]
                self.pool.write_enc(seq.slots, enc[off:off + B])
                off += B
        greedy = [i for i, seq in enumerate(seqs)
                  if seq.request.greedy or seq.rng is None]
        if greedy:
            nxt_cat = jnp.argmax(
                jnp.concatenate([rows[i] for i in greedy], axis=0),
                axis=-1).astype(jnp.int32)
            off = 0
            for i in greedy:
                B = rows[i].shape[0]
                self._emit(seqs[i], nxt_cat[off:off + B])
                off += B
        for i, seq in enumerate(seqs):
            if seq.request.greedy or seq.rng is None:
                continue
            seq.rng, k = jax.random.split(seq.rng)
            self._emit(seq, jax.random.categorical(
                k, rows[i]).astype(jnp.int32))
        # register the wave's speculation points AFTER the emits so each
        # captures the token it produced and the pre-emit out length
        # (eligibility guarantees these rows are greedy, so `seq.cur`
        # now holds the token the stale mix argmax'd)
        for seq, issue, logits in spec_new:
            seq.spec_points.append(SpecPoint(
                step=seq.step - 1, handle=issue.handle, logits=logits,
                emitted=seq.cur, out_len=len(seq.out) - 1))

    def _emit(self, seq: SequenceState, nxt: jnp.ndarray) -> None:
        seq.cur = nxt[:, None]
        seq.out.append(seq.cur)
        req = seq.request
        first = req.times.first_token is None
        if req.on_token is not None:
            # the streaming hook needs host tokens, which forces the
            # wave's device work to complete here — one sync per wave
            # (the first row's asarray blocks; the rest are free). The
            # first-token timestamp is taken AFTER the sync so TTFT
            # measures token availability, not dispatch.
            host = np.asarray(nxt)
            if first:
                req.times.first_token = time.perf_counter()
            req.on_token(seq.step, host)
        elif first:
            # no streaming consumer: stamp dispatch time (approximate —
            # jax async dispatch means the value may still be in flight)
            req.times.first_token = time.perf_counter()
        if first and req.trace_id is not None and self.tracer.enabled:
            # close the TTFT flow arrow opened at admission: Perfetto
            # draws queue.wait -> the wave that produced the first token
            self.tracer.flow_end(req.trace_id, track="wave",
                                 t_s=req.times.first_token)
        seq.step += 1

    # -- speculative retrieval (RaLMSpec, arXiv 2401.14021) -----------------

    @property
    def spec_stats(self):
        """Where speculation counters land: the retrieval service's
        ``RetrievalStats`` when one exists (so /statsz and the bench see
        one retrieval plane), else a local instance."""
        service = getattr(self.retriever, "service", None)
        if service is not None:
            return service.stats
        if self._local_spec_stats is None:
            from repro.retrieval.stats import RetrievalStats
            self._local_spec_stats = RetrievalStats()
        return self._local_spec_stats

    def _spec_eligible(self, seq: SequenceState) -> bool:
        """Per-row speculation gate, evaluated at each due step (the
        degrade ladder mutates ``rag`` between waves, so this cannot be
        decided at construction): greedy kNN-LM rows only — sampling
        consumes rng state a rollback cannot restore, and a streaming
        consumer (``on_token``) would have already seen tokens a
        rollback retracts."""
        req = seq.request
        return (self.speculate_k > 0
                and self.rag.mode == "knnlm"
                and (req.greedy or seq.rng is None)
                and req.on_token is None
                and len(seq.spec_points) < self.speculate_k)

    def _spec_source(self, seq: SequenceState, hidden: jnp.ndarray):
        """The stale neighbors to decode ahead with: the sequence's
        last verified result, else a stale-tolerant cache probe (a
        cross-request seed — partial-batch cache hits feeding
        speculation), else None (the row searches synchronously and
        seeds the next due step)."""
        if seq.last_neighbors is not None:
            return seq.last_neighbors
        lookup = getattr(self.retriever, "stale_lookup", None)
        if lookup is not None:
            return lookup(hidden)
        return None

    def spec_harvest(self, seqs: List[SequenceState],
                     decoded: Optional[List] = None,
                     force: bool = False) -> None:
        """Verify speculation points whose real search has had
        ``_spec_depth`` waves to land (all of them under ``force``).

        Verification compares *emitted tokens*, not neighbor ids: the
        point's saved pre-interpolation logits are re-mixed with the
        REAL (dists, tokens) — exactly the baseline's ``finish_step``
        math — and the argmax is compared against the token the stale
        mix emitted. Match -> the speculated timeline IS the baseline
        timeline (accept). Mismatch -> roll back and replay
        (``_spec_rollback``). The forcing of the in-flight results is
        timed into ``spec_wait`` — the residual retrieval time NOT
        hidden behind decode, the bench's numerator."""
        pts: List[Tuple[Optional[int], SequenceState, SpecPoint]] = []
        for idx, seq in enumerate(seqs):
            if not seq.spec_points:
                continue
            for p in seq.spec_points:
                p.age += 1
            take = 0
            for p in seq.spec_points:
                if force or p.age >= self._spec_depth:
                    take += 1
                else:
                    break
            for p in seq.spec_points[:take]:
                pts.append((idx if decoded is not None else None, seq, p))
            del seq.spec_points[:take]
        if not pts:
            return
        stats = self.spec_stats
        tr = self.tracer
        rag = self.rag
        with tr.span("spec.verify", "wave",
                     args={"points": len(pts), "force": force}
                     if tr.enabled else None):
            t0 = time.perf_counter()
            res = [p.handle.result() for _, _, p in pts]
            # spec_wait times ONLY the forcing of the in-flight search
            # results. XLA drains its queue in enqueue order, so this
            # wait excludes the decode wave dispatched after the scan —
            # it is the residual retrieval time the overlap failed to
            # hide, comparable to the baseline's queue_wait + scan.
            # Results already materialized (is_ready) were fully hidden.
            for d, i in res:
                ready_d = getattr(d, "is_ready", None)
                ready_i = getattr(i, "is_ready", None)
                if (ready_d is None or ready_d()) and \
                        (ready_i is None or ready_i()):
                    stats.spec_landed += 1
            jax.block_until_ready([x for pair in res for x in pair])
            stats.spec_wait.add(time.perf_counter() - t0)
            partials = [getattr(p.handle, "partial", False)
                        for _, _, p in pts]
            for (_, seq, _), part in zip(pts, partials):
                if part:
                    # the real search timed out into a partial result:
                    # the point still settles (verify math below runs on
                    # the degraded neighbors, so verification can never
                    # hang on a dead shard), but the result is not a
                    # speculation seed
                    stats.ft_spec_flushed += 1
                    seq.request.partial_steps += 1
            if not self.speculate_verify:
                # trust-the-stale mode: adopt the real neighbors as the
                # next seed, never compare, never roll back
                for (_, seq, _), (d, i), part in zip(pts, res, partials):
                    if not part:
                        seq.last_neighbors = (d, i)
                return
            # ONE batched interpolate + argmax + host sync over every
            # point being verified this wave; this math is NOT counted
            # in spec_wait — the baseline pays the same interpolate in
            # its finish phase
            d_cat = jnp.concatenate([d for d, _ in res], axis=0)
            i_cat = jnp.concatenate([i for _, i in res], axis=0)
            logits_cat = jnp.concatenate([p.logits for _, _, p in pts],
                                         axis=0)
            toks = self.retriever.resolve(i_cat, kind="tokens")
            mixed = rag_lib.knnlm_interpolate(
                logits_cat, d_cat, toks, rag.lam, rag.temperature)
            nxt_cat = np.asarray(
                jnp.argmax(mixed, axis=-1).astype(jnp.int32))
            emit_cat = np.asarray(
                jnp.concatenate([p.emitted[:, 0] for _, _, p in pts]))
            off = 0
            rolled: set = set()
            for (idx, seq, p), (d, i), part in zip(pts, res, partials):
                B = p.logits.shape[0]
                corrected = nxt_cat[off:off + B]
                emitted = emit_cat[off:off + B]
                off += B
                if id(seq) in rolled:
                    # a later point of a sequence that already rolled
                    # back this harvest: its query came from the
                    # discarded timeline
                    stats.spec_discarded += 1
                    continue
                stats.spec_verified += 1
                if not part:
                    seq.last_neighbors = (d, i)
                if seq.request.trace is not None:
                    # the REAL retrieval for this step — same entry the
                    # baseline records (acceptance is token equality,
                    # which doesn't require id equality)
                    seq.request.trace.append(
                        dict(step=p.step, ids=np.asarray(i)))
                if np.array_equal(corrected, emitted):
                    stats.spec_accepted += 1
                else:
                    stats.spec_rollbacks += 1
                    rolled.add(id(seq))
                    self._spec_rollback(seq, p, corrected, decoded, idx)

    def _spec_rollback(self, seq: SequenceState, point: SpecPoint,
                       corrected: np.ndarray,
                       decoded: Optional[List], idx: Optional[int]) -> None:
        """Mismatch path: rewind to the speculation point and replay
        through the per-sequence oracle semantics with verified
        neighbors.

        The corrected token for the speculation step itself is free —
        the verification interpolation already computed it. Later steps
        replay as single-row waves with BLOCKING searches at due steps,
        which is exactly the baseline's math on the corrected token
        stream, so greedy parity holds by induction."""
        stats = self.spec_stats
        tr = self.tracer
        t0 = time.perf_counter()
        cur_step = seq.step
        with tr.span("spec.rollback", "wave",
                     args={"step": point.step, "depth":
                           cur_step - point.step}
                     if tr.enabled else None):
            # later points' queries/logits came from the timeline being
            # discarded — drop them unverified
            for p in seq.spec_points:
                cancel = getattr(p.handle, "cancel", None)
                if cancel is not None:
                    cancel()
                stats.spec_discarded += 1
            seq.spec_points.clear()
            # token watermark: truncate to before the speculated emit
            del seq.out[point.out_len:]
            seq.cur = seq.out[-1][:, -1:]
            seq.step = point.step
            if self.pool is not None and seq.slots is not None:
                # KV watermark. Positions written so far: the prompt
                # (t0) plus one per decode step 1..s at t0+s-1 — plus
                # the current wave's phase-1 decode when we are
                # mid-wave (decoded is not None).
                old_len = seq.t0 + cur_step - (0 if decoded is not None
                                               else 1)
                keep_len = seq.t0 + point.step
                if old_len > keep_len:
                    self.pool.rewind(seq.slots, keep_len=keep_len,
                                     old_len=old_len)
            # the speculation step's corrected token (no decode needed)
            self._emit(seq, jnp.asarray(corrected, jnp.int32))
            stats.spec_replayed_steps += 1
            # replay the steps that decoded on the wrong token stream
            while seq.step < cur_step:
                logits, hidden = self.dispatch_wave([seq])[0]
                log_or_prob = logits
                if self._retrieval_due(seq.step):
                    dists, ids = self.retriever.search(hidden)  # blocks
                    seq.last_neighbors = (dists, ids)
                    if seq.request.trace is not None:
                        seq.request.trace.append(
                            dict(step=seq.step, ids=np.asarray(ids)))
                    toks = self.retriever.resolve(ids, kind="tokens")
                    log_or_prob = rag_lib.knnlm_interpolate(
                        logits, dists, toks, self.rag.lam,
                        self.rag.temperature)
                self._emit(seq, jnp.argmax(
                    log_or_prob, axis=-1).astype(jnp.int32))
                stats.spec_replayed_steps += 1
            if decoded is not None and idx is not None:
                # mid-wave: the current wave's phase-1 output for this
                # row was computed from the wrong token — redo it so
                # the pending finish_wave integrates corrected logits
                decoded[idx] = self.dispatch_wave([seq])[0]
        stats.spec_replay.add(time.perf_counter() - t0)

    def spec_finalize(self, seq: SequenceState) -> None:
        """Settle a finishing sequence's outstanding points BEFORE its
        response is emitted: cancelled requests discard them, completed
        ones force-verify (so the response tokens carry the parity
        guarantee)."""
        if not seq.spec_points:
            return
        if seq.request.cancelled:
            stats = self.spec_stats
            for p in seq.spec_points:
                cancel = getattr(p.handle, "cancel", None)
                if cancel is not None:
                    cancel()
                stats.spec_discarded += 1
            seq.spec_points.clear()
            return
        self.spec_harvest([seq], decoded=None, force=True)

    def flush_speculation(self) -> None:
        """Force-verify EVERY outstanding speculation point. The
        degrade ladder calls this before mutating retrieval quality
        (nprobe/interval/mode): in-flight points must verify with the
        math they were issued under, and the next due step re-seeds at
        the new quality."""
        if self.speculate_k <= 0:
            return
        seqs = [s for s in self.scheduler.active if s.spec_points]
        if seqs:
            self.spec_harvest(seqs, decoded=None, force=True)

    # -- serving API --------------------------------------------------------

    def submit(self, request: RalmRequest) -> int:
        return self.scheduler.submit(request)

    def step(self) -> List[RalmResponse]:
        return self.scheduler.step()

    def run(self) -> List[RalmResponse]:
        """Drain the scheduler; includes any responses that completed
        during an interleaved ``generate()`` call."""
        out = self._unclaimed + self.scheduler.run()
        self._unclaimed = []
        return out

    def generate(self, prompt: jnp.ndarray, steps: int, *,
                 greedy: bool = True, rng: Optional[jax.Array] = None,
                 trace: Optional[list] = None) -> jnp.ndarray:
        """Synchronous convenience: one request, run to completion.
        Other in-flight requests also advance; their responses are held
        for the next ``run()`` call, not discarded."""
        rid = self.submit(RalmRequest(prompt=jnp.asarray(prompt),
                                      steps=steps, greedy=greedy, rng=rng,
                                      trace=trace))
        result = None
        for resp in self.scheduler.run():
            if resp.request_id == rid:
                result = resp
            else:
                self._unclaimed.append(resp)
        if result is None:  # pragma: no cover
            raise RuntimeError("request did not complete")
        return jnp.asarray(result.tokens)

    def generate_batches(self, prompts: List[jnp.ndarray], steps: int
                         ) -> List[np.ndarray]:
        """Pipelined convenience: several request batches in flight at
        once (the old ``generate_pipelined``). Results in submit order."""
        rids = [self.submit(RalmRequest(prompt=jnp.asarray(p), steps=steps))
                for p in prompts]
        by_id = {r.request_id: r.tokens for r in self.run()}
        return [np.asarray(by_id[rid]) for rid in rids]
