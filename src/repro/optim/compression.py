"""Gradient compression for cross-pod reduction (distributed-optimization
trick, DESIGN.md §4 beyond-paper list).

Int8 stochastic-rounding quantization with per-tensor scales; the compressed
all-reduce runs the expensive inter-pod hop at 1/4 the bytes of bf16:

    g_q, scale = quantize_int8(g)
    g_sum = psum(g_q.astype(int32)) ; scale_max = pmax(scale)
    g ~= dequantize(g_sum, scale_max)

Exposed two ways: (a) pure quantize/dequantize utilities (tested for bias /
error bounds in tests/test_compression.py), (b) ``compressed_psum`` for
shard_map-based training loops. The GSPMD train path keeps full-precision
reduction by default; the launcher enables compression with
``--grad-compression int8`` which wraps the gradient tree between backward
and optimizer with a shard_map over the "pod" axis only (intra-pod ICI is
fast; the pod hop is the slow link, paper's disaggregation logic applied to
training comms).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, key: jax.Array | None = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 with optional stochastic rounding.

    Returns (q int8, scale f32) with x ~= q * scale."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    y = x32 / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(tree: Any, axis_name: str, key: jax.Array | None = None
                    ) -> Any:
    """All-reduce a gradient tree over ``axis_name`` in int8.

    Each participant quantizes with its own scale; scales are max-reduced
    first so the int32 sum dequantizes consistently. Must run inside
    shard_map/pmap with ``axis_name`` bound."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    out = []
    for leaf, k in zip(leaves, keys):
        # consistent scale across participants
        amax = jax.lax.pmax(jnp.max(jnp.abs(leaf.astype(jnp.float32))),
                            axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        y = leaf.astype(jnp.float32) / scale
        if k is not None:
            y = jnp.floor(y + jax.random.uniform(k, y.shape))
        else:
            y = jnp.round(y)
        q = jnp.clip(y, -127, 127).astype(jnp.int8)
        s = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out.append((s.astype(jnp.float32) * scale).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)
