"""Sharded AdamW with optional low-precision state compression.

Optimizer states inherit the parameter partition specs (FSDP: states live
with their shard — ZeRO-equivalent). ``state_dtype="bfloat16"`` halves the
m/v footprint (needed to fit llama3-405b training in 256x16GB; see
EXPERIMENTS.md §Dry-run memory table); the update math always runs in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "bfloat16"   # "float32" for exact Adam moments
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray       # scalar int32
    m: Any                  # like params
    v: Any                  # like params


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(z, params),
                    v=jax.tree.map(z, params))


def lr_schedule(step: jnp.ndarray, cfg: AdamWConfig) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), grads), g


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig
                  ) -> Tuple[Any, OptState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p32
        return ((p32 - lr * delta).astype(p.dtype),
                m32.astype(sdt), v32.astype(sdt))

    # three passes (XLA CSE dedups the shared math); a single pass returning
    # tuples would corrupt NamedTuple param nodes (MambaParams is a tuple)
    new_p = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[0],
                         params, grads, state.m, state.v)
    new_m = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[1],
                         params, grads, state.m, state.v)
    new_v = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[2],
                         params, grads, state.m, state.v)
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, OptState(step, new_m, new_v), metrics
