"""jax version-compatibility shims.

The codebase targets the jax >= 0.6 API surface (top-level ``shard_map``
with ``check_vma``, ``jax.set_mesh``, ``jax.make_mesh(..., axis_types=)``)
but must also run on the older jax baked into the CPU container
(0.4.x: ``jax.experimental.shard_map`` with ``check_rep``, ``with mesh:``,
no ``AxisType``). Every module that touches these APIs goes through here
so the difference lives in exactly one place.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax

try:  # jax >= 0.6 exposes shard_map at top level
    from jax import shard_map as _shard_map
    _SHARD_MAP_KW = "check_vma"
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = "check_rep"

HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the replication-check kwarg spelled per
    version (``check_vma`` >= 0.6, ``check_rep`` before)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_SHARD_MAP_KW: check_vma})


def use_mesh(mesh: jax.sharding.Mesh):
    """Context manager making ``mesh`` ambient: ``jax.set_mesh`` on new
    jax; on old jax a ``Mesh`` is itself a context manager."""
    if HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh


def make_mesh(shape: Sequence[int], axis_names: Tuple[str, ...],
              ) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the installed jax
    distinguishes them (explicit-sharding jax versions default to
    Explicit, which the shard_map code here does not want)."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(shape), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(shape), tuple(axis_names))
