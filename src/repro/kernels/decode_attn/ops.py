"""Registry-routed frontend for the Pallas decode-attention kernel.

Born after the registry (like ``chamvs_scan``), so the spec is the only
selector — no legacy ``backend=``/``interpret=`` kwargs. The routing
between the three flavors ("pallas" | "ref" | the legacy "einsum"
oracle) lives in ``repro.models.attention.decode_attention``; this
module owns only the Pallas leg: tile selection, the single-token
contract, and fallback accounting.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels import registry
from repro.kernels.decode_attn import kernel as _k
from repro.kernels.decode_attn import ref as _ref


def pallas_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, position: jnp.ndarray,
                            window: int = 0, ring: bool = False,
                            spec: Optional[registry.KernelSpec] = None
                            ) -> jnp.ndarray:
    """Streaming decode-attention — ONE dispatch for the whole wave.

    q [B, 1, H, D] | caches [B, S, KV, D] | position [B] -> [B, 1, H, D].
    Multi-token q (speculative / chunked decode) is outside the kernel's
    single-token contract and routes to the grouped ref oracle with a
    recorded fallback.
    """
    spec = registry.resolve("decode_attn", spec)
    B, S = k_cache.shape[0], k_cache.shape[1]
    if q.shape[1] != 1:
        registry.record_fallback(
            "decode_attn", f"T={q.shape[1]} != 1 (the streaming kernel "
            "decodes one token per row)", spec)
        return _ref.ref_decode_attention(q, k_cache, v_cache, position,
                                         window=window, ring=ring)
    return _k.fused_decode_attention(
        q, k_cache, v_cache, position, window=window, ring=ring,
        tile_b=spec.pick_tile_q(B), blk=spec.pick_block_seq(S),
        interpret=spec.interpret)


def count_skipped_blocks(positions: np.ndarray, S: int, blk: int,
                         tile_b: int, window: int = 0, ring: bool = False
                         ) -> tuple:
    """Host-side replica of the kernel's tile-level skip predicate:
    ``(blocks_skipped, blocks_total)`` across the whole grid. Used by
    tests to pin the kernel's skip arithmetic and by stats consumers
    that want the per-tile (not just per-wave) number."""
    pos = np.asarray(positions).reshape(-1)
    assert pos.shape[0] % tile_b == 0 and S % blk == 0
    nb = S // blk
    skipped = total = 0
    for t in range(pos.shape[0] // tile_b):
        tile = pos[t * tile_b:(t + 1) * tile_b]
        for j in range(nb):
            start = j * blk
            live = start <= tile.max()
            if window > 0 and not ring:
                live = live and (start + blk - 1 > tile.min() - window)
            total += 1
            skipped += 0 if live else 1
    return skipped, total
