"""Pallas kernel: streaming decode-attention over the slotted KV pool.

The legacy decode path materializes GQA-expanded K/V to ``[B, S, H, D]``
and a full ``[B, H, 1, S]`` score row over the *entire padded pool seq
axis* every step. This kernel is the dataflow-faithful replacement (the
LM-side twin of ``chamvs_scan``'s streaming K-selection):

  * grid ``(B // tile_b, S // blk)`` — the trailing **kv-block axis** is
    the streaming axis: each step pulls one ``[tile_b, blk, KV, D]``
    K/V block HBM->VMEM and folds it into an online-softmax accumulator
    carried in the *output refs* (their index_map ignores the kv-block
    index, the same scratch-residency trick ``chamvs_scan`` uses for
    its running top-k'), so the ``[B, H, S]`` score row never exists;
  * **GQA-native**: queries arrive pre-grouped as ``[B, KV, G, D]`` and
    scores contract directly against the KV-head axis — no
    ``_repeat_kv`` materialization anywhere;
  * **length-aware**: per-block validity is derived from each row's
    absolute ``position`` (linear slot ``i`` holds position ``i``; ring
    slot ``i`` holds ``pos - ((pos - i) mod S)``; sliding ``window``
    masks on top), and a whole kv block is **skipped** — zero FLOPs,
    accumulators untouched — when every slot in it is invalid for every
    row in the tile: blocks past the tile's max position, and (linear
    caches with a window) blocks wholly below the tile's min window
    edge. Short sequences in a ragged wave therefore stop paying for
    the pool's ``max_seq`` padding.

Both validity families reduce to the same skip predicate
``block_start > max(position)`` (a ring slot ``i`` is invalid exactly
when ``i > pos`` while the ring has not wrapped, and never invalid
after it wraps — at which point ``max(position) >= S - 1`` keeps every
block live).

Validated against the grouped ``ref`` oracle and the legacy einsum path
in ``tests/test_decode_attn.py`` (hypothesis property test). The
in-kernel einsums lower via ``dot_general`` with (row, kv-head) batch
dims; on the CPU containers this runs in interpret mode (parity
harness), compiled on a real accelerator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_attn_kernel(pos_ref, q_ref, k_ref, v_ref,
                        out_ref, m_ref, l_ref, *,
                        blk: int, s_real: int, window: int, ring: bool):
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[:, 0]                                   # [tile_b]
    start = j * blk
    # tile-level skip: every slot in this block invalid for every row
    live = start <= jnp.max(pos)
    if window > 0 and not ring:
        # linear cache + sliding window: blocks wholly below the tile's
        # min window edge are dead too (the window slid past them)
        live = jnp.logical_and(live, start + blk - 1 > jnp.min(pos) - window)

    @pl.when(live)
    def _block():
        q = q_ref[...].astype(jnp.float32)                # [tile_b,KV,G,D]
        k = k_ref[...].astype(jnp.float32)                # [tile_b,blk,KV,D]
        v = v_ref[...].astype(jnp.float32)
        scale = q.shape[-1] ** -0.5
        s = jnp.einsum("bkgd,bskd->bkgs", q, k,
                       preferred_element_type=jnp.float32) * scale
        tile_b = pos.shape[0]
        slot = start + jax.lax.broadcasted_iota(jnp.int32, (tile_b, blk), 1)
        if ring:
            p_slot = pos[:, None] - ((pos[:, None] - slot) % s_real)
            valid = p_slot >= 0
        else:
            p_slot = slot
            valid = p_slot <= pos[:, None]
        if window > 0:
            valid &= p_slot > pos[:, None] - window
        vmask = valid[:, None, None, :]                   # [tile_b,1,1,blk]
        s = jnp.where(vmask, s, NEG_INF)
        m_prev = m_ref[...]                               # [tile_b,KV,G]
        m_new = jnp.maximum(m_prev, s.max(-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(vmask, jnp.exp(s - m_new[..., None]), 0.0)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        pv = jnp.einsum("bkgs,bskd->bkgd", p, v,
                        preferred_element_type=jnp.float32)
        out_ref[...] = out_ref[...] * corr[..., None] + pv
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _final():
        out_ref[...] = out_ref[...] / jnp.maximum(
            l_ref[...][..., None], 1e-20)


@functools.partial(jax.jit, static_argnames=("window", "ring", "tile_b",
                                             "blk", "interpret"))
def fused_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                           v_cache: jnp.ndarray, position: jnp.ndarray,
                           window: int = 0, ring: bool = False,
                           tile_b: int = 1, blk: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """One streaming dispatch for a whole decode wave.

    q [B, 1, H, D] | k_cache/v_cache [B, S, KV, D] | position [B] int32
    -> [B, 1, H, D]. ``tile_b`` must divide B and ``blk`` must divide S
    (the frontend picks legal tiles via the registry heuristics).
    """
    B, S, KV, D = k_cache.shape
    H = q.shape[2]
    G = H // KV
    assert B % tile_b == 0 and S % blk == 0, (B, tile_b, S, blk)
    qg = q[:, 0].reshape(B, KV, G, D)
    pos = jnp.asarray(position, jnp.int32).reshape(B, 1)
    kernel = functools.partial(_decode_attn_kernel, blk=blk, s_real=S,
                               window=window, ring=ring)
    grid = (B // tile_b, S // blk)
    out, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_b, KV, G, D), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((tile_b, blk, KV, D), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((tile_b, blk, KV, D), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=(
            # index_map ignores j: the online-softmax state (acc, m, l)
            # is carried in the output refs across the kv-block axis
            pl.BlockSpec((tile_b, KV, G, D), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((tile_b, KV, G), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tile_b, KV, G), lambda i, j: (i, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, KV, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
        ),
        interpret=interpret,
    )(pos, qg, k_cache, v_cache)
    return out.reshape(B, 1, H, D).astype(v_cache.dtype)
