"""Fused Pallas decode-attention over a slotted / padded KV cache.

``ops.pallas_decode_attention`` is the registry-routed frontend;
``kernel.fused_decode_attention`` the Pallas kernel; ``ref`` the
grouped-einsum oracle (also the CPU serving flavor). The public entry
point is ``repro.models.attention.decode_attention(spec=...)``.
"""
from repro.kernels.decode_attn.ops import pallas_decode_attention
from repro.kernels.decode_attn.ref import ref_decode_attention

__all__ = ["pallas_decode_attention", "ref_decode_attention"]
