"""Grouped-einsum oracle for the fused decode-attention kernel.

Same contract as ``kernel.fused_decode_attention`` — and the fix for
the naive decode path itself: scores contract directly over the
KV-head axis (``[B, KV, G, T, S]``), so the GQA-expanded ``[B, S, H,
D]`` K/V copies the legacy path materialized every step never exist.
This is a pure-memory win even with Pallas off, which is why it is the
default ``backend="ref"`` serving flavor on CPU hosts (the Pallas
kernel runs there in interpret mode as a parity harness only).
``_repeat_kv`` stays in ``models/attention.py`` for prefill/flash,
where the repeated layout is load-bearing for the blocked scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_validity(position: jnp.ndarray, S: int, window: int,
                    ring: bool) -> jnp.ndarray:
    """[B, S] slot validity from per-row absolute positions — the single
    definition the ref oracle and the legacy einsum path share.

    Linear cache: slot ``i`` holds position ``i``, valid iff
    ``i <= pos``. Ring cache of size S: slot ``i`` holds
    ``pos - ((pos - i) mod S)``, valid iff that is ``>= 0``. A sliding
    ``window`` additionally rejects positions ``<= pos - window``."""
    B = position.shape[0]
    slot = jnp.arange(S)
    if ring:
        p_slot = position[:, None] - ((position[:, None] - slot[None]) % S)
        valid = p_slot >= 0
    else:
        p_slot = jnp.broadcast_to(slot[None], (B, S))
        valid = p_slot <= position[:, None]
    if window > 0:
        valid &= p_slot > position[:, None] - window
    return valid


def ref_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, position: jnp.ndarray,
                         window: int = 0, ring: bool = False,
                         constrain_scores=None) -> jnp.ndarray:
    """q [B, T, H, D] (T=1 in decode), caches [B, S, KV, D], position [B]
    -> [B, T, H, D]. Grouped over the KV-head axis; no head repeat.

    ``constrain_scores`` (optional) is applied to the [B, KV, G, T, S]
    score tensor — the caller's sharding-hint hook (this package stays
    free of ``repro.models.ctx``, so the TP softmax-stays-distributed
    annotation is injected from ``models/attention.py``)."""
    B, S, KV, D = k_cache.shape
    T, H = q.shape[1], q.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k_cache
                   ).astype(jnp.float32) * D ** -0.5
    if constrain_scores is not None:
        s = constrain_scores(s)
    valid = decode_validity(position, S, window, ring)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(B, T, H, D)
