"""Pallas TPU kernels for PQ ADC scanning — the ChamVS near-memory engine.

Two formulations (DESIGN.md §3, hardware adaptation):

1. ``adc_scan``  — the *paper-faithful* unit: stream PQ codes HBM->VMEM in
   BlockSpec tiles, per sub-space LUT lookup realized as a vectorized
   compare-FMA over the ksub table entries (the TPU VPU has no per-lane
   byte-addressable BRAM, so the FPGA's table lookup becomes a broadcast
   compare+select — same streaming contract: each code tile is read once).
   Fused epilogue: per-block truncated top-k' queue (paper §4.2.2), carried
   in the output ref across grid steps along the scan axis.

2. ``shared_scan`` — the *beyond-paper* MXU formulation: with non-residual
   PQ, a whole query batch shares one scan of the probed-list union; the
   LUT lookup becomes a one-hot × LUT-stack matmul
   ``[tile_n, m*ksub] @ [m*ksub, q]`` that runs on the 128x128 systolic
   array at full occupancy once q >= 128. This trades 2*ksub*q flops/byte
   of MXU work for reading the codes slab exactly once for the whole batch.

Both are validated against ``ref.py`` in interpret mode (tests/test_kernels_pq_adc.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# shared in-kernel helper: merge candidates into a running sorted top-k buffer
# ---------------------------------------------------------------------------

def _extract_topk(d: jnp.ndarray, i: jnp.ndarray, k: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """k smallest of (d, i) by iterative min-extraction (ascending).

    This is the TPU replacement for the FPGA systolic priority queue: k rounds
    of (vector min, argmin, mask) over a VMEM-resident candidate vector —
    all-lane parallel, no inter-lane shuffles required."""
    def body(j, carry):
        d_, out_d, out_i = carry
        p = jnp.argmin(d_)
        out_d = jax.lax.dynamic_update_index_in_dim(out_d, d_[p], j, 0)
        out_i = jax.lax.dynamic_update_index_in_dim(out_i, i[p], j, 0)
        d_ = d_.at[p].set(jnp.inf)
        return d_, out_d, out_i

    out_d = jnp.full((k,), jnp.inf, d.dtype)
    out_i = jnp.full((k,), -1, i.dtype)
    _, out_d, out_i = jax.lax.fori_loop(0, k, body, (d, out_d, out_i))
    # +inf slots are "no candidate" — normalize their id so backends agree.
    return out_d, jnp.where(jnp.isinf(out_d), -1, out_i)


# ---------------------------------------------------------------------------
# Kernel 1: paper-faithful streaming ADC + fused truncated top-k' queue
# ---------------------------------------------------------------------------

def _adc_scan_kernel(len_ref, lut_ref, codes_ref, out_d_ref, out_i_ref,
                     *, tile_n: int, m: int, ksub: int, k: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_d_ref[...] = jnp.full_like(out_d_ref, jnp.inf)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    codes = codes_ref[0].astype(jnp.int32)                  # [tile_n, m]
    lut = lut_ref[0]                                        # [m, ksub]
    # LUT lookup as compare-FMA: for each sub-space j, one-hot(codes[:, j])
    # against the iota, weighted by the LUT column. fori over m keeps the
    # [tile_n, ksub] intermediate VMEM-resident and small.
    iota = jax.lax.broadcasted_iota(jnp.int32, (tile_n, ksub), 1)

    def body(j, acc):
        cj = jax.lax.dynamic_slice_in_dim(codes, j, 1, axis=1)   # [tile_n, 1]
        lj = jax.lax.dynamic_slice_in_dim(lut, j, 1, axis=0)[0]  # [ksub]
        eq = (iota == cj).astype(lut.dtype)                       # [tile_n, ksub]
        return acc + eq @ lj                                      # [tile_n]

    dist = jax.lax.fori_loop(0, m, body, jnp.zeros((tile_n,), lut.dtype))

    # padding mask: rows beyond the list's valid length get +inf
    n_valid = len_ref[0]
    row = t * tile_n + jax.lax.broadcasted_iota(jnp.int32, (tile_n, 1), 0)[:, 0]
    dist = jnp.where(row < n_valid, dist, jnp.inf)

    # merge tile candidates into the running truncated queue (out refs carry
    # the queue across grid steps because their index_map ignores t)
    cand_d = jnp.concatenate([out_d_ref[0], dist])
    cand_i = jnp.concatenate([out_i_ref[0], row])
    top_d, top_i = _extract_topk(cand_d, cand_i, k)
    out_d_ref[0] = top_d
    out_i_ref[0] = top_i


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret"))
def adc_scan(luts: jnp.ndarray, codes: jnp.ndarray, lens: jnp.ndarray,
             k: int, tile_n: int = 512, interpret: bool = True
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ADC scan + local top-k' per (query, probe) batch entry.

    luts:  [B, m, ksub] f32 — distance lookup tables
    codes: [B, n, m] uint8 — PQ codes of the probed list slice (padded)
    lens:  [B] int32 — valid prefix length per entry
    Returns (dists [B, k], idx [B, k]) ascending; idx is the row within n.
    """
    B, n, m = codes.shape
    ksub = luts.shape[-1]
    assert n % tile_n == 0, (n, tile_n)
    grid = (B, n // tile_n)
    kernel = functools.partial(
        _adc_scan_kernel, tile_n=tile_n, m=m, ksub=ksub, k=k)
    out_shape = (
        jax.ShapeDtypeStruct((B, k), luts.dtype),
        jax.ShapeDtypeStruct((B, k), jnp.int32),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, t: (b,)),                 # lens
            pl.BlockSpec((1, m, ksub), lambda b, t: (b, 0, 0)),    # luts
            pl.BlockSpec((1, tile_n, m), lambda b, t: (b, t, 0)),  # codes
        ],
        out_specs=(
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(lens, luts, codes)


# ---------------------------------------------------------------------------
# Kernel 2: beyond-paper MXU shared-scan (one-hot matmul, batched LUTs)
# ---------------------------------------------------------------------------

def _shared_scan_kernel(lut_ref, codes_ref, out_ref, *,
                        tile_n: int, m: int, ksub: int):
    codes = codes_ref[...].astype(jnp.int32)                  # [tile_n, m]
    # one-hot over the joint (sub-space, centroid) axis -> [tile_n, m*ksub]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tile_n, m, ksub), 2)
    onehot = (iota == codes[:, :, None]).astype(lut_ref.dtype)
    onehot = onehot.reshape(tile_n, m * ksub)
    # MXU contraction against the stacked LUTs of the whole query batch.
    out_ref[...] = jax.lax.dot_general(
        onehot, lut_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # [tile_n, q]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def shared_scan(luts: jnp.ndarray, codes: jnp.ndarray,
                tile_n: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Distances of a whole query batch against one shared codes slab.

    luts:  [q, m, ksub] f32 (non-residual PQ: one LUT per query)
    codes: [n, m] uint8
    Returns dists [n, q] f32.
    """
    q, m, ksub = luts.shape
    n = codes.shape[0]
    assert n % tile_n == 0, (n, tile_n)
    lut_flat = luts.reshape(q, m * ksub).T                    # [m*ksub, q]
    kernel = functools.partial(
        _shared_scan_kernel, tile_n=tile_n, m=m, ksub=ksub)
    return pl.pallas_call(
        kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((m * ksub, q), lambda t: (0, 0)),
            pl.BlockSpec((tile_n, m), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, q), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n, q), jnp.float32),
        interpret=interpret,
    )(lut_flat, codes)
