"""Pure-jnp oracle for the PQ ADC scan kernel.

Semantics (paper §4.1, PQ decoding unit): given a distance lookup table
``lut[m, ksub]`` and quantized database vectors ``codes[n, m]`` (each byte an
address into the corresponding LUT column), produce
``dist[n] = sum_j lut[j, codes[n, j]]``.

The oracle also covers the fused local-top-k epilogue used by the kernel
(per-block truncated queues, paper §4.2.2): ``ref_adc_topk`` returns the
k smallest distances + their row indices, exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_adc(lut: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """lut: [m, ksub] f32; codes: [n, m] integer -> [n] f32 distances."""
    n, m = codes.shape
    gathered = jnp.take_along_axis(
        lut.T[None, :, :],                     # [1, ksub, m]
        codes[:, None, :].astype(jnp.int32),   # [n, 1, m]
        axis=1,
    )                                          # [n, 1, m]
    return jnp.sum(gathered[:, 0, :], axis=-1)


def ref_adc_batch(luts: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """luts: [b, m, ksub]; codes: [b, n, m] -> [b, n]."""
    return jax.vmap(ref_adc)(luts, codes)


def ref_adc_topk(lut: jnp.ndarray, codes: jnp.ndarray, valid: jnp.ndarray,
                 k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused scan + exact top-k oracle.

    valid: [n] bool (padding mask). Returns (dists [k], idx [k]) ascending."""
    d = jnp.where(valid, ref_adc(lut, codes), jnp.inf)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


def ref_shared_scan(luts: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the beyond-paper MXU shared-scan formulation.

    luts: [q, m, ksub] (one LUT per query, non-residual PQ);
    codes: [n, m] (a single scanned slab shared by all queries)
    -> dists [q, n]."""
    return jax.vmap(lambda lut: ref_adc(lut, codes))(luts)
