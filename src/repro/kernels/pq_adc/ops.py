"""Public jit'd wrappers for the PQ ADC kernels.

``pq_adc_topk`` is what ChamVS calls per memory-node shard; it handles
padding to tile multiples and exposes a ``backend`` switch:
  * "pallas"   — the Pallas kernel (interpret mode on CPU, compiled on TPU)
  * "ref"      — the pure-jnp oracle (also the paper's CPU-baseline flavor)
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.pq_adc import kernel as _k
from repro.kernels.pq_adc import ref as _ref


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "backend", "interpret"))
def pq_adc_topk(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    lens: jnp.ndarray,
    k: int,
    tile_n: int = 512,
    backend: str = "pallas",
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ADC + local top-k over a batch of probed lists.

    luts [B, m, ksub] f32 | codes [B, n, m] uint8 | lens [B] int32
    -> (dists [B, k], row_idx [B, k]) ascending.
    """
    B, n, m = codes.shape
    tile_n = min(tile_n, max(128, n))
    codes = _pad_to(codes, 1, tile_n)
    if backend == "pallas":
        return _k.adc_scan(luts, codes, lens, k, tile_n=tile_n,
                           interpret=interpret)
    if backend == "ref":
        npad = codes.shape[1]
        valid = jnp.arange(npad)[None, :] < lens[:, None]
        d = jax.vmap(_ref.ref_adc)(luts, codes)
        d = jnp.where(valid, d, jnp.inf)
        neg, idx = jax.lax.top_k(-d, k)
        idx = jnp.where(jnp.isinf(-neg), -1, idx)
        return -neg, idx.astype(jnp.int32)
    raise ValueError(f"unknown backend {backend!r}")


@functools.partial(jax.jit, static_argnames=("tile_n", "backend", "interpret"))
def pq_shared_scan(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    tile_n: int = 512,
    backend: str = "pallas",
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched-LUT shared scan: luts [q, m, ksub], codes [n, m] -> [n, q]."""
    n = codes.shape[0]
    tile_n = min(tile_n, max(128, n))
    codes_p = _pad_to(codes, 0, tile_n)
    if backend == "pallas":
        out = _k.shared_scan(luts, codes_p, tile_n=tile_n, interpret=interpret)
    elif backend == "ref":
        out = _ref.ref_shared_scan(luts, codes_p).T
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return out[:n]
