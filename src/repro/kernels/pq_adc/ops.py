"""Public wrappers for the PQ ADC kernels, routed through the kernel
registry (``repro.kernels.registry``).

``pq_adc_topk`` is the staged per-shard unit ChamVS calls per memory
node (the fused multi-shard path lives in ``kernels/chamvs_scan``); it
handles padding to tile multiples and takes a ``KernelSpec``:
  * backend "pallas" — the Pallas kernel (interpret mode on CPU,
    compiled on TPU);
  * backend "ref"    — the pure-jnp oracle (also the paper's
    CPU-baseline flavor).
``backend=``/``interpret=`` kwargs remain as deprecated aliases.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.pq_adc import kernel as _k
from repro.kernels.pq_adc import ref as _ref


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("k",))
def _jit_ref_topk(luts, codes, lens, k: int):
    npad = codes.shape[1]
    valid = jnp.arange(npad)[None, :] < lens[:, None]
    d = jax.vmap(_ref.ref_adc)(luts, codes)
    d = jnp.where(valid, d, jnp.inf)
    neg, idx = jax.lax.top_k(-d, k)
    idx = jnp.where(jnp.isinf(-neg), -1, idx)
    return -neg, idx.astype(jnp.int32)


def pq_adc_topk(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    lens: jnp.ndarray,
    k: int,
    tile_n: Optional[int] = None,
    spec: Optional[registry.KernelSpec] = None,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ADC + local top-k over a batch of probed lists.

    luts [B, m, ksub] f32 | codes [B, n, m] uint8 | lens [B] int32
    -> (dists [B, k], row_idx [B, k]) ascending.
    """
    spec = registry.resolve("pq_adc_topk", spec, backend, interpret)
    if tile_n is not None and spec.tile_n != tile_n:
        spec = dataclasses.replace(spec, tile_n=tile_n)
    n = codes.shape[1]
    tile = spec.pick_tile_n(n)
    codes = _pad_to(codes, 1, tile)
    if spec.backend == "pallas":
        return _k.adc_scan(luts, codes, lens, k, tile_n=tile,
                           interpret=spec.interpret)
    return _jit_ref_topk(luts, codes, lens, k=k)


@jax.jit
def _jit_ref_shared(luts, codes):
    return _ref.ref_shared_scan(luts, codes).T


def pq_shared_scan(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    tile_n: Optional[int] = None,
    spec: Optional[registry.KernelSpec] = None,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Batched-LUT shared scan: luts [q, m, ksub], codes [n, m] -> [n, q]."""
    spec = registry.resolve("pq_shared_scan", spec, backend, interpret)
    if tile_n is not None and spec.tile_n != tile_n:
        spec = dataclasses.replace(spec, tile_n=tile_n)
    n = codes.shape[0]
    tile = spec.pick_tile_n(n)
    codes_p = _pad_to(codes, 0, tile)
    if spec.backend == "pallas":
        out = _k.shared_scan(luts, codes_p, tile_n=tile,
                             interpret=spec.interpret)
    else:
        out = _jit_ref_shared(luts, codes_p)
    return out[:n]
