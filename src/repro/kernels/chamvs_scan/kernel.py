"""Pallas kernel: the fused streaming ChamVS scan (paper §4 dataflow).

The paper's near-memory accelerator is a *pipeline*, not a sequence of
kernels: the systolic PQ decoder streams ADC distances straight into the
K-selection priority-queue network, and the full distance array never
exists anywhere (§4.2). The staged reproduction ran three dispatches
per shard (ADC scan -> materialized [B, n] distances -> top-k) with a
Python loop over shards on top. This kernel is the dataflow-faithful
version:

  * grid ``(S, nq // tile_q, nprobe)`` — the leading **shard axis**
    makes the scan over every memory node's slice ONE dispatch per
    retrieval wave;
  * per grid step, the probed list's code tile streams HBM->VMEM, the
    per-(query, probe) LUT turns codes into ADC partial distances
    (compare-FMA — the TPU VPU has no per-lane byte-addressable BRAM,
    see pq_adc/kernel.py), and the ``[tile_q, cap]`` distance tile is
    folded immediately into a per-query **running top-k'** carried in
    the output refs across the probe grid axis (their index_map ignores
    the probe index, so the queue is scratch-resident between steps —
    streaming K-selection, paper §4.2.2);
  * global vector ids ride along with the distances, so the candidate
    the queue keeps is already ``(dist, global_id)`` — no separate
    local-row -> id remap dispatch afterwards.

Validated against the staged pipeline and ``ref.py`` in
``tests/test_chamvs_scan.py`` (hypothesis property test).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import extract_topk_rows


def _chamvs_scan_kernel(lens_ref, lut_ref, codes_ref, gid_ref,
                        out_d_ref, out_i_ref, *,
                        tile_q: int, cap: int, m: int, ksub: int, kk: int):
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        out_d_ref[...] = jnp.full_like(out_d_ref, jnp.inf)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    codes = codes_ref[0, :, 0].astype(jnp.int32)          # [tile_q, cap, m]
    lut = lut_ref[:, 0]                                   # [tile_q, m, ksub]
    # ADC as compare-FMA, one query row at a time: per (query, sub-space),
    # one-hot the code bytes against the iota and contract the ksub axis
    # with that query's LUT row (pq_adc's trick — the TPU VPU has no
    # per-lane byte-addressable BRAM). Looping queries inside the step
    # keeps the [cap, ksub] intermediate at the same cache-resident size
    # as the staged kernel's, while the step count stays tile_q x smaller.
    iota = jax.lax.broadcasted_iota(jnp.int32, (cap, ksub), 1)

    def q_body(qi, dist_acc):
        cq = jax.lax.dynamic_index_in_dim(codes, qi, 0, False)  # [cap, m]
        lq = jax.lax.dynamic_index_in_dim(lut, qi, 0, False)    # [m, ksub]

        def m_body(j, acc):
            cj = jax.lax.dynamic_slice_in_dim(cq, j, 1, axis=1)      # [cap,1]
            lj = jax.lax.dynamic_slice_in_dim(lq, j, 1, axis=0)[0]   # [ksub]
            eq = (iota == cj).astype(lut.dtype)                  # [cap,ksub]
            return acc + eq @ lj                                 # [cap]

        d = jax.lax.fori_loop(0, m, m_body, jnp.zeros((cap,), lut.dtype))
        return jax.lax.dynamic_update_index_in_dim(
            dist_acc, d[None], qi, 0)

    dist = jax.lax.fori_loop(0, tile_q, q_body,
                             jnp.zeros((tile_q, cap), lut.dtype))

    # rows beyond the probed list's valid length get +inf (their gid is
    # already the -1 sentinel in the padded id table)
    n_valid = lens_ref[0, :, 0]                           # [tile_q]
    col = jax.lax.broadcasted_iota(jnp.int32, (tile_q, cap), 1)
    dist = jnp.where(col < n_valid[:, None], dist, jnp.inf)

    # fold the tile into the running queue carried across the probe axis
    cand_d = jnp.concatenate([out_d_ref[0], dist], axis=1)
    cand_i = jnp.concatenate([out_i_ref[0], gid_ref[0, :, 0]], axis=1)
    top_d, top_i = extract_topk_rows(cand_d, cand_i, kk)
    out_d_ref[0] = top_d
    out_i_ref[0] = top_i


@functools.partial(jax.jit, static_argnames=("kk", "tile_q", "interpret"))
def fused_scan(luts: jnp.ndarray, codes: jnp.ndarray, gids: jnp.ndarray,
               lens: jnp.ndarray, kk: int, tile_q: int = 8,
               interpret: bool = True
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One dispatch: ADC + streaming top-kk over every shard's probed lists.

    luts:  [nq, nprobe, m, ksub] f32 — per-(query, probed list) LUTs
           (shared by all shards; residual PQ makes them probe-dependent)
    codes: [S, nq, nprobe, cap, m] uint8 — gathered probed-list codes
    gids:  [S, nq, nprobe, cap] int32 — global vector ids (-1 = pad)
    lens:  [S, nq, nprobe] int32 — valid prefix length per probed list
    Returns (dists [S, nq, kk], ids [S, nq, kk]) ascending; ids are
    global vector ids, -1 where fewer than kk candidates exist.
    """
    S, nq, nprobe, cap, m = codes.shape
    ksub = luts.shape[-1]
    assert nq % tile_q == 0, (nq, tile_q)
    grid = (S, nq // tile_q, nprobe)
    kernel = functools.partial(_chamvs_scan_kernel, tile_q=tile_q, cap=cap,
                               m=m, ksub=ksub, kk=kk)
    out_shape = (
        jax.ShapeDtypeStruct((S, nq, kk), luts.dtype),
        jax.ShapeDtypeStruct((S, nq, kk), jnp.int32),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, 1), lambda s, q, p: (s, q, p)),
            pl.BlockSpec((tile_q, 1, m, ksub), lambda s, q, p: (q, p, 0, 0)),
            pl.BlockSpec((1, tile_q, 1, cap, m),
                         lambda s, q, p: (s, q, p, 0, 0)),
            pl.BlockSpec((1, tile_q, 1, cap), lambda s, q, p: (s, q, p, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, tile_q, kk), lambda s, q, p: (s, q, 0)),
            pl.BlockSpec((1, tile_q, kk), lambda s, q, p: (s, q, 0)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(lens, luts, codes, gids)
