"""Pure-jnp oracle for the fused ChamVS scan.

Same contract as ``kernel.fused_scan`` — one call covers every shard —
formulated as a ``vmap`` over the shard axis of (gather-ADC -> padding
mask -> one exact top-kk). This is also what ``backend="ref"`` serves:
it is *fused* in the one-dispatch sense (no Python loop over shards, no
per-shard dispatches — the whole stack lowers to one XLA executable),
just not streaming. The vmap-over-shards form measurably beats both the
broadcast form (``adc_scan_ref(luts[None], codes)``) and the unrolled
per-shard loop on CPU — XLA fuses the per-shard mask/select chain
better when the shard axis is a real batch axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.ivfpq import adc_scan_ref


def ref_chamvs_scan(luts: jnp.ndarray, codes: jnp.ndarray,
                    gids: jnp.ndarray, lens: jnp.ndarray, kk: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """luts [nq,np,m,ksub], codes [S,nq,np,cap,m], gids [S,nq,np,cap],
    lens [S,nq,np] -> (dists [S,nq,kk], ids [S,nq,kk]) ascending."""
    S, nq, nprobe, cap, _ = codes.shape
    keep = min(kk, nprobe * cap)

    def per_shard(c, g, l):
        d = adc_scan_ref(luts, c)                         # [nq, np, cap]
        valid = jnp.arange(cap)[None, None, :] < l[..., None]
        d = jnp.where(valid, d, jnp.inf)
        flat_d = d.reshape(nq, nprobe * cap)
        flat_i = g.reshape(nq, nprobe * cap)
        neg, pos = jax.lax.top_k(-flat_d, keep)
        out_d = -neg
        out_i = jnp.take_along_axis(flat_i, pos, axis=-1)
        return out_d, jnp.where(jnp.isinf(out_d), -1, out_i)

    out_d, out_i = jax.vmap(per_shard)(codes, gids, lens)
    if keep < kk:   # fewer candidates than kk: pad like the kernel queue
        pad = ((0, 0), (0, 0), (0, kk - keep))
        out_d = jnp.pad(out_d, pad, constant_values=jnp.inf)
        out_i = jnp.pad(out_i, pad, constant_values=-1)
    return out_d, out_i
