"""Fused streaming ChamVS scan: ADC + running top-k' over all shards in
ONE kernel dispatch (paper §4's pipelined dataflow on TPU)."""
from repro.kernels.chamvs_scan.ops import chamvs_scan  # noqa: F401
