"""Public frontend for the fused ChamVS scan, routed through the
kernel registry (``repro.kernels.registry.KernelSpec``).

Unlike the older per-kernel frontends there are no legacy
``backend=``/``interpret=`` kwargs here — this frontend was born after
the registry, so the spec is the only selector.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.chamvs_scan import kernel as _k
from repro.kernels.chamvs_scan import ref as _ref

_jit_ref = jax.jit(_ref.ref_chamvs_scan, static_argnames=("kk",))


def chamvs_scan(luts: jnp.ndarray, codes: jnp.ndarray, gids: jnp.ndarray,
                lens: jnp.ndarray, kk: int,
                spec: Optional[registry.KernelSpec] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused multi-shard ADC + streaming top-kk — ONE dispatch for the
    whole retrieval wave.

    luts [nq, np, m, ksub] | codes [S, nq, np, cap, m] uint8 |
    gids [S, nq, np, cap] int32 | lens [S, nq, np] int32
    -> (dists [S, nq, kk], global ids [S, nq, kk]) ascending.
    """
    spec = registry.resolve("chamvs_scan", spec)
    nq = codes.shape[1]
    if spec.backend == "pallas":
        return _k.fused_scan(luts, codes, gids, lens, kk,
                             tile_q=spec.pick_tile_q(nq),
                             interpret=spec.interpret)
    return _jit_ref(luts, codes, gids, lens, kk=kk)


@functools.partial(jax.jit, static_argnames=("cfg", "kk", "spec"))
def fused_shard_scan(params, stacked, queries: jnp.ndarray,
                     probe_ids: jnp.ndarray, cfg, kk: int,
                     spec: Optional[registry.KernelSpec] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """LUTs + gather + fused scan over a ``stack_shards``-packed shard
    stack. The candidate-preparation twin of ``chamvs.shard_search``,
    but for ALL shards at once: compute the per-(query, probe) LUTs,
    gather every shard's probed slices, and run ONE ``chamvs_scan``
    dispatch over the stack.

    params: IVFPQParams | stacked: IVFPQShard with leading [S] axis |
    queries [nq, D] | probe_ids [nq, np]
    -> (dists [S, nq, kk], global ids [S, nq, kk]).
    """
    from repro.core import ivfpq
    luts = ivfpq.compute_luts(params, queries, probe_ids, cfg.ivfpq)
    codes = stacked.codes[:, probe_ids]         # [S, nq, np, cap, m]
    gids = stacked.ids[:, probe_ids]            # [S, nq, np, cap]
    lens = stacked.list_len[:, probe_ids]       # [S, nq, np]
    return chamvs_scan(luts, codes, gids, lens, kk,
                       spec=spec if spec is not None else cfg.kernel_spec())
