"""Pallas kernel: approximate hierarchical top-k (paper §4.2.2 on TPU).

Level-1: each grid block scans one tile of the distance row and keeps a
truncated top-k' queue (k' from the binomial bound in
``core/approx_topk_math.py``). Level-2: exact merge of the ``num_blocks * k'``
survivors. Level-1 is the bandwidth-critical stage — it reads the full
distance row; level-2 touches only KBs and runs as a tiny epilogue.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import extract_topk_rows


def _l1_kernel(d_ref, out_d_ref, out_i_ref, *, tile: int, k_prime: int,
               rows: int):
    t = pl.program_id(1)
    d = d_ref[...]                                           # [rows, tile]
    col = t * tile + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    top_d, top_i = extract_topk_rows(d, col, k_prime)
    out_d_ref[...] = top_d[:, None, :]
    out_i_ref[...] = top_i[:, None, :]


@functools.partial(jax.jit,
                   static_argnames=("k", "k_prime", "num_blocks", "row_tile",
                                    "interpret"))
def hierarchical_topk(
    d: jnp.ndarray,
    k: int,
    k_prime: int,
    num_blocks: int,
    row_tile: int = 8,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """d: [B, n] f32 (+inf = invalid) -> (dists [B, k], idx [B, k]) ascending.

    Approximate: identical to exact top-k unless one level-1 block holds more
    than k' of the true top-k (probability bounded by
    ``approx_topk_math.queue_overflow_prob(k, num_blocks, k_prime)``)."""
    B, n = d.shape
    assert n % num_blocks == 0, (n, num_blocks)
    tile = n // num_blocks
    assert B % row_tile == 0, (B, row_tile)

    l1_d, l1_i = pl.pallas_call(
        functools.partial(_l1_kernel, tile=tile, k_prime=k_prime,
                          rows=row_tile),
        grid=(B // row_tile, num_blocks),
        in_specs=[pl.BlockSpec((row_tile, tile), lambda b, t: (b, t))],
        out_specs=(
            pl.BlockSpec((row_tile, 1, k_prime), lambda b, t: (b, t, 0)),
            pl.BlockSpec((row_tile, 1, k_prime), lambda b, t: (b, t, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, num_blocks, k_prime), d.dtype),
            jax.ShapeDtypeStruct((B, num_blocks, k_prime), jnp.int32),
        ),
        interpret=interpret,
    )(d)

    # Level-2 queue: exact merge over the truncated survivors (tiny).
    flat_d = l1_d.reshape(B, num_blocks * k_prime)
    flat_i = l1_i.reshape(B, num_blocks * k_prime)
    neg, pos = jax.lax.top_k(-flat_d, k)
    out_i = jnp.take_along_axis(flat_i, pos, axis=1)
    out_d = -neg
    return out_d, jnp.where(jnp.isinf(out_d), -1, out_i)
