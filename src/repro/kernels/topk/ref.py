"""Pure-jnp oracles for the approximate hierarchical top-k kernel."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ref_exact_topk(d: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact k smallest per row: d [B, n] -> (dists [B,k], idx [B,k]) ascending."""
    neg, idx = jax.lax.top_k(-d, k)
    idx = jnp.where(jnp.isinf(-neg), -1, idx)
    return -neg, idx


def ref_hierarchical_topk(d: jnp.ndarray, k: int, num_blocks: int,
                          k_prime: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle of the *approximate* semantics: per-block truncated top-k' queues
    followed by an exact level-2 merge (paper §4.2.2). Returns what the kernel
    should return, including the cases where truncation drops true members."""
    B, n = d.shape
    assert n % num_blocks == 0
    blk = n // num_blocks
    db = d.reshape(B, num_blocks, blk)
    neg, pos = jax.lax.top_k(-db, k_prime)                 # [B, nb, k']
    base = (jnp.arange(num_blocks) * blk)[None, :, None]
    idx = pos + base
    l1_d = (-neg).reshape(B, num_blocks * k_prime)
    l1_i = idx.reshape(B, num_blocks * k_prime)
    neg2, pos2 = jax.lax.top_k(-l1_d, k)
    out_i = jnp.take_along_axis(l1_i, pos2, axis=1)
    out_d = -neg2
    return out_d, jnp.where(jnp.isinf(out_d), -1, out_i)
