"""Public wrapper for approximate hierarchical top-k selection, routed
through the kernel registry (``repro.kernels.registry``).

Degenerate tiles (``n % num_blocks != 0`` or blocks shorter than the
truncated queue) cannot be served by the hierarchical kernel and route
to the *exact* reference path. That fallback used to be silent — a
benchmark sweeping such shapes reported ref numbers as "pallas" — so it
now goes through ``registry.record_fallback`` like every other
pallas->ref route (counted, warned once, or raised under
``fallback="error"``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.approx_topk_math import truncated_queue_len
from repro.kernels import registry
from repro.kernels.topk import kernel as _k
from repro.kernels.topk import ref as _ref

_jit_exact = jax.jit(_ref.ref_exact_topk, static_argnames=("k",))
_jit_ref_hier = jax.jit(_ref.ref_hierarchical_topk,
                        static_argnames=("k", "num_blocks", "k_prime"))


def approx_topk(
    d: jnp.ndarray,
    k: int,
    num_blocks: int = 16,
    k_prime: Optional[int] = None,
    eps: float = 0.01,
    spec: Optional[registry.KernelSpec] = None,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """k smallest per row with truncated level-1 queues (paper §4.2.2).

    If ``k_prime`` is None it is sized by the paper's binomial bound so
    that at most ``eps`` of queries differ from exact top-k.
    ``num_blocks`` is the number of level-1 producers (grid blocks).
    ``backend="exact"`` (legacy alias) selects the exact reference path
    directly. ``backend=``/``interpret=`` are deprecated aliases for
    ``spec=KernelSpec(...)``."""
    exact = backend == "exact"
    if exact:
        backend = "ref"
    spec = registry.resolve("approx_topk", spec, backend, interpret)
    B, n = d.shape
    if k_prime is None:
        k_prime = truncated_queue_len(k, num_blocks, eps)
    k_prime = min(max(k_prime, 1), k)
    # degenerate tiles: every level-1 block must hold >= k' candidates
    if n % num_blocks != 0 or n // num_blocks < k_prime:
        if spec.backend == "pallas":
            registry.record_fallback(
                "approx_topk",
                f"degenerate tiling n={n}, num_blocks={num_blocks}, "
                f"k'={k_prime} (need n % num_blocks == 0 and "
                "n // num_blocks >= k')", spec)
        return _jit_exact(d, k=k)
    if exact:
        return _jit_exact(d, k=k)
    if spec.backend == "pallas":
        return _k.hierarchical_topk(d, k, k_prime, num_blocks,
                                    row_tile=spec.pick_tile_q(B),
                                    interpret=spec.interpret)
    return _jit_ref_hier(d, k=k, num_blocks=num_blocks, k_prime=k_prime)
