"""Public wrapper for approximate hierarchical top-k selection."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.approx_topk_math import truncated_queue_len
from repro.kernels.topk import kernel as _k
from repro.kernels.topk import ref as _ref


@functools.partial(jax.jit, static_argnames=(
    "k", "num_blocks", "k_prime", "eps", "backend", "interpret"))
def approx_topk(
    d: jnp.ndarray,
    k: int,
    num_blocks: int = 16,
    k_prime: Optional[int] = None,
    eps: float = 0.01,
    backend: str = "pallas",
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """k smallest per row with truncated level-1 queues (paper §4.2.2).

    If ``k_prime`` is None it is sized by the paper's binomial bound so that
    at most ``eps`` of queries differ from exact top-k. ``num_blocks`` is the
    number of level-1 producers (grid blocks)."""
    B, n = d.shape
    if k_prime is None:
        k_prime = truncated_queue_len(k, num_blocks, eps)
    k_prime = min(max(k_prime, 1), k)
    # degenerate tiles: every block must hold at least k' candidates
    if n % num_blocks != 0 or n // num_blocks < k_prime:
        return _ref.ref_exact_topk(d, k)
    if backend == "pallas":
        row_tile = 8 if B % 8 == 0 else (4 if B % 4 == 0 else 1)
        return _k.hierarchical_topk(d, k, k_prime, num_blocks,
                                    row_tile=row_tile, interpret=interpret)
    if backend == "ref":
        return _ref.ref_hierarchical_topk(d, k, num_blocks, k_prime)
    if backend == "exact":
        return _ref.ref_exact_topk(d, k)
    raise ValueError(f"unknown backend {backend!r}")
