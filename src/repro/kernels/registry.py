"""Unified kernel-spec layer for the ChamVS Pallas kernels.

Before this module existed, each kernel package (``ivf_scan``,
``pq_adc``, ``topk``) carried its own copy-pasted frontend with a
``backend=``/``interpret=`` kwarg pair, its own tile heuristics, and —
worst — its own fallback behavior: ``ivf_scan`` kept a module-global
"warned once" flag that leaked across tests, while ``approx_topk``
silently returned the exact reference path on degenerate tiles, so
"pallas" benchmark numbers could quietly be ref numbers.

``KernelSpec`` is now the single description of *how* a kernel should
run, and this module owns the shared policy around it:

  * **tile heuristics** — the `pick_*` methods reproduce (and replace)
    the per-frontend divisor searches, overridable per spec;
  * **fallback accounting** — every time a frontend routes a "pallas"
    request to a reference path it calls :func:`record_fallback`, which
    bumps a per-op counter and (policy permitting) warns once per op.
    Benchmarks read :func:`fallback_count` so ref numbers can never
    masquerade as Pallas numbers;
  * **test-resettable one-time state** — :func:`reset_warnings` clears
    the warned-set and the counters; the test suite installs it as an
    autouse fixture so "warn once per process" becomes "once per test"
    instead of leaking between tests.

NOTE on jit: frontends make their routing decision from *static* shapes
and the (hashable, static) spec. When a frontend is called inside an
outer ``jax.jit`` (e.g. the retrieval service's scan stage), the
decision — and therefore the fallback warning/counter — runs at trace
time, once per traced shape. Counters therefore count *routing
decisions*, not dispatches; the retrieval service's ``scan_dispatches``
counter is the per-dispatch ground truth.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional, Tuple

_BACKENDS = ("pallas", "ref", "einsum")
_FALLBACK_POLICIES = ("warn", "silent", "error")


class KernelFallbackError(RuntimeError):
    """Raised when ``fallback="error"`` and a Pallas request cannot be
    served by the Pallas kernel (deployment configs that must never
    silently serve reference-path numbers)."""


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """How a ChamVS kernel call should execute.

    Hashable and frozen, so it can ride through ``jax.jit`` as a static
    argument (``ChamVSConfig`` embeds one per search config, the serve
    ``RalmEngine`` one per deployment for decode attention).

    ``backend="einsum"`` exists for ``decode_attn`` only: the legacy
    full-materialization einsum path kept as the parity oracle. The
    ChamVS frontends treat any non-"pallas" backend as "ref"."""

    backend: str = "pallas"        # "pallas" | "ref" | "einsum"
    interpret: bool = True         # Pallas interpret mode (CPU containers)
    tile_q: Optional[int] = None   # query-tile rows (None = heuristic)
    tile_n: Optional[int] = None   # scan-axis tile (None = heuristic)
    tile_c: Optional[int] = None   # centroid-tile cols (None = heuristic)
    fallback: str = "warn"         # "warn" | "silent" | "error"

    def __post_init__(self) -> None:
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {_BACKENDS}")
        if self.fallback not in _FALLBACK_POLICIES:
            raise ValueError(f"unknown fallback policy {self.fallback!r}; "
                             f"expected one of {_FALLBACK_POLICIES}")

    # -- tile heuristics (the old per-frontend divisor searches) ------------

    @staticmethod
    def _divisor_at_most(n: int, want: int) -> int:
        """Largest divisor of ``n`` that is <= ``want`` (>= 1). The grid
        kernels require tiles to divide their axis exactly, so explicit
        overrides are rounded down to a legal tile instead of tripping
        the kernels' shape asserts."""
        t = max(1, min(want, n))
        while n % t:
            t -= 1
        return t

    def pick_tile_q(self, nq: int) -> int:
        """Query-tile rows: largest of 8/4/1 dividing the batch."""
        if self.tile_q is not None:
            return self._divisor_at_most(nq, self.tile_q)
        return 8 if nq % 8 == 0 else (4 if nq % 4 == 0 else 1)

    def pick_tile_c(self, nlist: int) -> int:
        """Centroid-tile columns for the IVF scan grid."""
        if self.tile_c is not None:
            return self._divisor_at_most(nlist, self.tile_c)
        return 512 if nlist % 512 == 0 else (128 if nlist % 128 == 0
                                             else nlist)

    def pick_tile_n(self, n: int) -> int:
        """Scan-axis tile for the streaming ADC kernels."""
        tile = self.tile_n if self.tile_n is not None else 512
        return min(tile, max(128, n))

    def pick_block_seq(self, s: int) -> int:
        """KV-block length for the streaming decode-attention kernel:
        the largest divisor of the cache seq axis <= ``tile_n`` (default
        128 — one pool seq-alignment quantum). The grid streams one such
        block per step, so this is also the skip granularity."""
        want = self.tile_n if self.tile_n is not None else 128
        return self._divisor_at_most(s, want)

    def with_overrides(self, backend: Optional[str] = None,
                       interpret: Optional[bool] = None) -> "KernelSpec":
        """Copy with backend/interpret overridden (``None`` keeps)."""
        if backend is None and interpret is None:
            return self
        return dataclasses.replace(
            self,
            backend=backend if backend is not None else self.backend,
            interpret=interpret if interpret is not None else self.interpret)


#: the two specs almost every call site wants
REF = KernelSpec(backend="ref")
PALLAS_INTERPRET = KernelSpec(backend="pallas", interpret=True)


# ---------------------------------------------------------------------------
# one-time warnings + fallback counters (module-level, test-resettable)
# ---------------------------------------------------------------------------

_warned: set = set()
_fallbacks: Dict[str, int] = {}


def reset_warnings() -> None:
    """Clear the warned-once set and the fallback counters. The test
    suite calls this between tests (autouse fixture in conftest), so no
    module-global flag can leak warning state across tests again."""
    _warned.clear()
    _fallbacks.clear()


def fallback_count(op: Optional[str] = None) -> int:
    """Pallas->ref routing decisions recorded since the last reset —
    for one op, or in total. Benchmarks assert this is 0 before tagging
    a number 'pallas'."""
    if op is not None:
        return _fallbacks.get(op, 0)
    return sum(_fallbacks.values())


def fallback_counts() -> Dict[str, int]:
    """Per-op copy of the fallback counters — the gateway's ``/statsz``
    and the ``/metricsz`` adapter export this so degraded kernel routing
    is visible in production, not just under pytest."""
    return dict(_fallbacks)


def warn_once(key: Tuple, message: str, category=RuntimeWarning,
              stacklevel: int = 3) -> None:
    """Emit ``message`` once per ``key`` per process (or per
    ``reset_warnings`` interval)."""
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(message, category, stacklevel=stacklevel)


def record_fallback(op: str, reason: str,
                    spec: Optional[KernelSpec] = None) -> None:
    """A frontend routed a ``backend="pallas"`` request to a reference
    path. Count it, and warn/raise per the spec's fallback policy."""
    policy = spec.fallback if spec is not None else "warn"
    if policy == "error":
        raise KernelFallbackError(f"{op}: {reason}")
    _fallbacks[op] = _fallbacks.get(op, 0) + 1
    if policy == "warn":
        warn_once(
            (op, "fallback"),
            f"{op}: backend='pallas' requested but {reason}; falling back "
            "to the reference path (benchmark numbers for this shape are "
            "NOT Pallas numbers). Warned once per op per process; see "
            "repro.kernels.registry.fallback_count().",
            RuntimeWarning, stacklevel=4)


def resolve(op: str, spec: Optional[KernelSpec],
            backend: Optional[str] = None,
            interpret: Optional[bool] = None,
            default: KernelSpec = PALLAS_INTERPRET) -> KernelSpec:
    """Fold a frontend's arguments into one ``KernelSpec``.

    ``spec`` wins when given; the legacy ``backend=``/``interpret=``
    kwargs are deprecated aliases that override on top of it (warning
    once per op). A bare string in the ``spec`` slot is a legacy
    *positional* backend (the old signatures had ``backend`` where
    ``spec`` now sits) — honored with the same deprecation warning
    rather than crashing on ``'str'.backend`` downstream."""
    if isinstance(spec, str):
        backend = spec if backend is None else backend
        spec = None
    out = spec if spec is not None else default
    if backend is None and interpret is None:
        return out
    warn_once(
        (op, "deprecated-kwargs"),
        f"{op}: the backend=/interpret= kwargs are deprecated; pass "
        "spec=repro.kernels.registry.KernelSpec(...) instead (see "
        "docs/kernels.md for the migration table).",
        DeprecationWarning, stacklevel=4)
    return out.with_overrides(backend, interpret)
