"""Shared in-kernel utilities for the ChamVS Pallas kernels."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def extract_topk_rows(d: jnp.ndarray, i: jnp.ndarray, k: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-batched k-smallest by iterative min-extraction (ascending).

    d, i: [rows, cand] -> ([rows, k], [rows, k]).

    TPU replacement for the FPGA systolic priority queue (DESIGN.md §3): k
    rounds of (row-min, row-argmin, mask) — each round is an all-lane VPU
    reduction, no inter-lane register shuffles. k is static and small (the
    truncated queue length k' from the paper's binomial bound), so the loop
    body is cheap relative to the producing scan."""
    rows, cand = d.shape

    def body(j, carry):
        d_, out_d, out_i = carry
        m = jnp.min(d_, axis=1)                                  # [rows]
        p = jnp.argmin(d_, axis=1)                               # [rows]
        val_i = jnp.take_along_axis(i, p[:, None], axis=1)[:, 0]
        out_d = jax.lax.dynamic_update_slice_in_dim(out_d, m[:, None], j, 1)
        out_i = jax.lax.dynamic_update_slice_in_dim(out_i, val_i[:, None], j, 1)
        col = jax.lax.broadcasted_iota(jnp.int32, d_.shape, 1)
        d_ = jnp.where(col == p[:, None], jnp.inf, d_)
        return d_, out_d, out_i

    out_d = jnp.full((rows, k), jnp.inf, d.dtype)
    out_i = jnp.full((rows, k), -1, i.dtype)
    _, out_d, out_i = jax.lax.fori_loop(0, k, body, (d, out_d, out_i))
    return out_d, jnp.where(jnp.isinf(out_d), -1, out_i)
