"""Pallas kernel: fused IVF index scan (centroid matmul + running top-nprobe).

ChamVS.idx (paper §3): queries are compared against all ``nlist`` coarse
centroids and the ``nprobe`` closest lists are selected. On GPU the paper runs
this as two passes (GEMM then select); here the top-nprobe selection is fused
into the GEMM's epilogue so centroid-distance tiles never round-trip to HBM —
the [tile_q, tile_c] score tile is consumed in VMEM by the running queue
carried in the output refs across the centroid-tile grid axis.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import extract_topk_rows


def _ivf_scan_kernel(q_ref, ct_ref, c2_ref, out_d_ref, out_i_ref, *,
                     tile_q: int, tile_c: int, nprobe: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        out_d_ref[...] = jnp.full_like(out_d_ref, jnp.inf)
        out_i_ref[...] = jnp.full_like(out_i_ref, -1)

    q = q_ref[...]                                             # [tile_q, D]
    ct = ct_ref[...]                                           # [D, tile_c]
    # dist = ||q||^2 - 2 q.c + ||c||^2 ; the ||q||^2 term is rank-invariant
    # per row but kept so returned values equal true L2^2 distances.
    scores = jax.lax.dot_general(
        q, ct, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # MXU
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)
    d = q2 - 2.0 * scores + c2_ref[...]                        # [tile_q, tile_c]

    col = ci * tile_c + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    cand_d = jnp.concatenate([out_d_ref[...], d], axis=1)
    cand_i = jnp.concatenate([out_i_ref[...], col], axis=1)
    top_d, top_i = extract_topk_rows(cand_d, cand_i, nprobe)
    out_d_ref[...] = top_d
    out_i_ref[...] = top_i


@functools.partial(jax.jit,
                   static_argnames=("nprobe", "tile_q", "tile_c", "interpret"))
def ivf_scan(queries: jnp.ndarray, centroids: jnp.ndarray, nprobe: int,
             tile_q: int = 8, tile_c: int = 512, interpret: bool = True
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """queries [nq, D], centroids [nlist, D] ->
    (dists [nq, nprobe], list_ids [nq, nprobe]) ascending."""
    nq, D = queries.shape
    nlist = centroids.shape[0]
    tile_q = min(tile_q, nq)
    tile_c = min(tile_c, nlist)
    assert nq % tile_q == 0 and nlist % tile_c == 0, (nq, tile_q, nlist, tile_c)
    ct = centroids.T.astype(jnp.float32)                       # [D, nlist]
    c2 = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=-1)[None, :]

    kernel = functools.partial(_ivf_scan_kernel, tile_q=tile_q, tile_c=tile_c,
                               nprobe=nprobe)
    return pl.pallas_call(
        kernel,
        grid=(nq // tile_q, nlist // tile_c),
        in_specs=[
            pl.BlockSpec((tile_q, D), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((D, tile_c), lambda qi, ci: (0, ci)),
            pl.BlockSpec((1, tile_c), lambda qi, ci: (0, ci)),
        ],
        out_specs=(
            pl.BlockSpec((tile_q, nprobe), lambda qi, ci: (qi, 0)),
            pl.BlockSpec((tile_q, nprobe), lambda qi, ci: (qi, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((nq, nprobe), jnp.float32),
            jax.ShapeDtypeStruct((nq, nprobe), jnp.int32),
        ),
        interpret=interpret,
    )(queries.astype(jnp.float32), ct, c2)
