"""Public wrapper for the fused IVF index scan, routed through the
kernel registry (``repro.kernels.registry``).

The routing decision (Pallas vs reference, tile sizes, the small-index
fallback) lives *outside* the jit boundary so the registry's fallback
counter and one-time warning fire per call — or, when this frontend is
traced inside an outer jit, once per traced shape.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import registry
from repro.kernels.ivf_scan import kernel as _k
from repro.kernels.ivf_scan import ref as _ref

# Below this many IVF lists the Pallas kernel cannot tile profitably
# (tile_c would degenerate to the whole centroid table and the grid to a
# single program), so ``backend="pallas"`` transparently routes to the
# reference scan — loudly, via registry.record_fallback, so benchmarks
# that sweep tiny indexes know their "pallas" numbers are ref numbers.
PALLAS_MIN_NLIST = 128

_jit_ref = jax.jit(_ref.ref_ivf_scan, static_argnames=("nprobe",))


def ivf_index_scan(queries, centroids, nprobe: int,
                   spec: Optional[registry.KernelSpec] = None,
                   backend: Optional[str] = None,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Select the nprobe closest IVF lists per query (ChamVS.idx).

    queries [nq, D], centroids [nlist, D] -> (dists, list_ids)
    [nq, nprobe]. ``backend=``/``interpret=`` are deprecated aliases for
    ``spec=KernelSpec(...)``."""
    spec = registry.resolve("ivf_index_scan", spec, backend, interpret)
    nq = queries.shape[0]
    nlist = centroids.shape[0]
    if spec.backend == "pallas":
        if nlist < PALLAS_MIN_NLIST:
            registry.record_fallback(
                "ivf_index_scan",
                f"nlist={nlist} < PALLAS_MIN_NLIST={PALLAS_MIN_NLIST}",
                spec)
        else:
            return _k.ivf_scan(queries, centroids, nprobe,
                               tile_q=spec.pick_tile_q(nq),
                               tile_c=spec.pick_tile_c(nlist),
                               interpret=spec.interpret)
    return _jit_ref(queries, centroids, nprobe=nprobe)
