"""Public wrapper for the fused IVF index scan."""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ivf_scan import kernel as _k
from repro.kernels.ivf_scan import ref as _ref


@functools.partial(jax.jit, static_argnames=("nprobe", "backend", "interpret"))
def ivf_index_scan(queries: jnp.ndarray, centroids: jnp.ndarray, nprobe: int,
                   backend: str = "pallas", interpret: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Select the nprobe closest IVF lists per query (ChamVS.idx).

    queries [nq, D], centroids [nlist, D] -> (dists, list_ids) [nq, nprobe]."""
    nq = queries.shape[0]
    nlist = centroids.shape[0]
    if backend == "ref" or nlist < 128:
        return _ref.ref_ivf_scan(queries, centroids, nprobe)
    if backend == "pallas":
        tile_q = 8 if nq % 8 == 0 else (4 if nq % 4 == 0 else 1)
        tile_c = 512 if nlist % 512 == 0 else (128 if nlist % 128 == 0 else nlist)
        return _k.ivf_scan(queries, centroids, nprobe,
                           tile_q=tile_q, tile_c=tile_c, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")
