"""Public wrapper for the fused IVF index scan."""
from __future__ import annotations

import functools
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ivf_scan import kernel as _k
from repro.kernels.ivf_scan import ref as _ref

# Below this many IVF lists the Pallas kernel cannot tile profitably
# (tile_c would degenerate to the whole centroid table and the grid to a
# single program), so ``backend="pallas"`` transparently routes to the
# reference scan. Benchmarks that sweep tiny indexes must know their
# "pallas" numbers are really ref numbers — hence the one-time warning.
PALLAS_MIN_NLIST = 128

_pallas_fallback_warned = False


def _warn_pallas_fallback(nlist: int) -> None:
    global _pallas_fallback_warned
    if _pallas_fallback_warned:
        return
    _pallas_fallback_warned = True
    warnings.warn(
        f"ivf_index_scan: backend='pallas' requested but nlist={nlist} < "
        f"PALLAS_MIN_NLIST={PALLAS_MIN_NLIST}; falling back to the "
        "reference scan (benchmark numbers for this index size are NOT "
        "Pallas numbers). This warning is emitted once per process.",
        RuntimeWarning, stacklevel=3)


@functools.partial(jax.jit, static_argnames=("nprobe", "backend", "interpret"))
def ivf_index_scan(queries: jnp.ndarray, centroids: jnp.ndarray, nprobe: int,
                   backend: str = "pallas", interpret: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Select the nprobe closest IVF lists per query (ChamVS.idx).

    queries [nq, D], centroids [nlist, D] -> (dists, list_ids) [nq, nprobe]."""
    nq = queries.shape[0]
    nlist = centroids.shape[0]
    if backend == "ref":
        return _ref.ref_ivf_scan(queries, centroids, nprobe)
    if backend == "pallas":
        if nlist < PALLAS_MIN_NLIST:
            _warn_pallas_fallback(nlist)
            return _ref.ref_ivf_scan(queries, centroids, nprobe)
        tile_q = 8 if nq % 8 == 0 else (4 if nq % 4 == 0 else 1)
        tile_c = 512 if nlist % 512 == 0 else (128 if nlist % 128 == 0 else nlist)
        return _k.ivf_scan(queries, centroids, nprobe,
                           tile_q=tile_q, tile_c=tile_c, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")
