"""Pure-jnp oracle for the IVF index scan kernel (ChamVS.idx, paper step 2)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ref_ivf_scan(queries: jnp.ndarray, centroids: jnp.ndarray, nprobe: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Brute-force L2 distances to all IVF centroids + exact top-nprobe.

    queries [nq, D], centroids [nlist, D] -> (dists [nq, nprobe],
    ids [nq, nprobe]) ascending."""
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=-1)
    d = q2 - 2.0 * (queries @ centroids.T) + c2[None, :]
    neg, idx = jax.lax.top_k(-d, nprobe)
    return -neg, idx
