# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# All frontends route through repro.kernels.registry (KernelSpec:
# backend / interpret / tile heuristics / fallback policy); see
# docs/kernels.md.
from repro.kernels.registry import KernelSpec, reset_warnings  # noqa: F401
