"""Primitive layers: norms, activations, RoPE (standard + M-RoPE), MLP."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5
             ) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    """Gated MLP: (act(x @ w_gate) * (x @ w_up)) @ w_down."""
    g = x @ w_gate
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return (g * (x @ w_up)) @ w_down


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    """[d_head//2] inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: [B, T, H, Dh]; positions: [B, T] -> rotated x (rotate-half form)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                               # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * inv      # [B, T, dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL §3.1): the head-dim frequency bands are
    split into (temporal, height, width) sections, each rotated by its own
    position stream. positions: [3, B, T]; for pure text all three streams
    are equal and M-RoPE degenerates to 1-D RoPE.

    x: [B, T, H, Dh]."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(dh, theta)                               # [half]
    # build a per-frequency position stream by section
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=half)  # [half]
    pos = positions.astype(jnp.float32)                        # [3, B, T]
    pos_per_freq = pos[sec_id]                                 # [half, B, T]
    ang = jnp.moveaxis(pos_per_freq, 0, -1) * inv              # [B, T, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positional_rotate(x: jnp.ndarray, positions: jnp.ndarray, cfg
                      ) -> jnp.ndarray:
    """Dispatch on cfg.rope_mode. positions is [B, T] (rope) or [3, B, T]
    (mrope; a [B, T] input is broadcast to all three streams)."""
    if cfg.rope_mode == "none":
        return x
    if cfg.rope_mode == "mrope":
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[None],
                                         (3,) + positions.shape)
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)
