"""Attention: GQA with full / sliding-window masks.

Execution paths:
  * ``flash_attention`` — blocked online-softmax over (q-block, kv-block)
    tiles via ``lax.scan`` so the [T, S] score matrix is never materialized
    (required: train_4k batch 256 and prefill_32k would otherwise allocate
    TB-scale score tensors). This is the pure-JAX analogue of a Pallas/TPU
    flash kernel and is what the dry-run lowers.
  * ``naive_attention`` — direct softmax(QK^T)V oracle for tests.
  * ``decode_attention`` — one new token against a KV cache (full or ring),
    spec-routed through the kernel registry: ``backend="ref"`` (default)
    is the grouped-einsum path that contracts the KV-head axis directly,
    ``backend="pallas"`` the streaming ``kernels/decode_attn`` kernel
    (online softmax over kv blocks, skips blocks past each row tile's
    max valid position), ``backend="einsum"`` the legacy
    full-materialization path kept as the parity oracle
    (``decode_attention_einsum``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.decode_attn.ops import pallas_decode_attention
from repro.kernels.decode_attn.ref import (decode_validity,
                                           ref_decode_attention)
from repro.models.ctx import constrain, kv_tags

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, q_per_kv: int) -> jnp.ndarray:
    """[B, S, KV, D] -> [B, S, KV*q_per_kv, D] (GQA head expansion)."""
    if q_per_kv == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, q_per_kv, d)
                            ).reshape(b, s, kv * q_per_kv, d)


def _mask(qpos: jnp.ndarray, kpos: jnp.ndarray, causal: bool, window: int
          ) -> jnp.ndarray:
    """[..., Tq, Tk] boolean validity from absolute positions."""
    m = jnp.ones(qpos.shape[:-1] + (qpos.shape[-1], kpos.shape[-1]), bool)
    if causal:
        m &= kpos[..., None, :] <= qpos[..., :, None]
    if window > 0:
        m &= kpos[..., None, :] > qpos[..., :, None] - window
    return m


def naive_attention(q, k, v, q_positions, k_positions, causal=True, window=0):
    """Oracle. q [B,T,H,D], k/v [B,S,KV,D], positions [B,T]/[B,S] -> [B,T,H,D]."""
    qkv = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, qkv), _repeat_kv(v, qkv)
    scores = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32)
    scores *= q.shape[-1] ** -0.5
    m = _mask(q_positions, k_positions, causal, window)[:, None]   # [B,1,T,S]
    scores = jnp.where(m, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (window slid past): zero output, not nan
    w = jnp.where(m.any(-1, keepdims=True), w, 0.0)
    return jnp.einsum("bhts,bshd->bthd", w.astype(v.dtype), v)


def flash_attention(q, k, v, q_positions, k_positions, causal=True, window=0,
                    q_block=512, k_block=512):
    """Blocked online-softmax attention (memory O(T*D), not O(T*S)).

    q [B,T,H,D], k/v [B,S,KV,D]; positions carry absolute indices so causal /
    sliding-window masks work for prefill with history and for padded tails.

    Custom VJP (FA2-style): the backward recomputes p-tiles from q/k and the
    saved per-row (m, l) statistics instead of letting autodiff checkpoint
    every kv-scan iteration — plain autodiff of the scan stored ~8 TB/layer
    of residuals for llama3-405b train (EXPERIMENTS.md §Perf iteration 9).
    """
    out, _ = _flash_fwd_stats(q, k, v, q_positions, k_positions, causal,
                              window, q_block, k_block)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash_fwd_stats(q, k, v, q_positions, k_positions, causal, window,
                     q_block, k_block):
    return _flash_forward(q, k, v, q_positions, k_positions, causal, window,
                          q_block, k_block)


def _flash_forward(q, k, v, q_positions, k_positions, causal=True, window=0,
                   q_block=512, k_block=512):
    """Returns (out [B,T,H,D], lse [B,T,H]) — log-sum-exp per row for bwd."""
    B, T, H, D = q.shape
    S = k.shape[1]
    qkv = H // k.shape[2]
    q_block = min(q_block, T)
    k_block = min(k_block, S)
    # pad to block multiples; padded q rows are garbage-in/garbage-out (cropped),
    # padded k rows get position +inf-like so every mask rejects them.
    Tp = -(-T // q_block) * q_block
    Sp = -(-S // k_block) * k_block
    qp = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, ((0, 0), (0, Tp - T)))
    kpos = jnp.pad(k_positions, ((0, 0), (0, Sp - S)),
                   constant_values=jnp.iinfo(jnp.int32).max)
    kp = _repeat_kv(kp, qkv)
    vp = _repeat_kv(vp, qkv)
    # pin the attention layout: batch over dp, heads over model. Without
    # this GSPMD re-shards q/k/v feature-wise inside the kv scan and
    # replicates the batch (measured 27 TB/step prefill traffic for
    # qwen2-0.5b; EXPERIMENTS.md §Perf iteration 6). Head counts that do
    # not divide the axis (14H/16) are padded by GSPMD — bounded waste.
    qp = constrain(qp, "dp", None, "model", None)
    kp = constrain(kp, "dp", None, "model", None)
    vp = constrain(vp, "dp", None, "model", None)

    nq, nk = Tp // q_block, Sp // k_block
    qb = qp.reshape(B, nq, q_block, H, D)
    qbpos = qpos.reshape(B, nq, q_block)
    kb = kp.reshape(B, nk, k_block, H, D)
    vbv = vp.reshape(B, nk, k_block, H, D)
    kbpos = kpos.reshape(B, nk, k_block)
    scale = D ** -0.5

    def q_step(_, qi):
        qblk, qbp = qi                                       # [B,qb,H,D],[B,qb]

        def kv_step(carry, ki):
            acc, mx, sm = carry
            kblk, vblk, kbp = ki
            # f32 accumulation via preferred_element_type: a separate
            # .astype makes XLA re-convert the whole stacked K/V every scan
            # step (missed LICM, measured 34 MB/tile; §Perf iteration 7)
            s = jnp.einsum("bthd,bshd->bhts", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qbp, kbp, causal, window)[:, None]
            # padded keys carry sentinel positions — always reject them
            msk &= (kbp < jnp.iinfo(jnp.int32).max)[:, None, None, :]
            s = jnp.where(msk, s, NEG_INF)
            new_mx = jnp.maximum(mx, s.max(-1))              # [B,H,qb]
            corr = jnp.exp(mx - new_mx)
            p = jnp.exp(s - new_mx[..., None])
            p = jnp.where(msk, p, 0.0)
            sm = sm * corr + p.sum(-1)
            pv = jnp.einsum("bhts,bshd->bhtd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, new_mx, sm), None

        acc0 = jnp.zeros((B, H, q_block, D), jnp.float32)
        mx0 = jnp.full((B, H, q_block), NEG_INF, jnp.float32)
        sm0 = jnp.zeros((B, H, q_block), jnp.float32)
        (acc, mx, sm), _ = jax.lax.scan(
            kv_step, (acc0, mx0, sm0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vbv, 1, 0),
             jnp.moveaxis(kbpos, 1, 0)))
        out = acc / jnp.maximum(sm[..., None], 1e-20)
        lse = mx + jnp.log(jnp.maximum(sm, 1e-20))           # [B,H,qb]
        return None, (out.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(
        q_step, None,
        (jnp.moveaxis(qb, 1, 0), jnp.moveaxis(qbpos, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1)                           # [B,nq,H,qb,D]
    out = jnp.moveaxis(out, 3, 2).reshape(B, Tp, H, D)
    lse = jnp.moveaxis(lses, 0, 1)                           # [B,nq,H,qb]
    lse = jnp.moveaxis(lse, 3, 2).reshape(B, Tp, H)
    return out[:, :T], lse[:, :T]


def _flash_fwd_rule(q, k, v, q_positions, k_positions, causal, window,
                    q_block, k_block):
    out, lse = _flash_forward(q, k, v, q_positions, k_positions, causal,
                              window, q_block, k_block)
    return (out, lse), (q, k, v, q_positions, k_positions, out, lse)


def _flash_bwd_rule(causal, window, q_block, k_block, res, cts):
    """FA2 backward: recompute p-tiles from (q, k, lse); no stored tiles.

    dq pass: scan q blocks, inner scan kv blocks.
    dk/dv pass: scan kv blocks, inner scan q blocks (loop order swapped so
    each accumulator lives in its own outer scan)."""
    q, k, v, q_positions, k_positions, out, lse = res
    dout = cts[0].astype(jnp.float32)
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    qkv = H // KV
    qb_n = min(q_block, T)
    kb_n = min(k_block, S)
    Tp = -(-T // qb_n) * qb_n
    Sp = -(-S // kb_n) * kb_n

    def padt(x, n, fill=0):
        w = [(0, 0)] * x.ndim
        w[1] = (0, n - x.shape[1])
        return jnp.pad(x, w, constant_values=fill)

    qp = padt(q, Tp).astype(jnp.float32)
    kp = _repeat_kv(padt(k, Sp), qkv).astype(jnp.float32)
    vp = _repeat_kv(padt(v, Sp), qkv).astype(jnp.float32)
    dop = padt(dout, Tp)
    lsep = padt(lse, Tp)
    outp = padt(out, Tp).astype(jnp.float32)
    qpos = padt(q_positions, Tp)
    kpos = padt(k_positions, Sp, fill=jnp.iinfo(jnp.int32).max)
    qp = constrain(qp, "dp", None, "model", None)
    kp = constrain(kp, "dp", None, "model", None)
    vp = constrain(vp, "dp", None, "model", None)
    scale = D ** -0.5
    nq, nk = Tp // qb_n, Sp // kb_n

    # D_i = rowsum(dOut * Out)
    delta = jnp.einsum("bthd,bthd->bth", dop, outp)          # [B,Tp,H]

    def blocks(x, n, blk):
        return jnp.moveaxis(x.reshape(B, n, blk, *x.shape[2:]), 1, 0)

    qB, doB = blocks(qp, nq, qb_n), blocks(dop, nq, qb_n)
    lseB, dltB = blocks(lsep, nq, qb_n), blocks(delta, nq, qb_n)
    qpB = blocks(qpos, nq, qb_n)
    kB, vB = blocks(kp, nk, kb_n), blocks(vp, nk, kb_n)
    kpB = blocks(kpos, nk, kb_n)

    def tile(qblk, qbp, lseb, dltb, dob, kblk, vblk, kbp):
        s = jnp.einsum("bthd,bshd->bhts", qblk, kblk,
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(qbp, kbp, causal, window)[:, None]
        msk &= (kbp < jnp.iinfo(jnp.int32).max)[:, None, None, :]
        p = jnp.where(msk, jnp.exp(s - jnp.moveaxis(lseb, -1, 1)[..., None]),
                      0.0)                                    # [B,H,qb,kb]
        dp = jnp.einsum("bthd,bshd->bhts", dob, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - jnp.moveaxis(dltb, -1, 1)[..., None]) * scale
        return p, ds

    # pass 1: dq
    def dq_step(_, xs):
        qblk, qbp, lseb, dltb, dob = xs

        def inner(dq_acc, ys):
            kblk, vblk, kbp = ys
            p, ds = tile(qblk, qbp, lseb, dltb, dob, kblk, vblk, kbp)
            dq_acc = dq_acc + jnp.einsum("bhts,bshd->bthd", ds, kblk)
            return dq_acc, None

        dq0 = jnp.zeros((B, qb_n, H, D), jnp.float32)
        dq_blk, _ = jax.lax.scan(inner, dq0, (kB, vB, kpB))
        return None, dq_blk

    _, dqs = jax.lax.scan(dq_step, None, (qB, qpB, lseB, dltB, doB))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Tp, H, D)[:, :T]

    # pass 2: dk, dv
    def dkv_step(_, xs):
        kblk, vblk, kbp = xs

        def inner(carry, ys):
            dk_acc, dv_acc = carry
            qblk, qbp, lseb, dltb, dob = ys
            p, ds = tile(qblk, qbp, lseb, dltb, dob, kblk, vblk, kbp)
            dv_acc = dv_acc + jnp.einsum("bhts,bthd->bshd", p, dob)
            dk_acc = dk_acc + jnp.einsum("bhts,bthd->bshd", ds, qblk)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, kb_n, H, D), jnp.float32)
        (dk_blk, dv_blk), _ = jax.lax.scan(inner, (z, z),
                                           (qB, qpB, lseB, dltB, doB))
        return None, (dk_blk, dv_blk)

    _, (dks, dvs) = jax.lax.scan(dkv_step, None, (kB, vB, kpB))
    dk_full = jnp.moveaxis(dks, 0, 1).reshape(B, Sp, H, D)[:, :S]
    dv_full = jnp.moveaxis(dvs, 0, 1).reshape(B, Sp, H, D)[:, :S]
    # un-repeat GQA heads: sum gradient over the q-per-kv group
    dk = dk_full.reshape(B, S, KV, qkv, D).sum(3)
    dv = dv_full.reshape(B, S, KV, qkv, D).sum(3)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_flash_fwd_stats.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def decode_attention(q, k_cache, v_cache, position, window=0,
                     ring: bool = False,
                     spec: Optional[registry.KernelSpec] = None):
    """One-token decode. q [B,1,H,D]; caches [B,S,KV,D]; position [B] int32.

    The batch rows are independent: ``position`` is per-row, and every row
    attends only over its own valid prefix (invalid slots are masked to
    exactly zero weight). This is what lets a wave of pooled cache slots
    with ragged lengths decode as one batch (``transformer.decode_wave``).

    ``ring=True`` means the cache is a sliding ring buffer of size S=window:
    slot i holds absolute position p_i = pos - ((pos - i) mod S); otherwise
    slot i holds absolute position i and validity is i <= pos.

    ``spec`` selects the execution path (see the module docstring). The
    default is ``backend="ref"`` — the grouped-einsum path, the right
    flavor for CPU hosts where Pallas only interprets — NOT the
    registry-wide pallas default; accelerator deployments opt into the
    streaming kernel via ``EngineConfig.attn_backend="pallas"``."""
    spec = registry.resolve("decode_attn", spec, default=registry.REF)
    if spec.backend == "einsum":
        return decode_attention_einsum(q, k_cache, v_cache, position,
                                       window=window, ring=ring)
    tags = kv_tags()
    constrain_scores = None
    if tags is not None:
        # keep the softmax DISTRIBUTED over the seq-sharded cache: without
        # these hints GSPMD all-gathers the full cache per TP column
        # (measured f32 1.1 GB/layer, EXPERIMENTS.md §Perf iteration 4)
        kb, ks = tags
        k_cache = constrain(k_cache, kb, ks, None, None)
        v_cache = constrain(v_cache, kb, ks, None, None)
        # grouped scores are [B, KV, G, T, S]: batch tag on dim 0, the
        # seq-sharded axis on dim 4 — same invariant the einsum oracle
        # pins on its [B, H, T, S] row
        constrain_scores = lambda s: constrain(s, kb, None, None, None, ks)
    if spec.backend == "pallas":
        return pallas_decode_attention(q, k_cache, v_cache, position,
                                       window=window, ring=ring, spec=spec)
    return ref_decode_attention(q, k_cache, v_cache, position,
                                window=window, ring=ring,
                                constrain_scores=constrain_scores)


def decode_attention_einsum(q, k_cache, v_cache, position, window=0,
                            ring: bool = False):
    """The legacy decode path, kept verbatim as the parity oracle: GQA
    heads expanded via ``_repeat_kv`` to [B,S,H,D] and one full
    [B,H,1,S] score row over the entire padded seq axis. Every other
    flavor (grouped ref, streaming Pallas) must match it token-for-token
    under greedy serving (tests/test_decode_attn.py)."""
    B, S, KV, D = k_cache.shape
    H = q.shape[2]
    k = _repeat_kv(k_cache, H // KV)
    v = _repeat_kv(v_cache, H // KV)
    tags = kv_tags()
    if tags is not None:
        kb, ks = tags
        k = constrain(k, kb, ks, None, None)
        v = constrain(v, kb, ks, None, None)
    s = jnp.einsum("bthd,bshd->bhts", q, k).astype(jnp.float32) * D ** -0.5
    if tags is not None:
        s = constrain(s, tags[0], None, None, tags[1])
    valid = decode_validity(position, S, window, ring)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bshd->bthd", w.astype(v.dtype), v)


def prefill_cache(k_cache, v_cache, k_new, v_new, ring: bool = False):
    """Bulk cache construction for prefill of positions 0..T-1 — pad/roll
    instead of a scatter (SPMD scatters into seq-sharded caches force the
    partitioner to replicate operands; §Perf iteration 7)."""
    B, S, KV, D = k_cache.shape
    T = k_new.shape[1]
    dt = k_cache.dtype
    if not ring:
        if T >= S:
            return k_new[:, :S].astype(dt), v_new[:, :S].astype(dt)
        pad = ((0, 0), (0, S - T), (0, 0), (0, 0))
        return jnp.pad(k_new.astype(dt), pad), jnp.pad(v_new.astype(dt), pad)
    if T < S:   # ring not yet wrapped: slots p%S == p
        pad = ((0, 0), (0, S - T), (0, 0), (0, 0))
        return jnp.pad(k_new.astype(dt), pad), jnp.pad(v_new.astype(dt), pad)
    tail_k = k_new[:, T - S:].astype(dt)       # positions T-S .. T-1
    tail_v = v_new[:, T - S:].astype(dt)
    shift = (T - S) % S                         # slot of the first tail pos
    return (jnp.roll(tail_k, shift, axis=1), jnp.roll(tail_v, shift, axis=1))


def update_cache(k_cache, v_cache, k_new, v_new, position, ring: bool = False,
                 slots=None):
    """Write [B,Tn,KV,D] new keys/values at `position` (scalar int or [B]).

    Full cache: slot = position + t. Ring cache: slot = (position + t) % S.
    Scatter form: with donated caches XLA performs the update in place, so
    per-step HBM traffic is O(written slots), not O(cache) — this is what
    keeps the decode memory-roofline term parameter-dominated.

    ``slots`` is the batched-slot path (KV-cache pool): the caches hold
    ``P`` pooled rows while ``k_new``/``v_new`` carry one wave of ``W``
    active rows; row ``w`` of the wave is written into pool row
    ``slots[w]``. Without ``slots`` the wave and the cache batch dims
    coincide (the classic per-sequence layout)."""
    S = k_cache.shape[1]
    B, Tn = k_new.shape[:2]
    pos = jnp.broadcast_to(jnp.asarray(position), (B,))
    t = jnp.arange(Tn)
    seq_idx = pos[:, None] + t[None, :]                       # [B,Tn]
    if ring:
        seq_idx = seq_idx % S
    rows = jnp.arange(B) if slots is None else jnp.asarray(slots)
    bidx = jnp.broadcast_to(rows[:, None], (B, Tn))
    k_cache = k_cache.at[bidx, seq_idx].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, seq_idx].set(v_new.astype(v_cache.dtype))
    return k_cache, v_cache
