"""Model configuration — one dataclass covers the 10 assigned backbones plus
the paper's own four RALM configs (Table 2).

Layer heterogeneity (gemma3's 5:1 local:global, hymba's sparse global layers)
is expressed with a *layer pattern*: a cycle of layer-class names; the stack
groups parameters by class and scans each class's layers with a uniform body
(compile-economy: HLO size independent of depth, DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                      # 0 -> d_model // n_heads

    # block family: "dense" | "moe" | "rwkv6" | "hybrid" (attn ∥ mamba)
    block: str = "dense"

    # attention pattern: cycle of "global" / "local" layer classes
    layer_pattern: Tuple[str, ...] = ("global",)
    window: int = 0                      # sliding window for "local" layers

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_mode: str = "rope"              # "rope" | "mrope" | "none"
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # qwen2-vl t/h/w split

    # MoE
    n_experts: int = 0
    top_k: int = 0

    # SSM (hybrid mamba branch / rwkv6)
    ssm_state: int = 0
    conv_width: int = 4

    # encoder-decoder
    arch: str = "decoder"                # "decoder" | "encdec"
    n_enc_layers: int = 0

    # norm / act
    norm_eps: float = 1e-5
    act: str = "silu"                    # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = False

    # modality frontend stub: None | "vision" | "audio"
    frontend: Optional[str] = None

    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))

    # ---- derived ----
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def pattern_classes(self) -> Tuple[str, ...]:
        """Distinct layer-class names in stack order of first appearance."""
        seen, out = set(), []
        for i in range(self.n_layers):
            c = self.layer_pattern[i % len(self.layer_pattern)]
            if c not in seen:
                seen.add(c)
                out.append(c)
        return tuple(out)

    def layer_classes(self) -> Tuple[str, ...]:
        """Per-layer class name, length n_layers."""
        return tuple(self.layer_pattern[i % len(self.layer_pattern)]
                     for i in range(self.n_layers))

    def class_layers(self, cls: str) -> Tuple[int, ...]:
        """Global layer indices belonging to class `cls`."""
        return tuple(i for i, c in enumerate(self.layer_classes()) if c == cls)

    def param_count(self) -> int:
        """Total parameters (embedding + blocks), for 6ND model-flops math."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab_size
        nh, nkv, dh = self.n_heads, self.n_kv_heads, self.d_head
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.block == "rwkv6":
            # r,k,v,g,w projections + output + ffn(2 mats) + small lora decays
            tmix = d * (nh * dh) * 4 + d * (nh * dh) + d * 64 * 2 * 5
            cmix = d * f + f * d + d * d
            blk = tmix + cmix
        else:
            attn = d * nh * dh + 2 * d * nkv * dh + nh * dh * d
            if self.block == "moe":
                mlp = self.n_experts * 3 * d * f + d * self.n_experts
            else:
                mlp = 3 * d * f
            blk = attn + mlp
            if self.block == "hybrid":
                d_in = nh * dh
                blk += 2 * d * d_in + d_in * (2 * self.ssm_state + 1) + d_in * d
        enc = 0
        if self.arch == "encdec":
            # encoder layers + decoder cross-attention
            enc_attn = d * nh * dh + 2 * d * nkv * dh + nh * dh * d
            enc = self.n_enc_layers * (enc_attn + 3 * d * f)
            blk += enc_attn  # cross-attn per decoder layer
        return emb + L * blk + enc

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top_k experts)."""
        if self.block != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dense_like = self.param_count() - L * self.n_experts * 3 * d * f
        return dense_like + L * self.top_k * 3 * d * f
