"""Mixture-of-Experts FFN (dbrx: 16e top-4; phi3.5-moe: 16e top-2).

Sort-based capacity dispatch (MegaBlocks-lite, static shapes):
  router top-k -> flatten (token, expert) assignments -> stable-sort by
  expert -> slot-in-expert via segment arithmetic -> scatter into a dense
  [E, C, d] buffer -> batched expert GEMMs -> weighted scatter-add combine.

This keeps compute proportional to top_k (not E) and avoids the GShard
[N, E, C] one-hot dispatch tensor, which does not fit at train_4k scale.
Tokens beyond expert capacity are dropped (standard GShard semantics); the
residual path keeps their representation intact.

Sharding (DESIGN.md §7): experts over the "data" axis (EP), expert-internal
f over "model" (TP); the dispatch scatter becomes an all-to-all under GSPMD.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def route_topk(x: jnp.ndarray, router_w: jnp.ndarray, top_k: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [N, d], router_w [d, E] -> (weights [N, k] softmaxed over chosen,
    expert_ids [N, k])."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    top_logits, top_ids = jax.lax.top_k(logits, top_k)
    w = jax.nn.softmax(top_logits, axis=-1)
    return w, top_ids


def load_balance_loss(x: jnp.ndarray, router_w: jnp.ndarray, top_k: int
                      ) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    E = logits.shape[-1]
    p = jax.nn.softmax(logits, axis=-1)
    _, top_ids = jax.lax.top_k(logits, top_k)
    f = jnp.zeros((E,), jnp.float32).at[top_ids.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    return E * jnp.sum(f * p.mean(axis=0))


def moe_ffn(
    x: jnp.ndarray,            # [N, d] flattened tokens
    router_w: jnp.ndarray,     # [d, E]
    w_gate: jnp.ndarray,       # [E, d, f]
    w_up: jnp.ndarray,         # [E, d, f]
    w_down: jnp.ndarray,       # [E, f, d]
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
) -> jnp.ndarray:
    """Top-k expert FFN with capacity dropping. Returns [N, d]."""
    N, d = x.shape
    E = router_w.shape[-1]
    C = int(max(1, -(-int(N * top_k * capacity_factor) // E)))
    C = -(-C // 8) * 8  # pad capacity to a lane-friendly multiple

    gate_w, expert_ids = route_topk(x, router_w, top_k)      # [N,k] each
    flat_e = expert_ids.reshape(-1)                          # [N*k]
    flat_t = jnp.repeat(jnp.arange(N), top_k)                # [N*k] token idx
    flat_w = gate_w.reshape(-1)                              # [N*k]

    # stable sort by expert -> contiguous expert segments
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # slot within expert = rank - segment start
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    seg_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                 jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(N * top_k, dtype=jnp.int32) - seg_start[se]
    keep = slot < C

    # dispatch: scatter tokens into the [E, C, d] expert buffer
    e_idx = jnp.where(keep, se, 0)
    s_idx = jnp.where(keep, slot, 0)
    xin = jnp.where(keep[:, None], x[st], 0.0)
    buf = jnp.zeros((E, C, d), x.dtype).at[e_idx, s_idx].add(xin)

    # batched expert GEMMs (gated MLP)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    y_e = jnp.einsum("ecf,efd->ecd", g * u, w_down)          # [E, C, d]

    # combine: gather each assignment's expert output, weight, scatter by token
    y_tok = y_e[e_idx, s_idx]                                # [N*k, d]
    y_tok = jnp.where(keep[:, None], y_tok, 0.0) * sw[:, None].astype(y_e.dtype)
    return jnp.zeros((N, d), y_e.dtype).at[st].add(y_tok)
