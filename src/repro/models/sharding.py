"""Partition rules (DESIGN.md §7): FSDP over ("pod","data"), TP over "model",
EP over "data" for MoE experts.

GSPMD (jit + NamedSharding) rather than shard_map is used for the model
programs because several assigned archs have head counts that do not divide
the 16-way model axis (qwen2 14H, hymba 25H, rwkv6 40H) — GSPMD handles
uneven sharding by padding; shard_map would reject it. ChamVS keeps
shard_map (its shapes are deployment-controlled).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The compound FSDP/batch axis: ("pod","data") when multi-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _block_rules(dp, ep_axis: Optional[str], pod_axis: Optional[str]
                 ) -> Dict[str, P]:
    """Specs for stacked per-layer params ([L, ...] leading axis unsharded)."""
    return {
        # attention
        "ln1": P(), "ln2": P(), "lnx": P(),
        "wq": P(None, dp, "model"), "wk": P(None, dp, "model"),
        "wv": P(None, dp, "model"), "wo": P(None, "model", dp),
        "bq": P(None, "model"), "bk": P(None, "model"), "bv": P(None, "model"),
        "xwq": P(None, dp, "model"), "xwk": P(None, dp, "model"),
        "xwv": P(None, dp, "model"), "xwo": P(None, "model", dp),
        # dense mlp (3D) — moe variants (4D) handled by ndim below
        "wg": P(None, dp, "model"), "wu": P(None, dp, "model"),
        "wd": P(None, "model", dp),
        "router": P(None, dp, None),
        # hybrid mamba branch
        "w_in": P(None, dp, "model"), "conv_w": P(None, None, "model"),
        "w_bcdt": P(None, "model", None), "a_log": P(), "dt_bias": P(),
        "d_skip": P(), "w_out": P(None, "model", dp),
        "ln_attn_out": P(), "ln_ssm_out": P(),
        # rwkv6
        "mu_r": P(), "mu_k": P(), "mu_v": P(), "mu_g": P(), "mu_w": P(),
        "w_r": P(None, dp, "model"), "w_k": P(None, dp, "model"),
        "w_v": P(None, dp, "model"), "w_g": P(None, dp, "model"),
        "w_o": P(None, "model", dp),
        "w0": P(None, "model"), "w_lora_a": P(None, dp, None),
        "w_lora_b": P(None, None, "model"),
        "bonus_u": P(), "ln_x": P(None, "model"),
        "mu_ck": P(), "mu_cr": P(),
        "w_ck": P(None, dp, "model"), "w_cv": P(None, "model", dp),
        "w_cr": P(None, dp, "model"),
    }


def param_specs(cfg: ModelConfig, mesh: Mesh) -> Any:
    """PartitionSpec tree matching ``transformer.init_params`` output."""
    dp = dp_axes(mesh)
    ep = "data" if "data" in mesh.axis_names else None
    pod = "pod" if "pod" in mesh.axis_names else None
    rules = _block_rules(dp, ep, pod)

    moe_rules = {
        # experts over data (EP); d over pod (extra FSDP dim); f over model
        "wg": P(None, ep, pod, "model"), "wu": P(None, ep, pod, "model"),
        "wd": P(None, ep, "model", pod),
    }

    def spec_of(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = keys[-1]
        in_moe = leaf.ndim == 4 and name in moe_rules
        if name == "embed":
            return P("model", dp)
        if name == "lm_head":
            return P(dp, "model")
        if name == "final_norm":
            return P()
        if in_moe:
            return moe_rules[name]
        if name in rules:
            s = rules[name]
            # stacked-norm etc: P() means fully replicated regardless of ndim
            return s
        return P()

    return jax.tree_util.tree_map_with_path(spec_of, _as_shape_tree(cfg))


def _as_shape_tree(cfg: ModelConfig):
    """Abstract params (ShapeDtypeStructs) — cheap spec derivation without
    materializing weights."""
    from repro.models import transformer as tf
    return jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg))


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_tree,
                shard_seq: bool = False) -> Any:
    """Specs for decode caches.

    KV caches are sharded batch-over-dp and **sequence-over-model**
    (split-KV / flash-decode style): every TP column streams S/|model| of
    the cache and the softmax reduces across columns with a tiny all-reduce.
    Head-dim sharding is deliberately avoided — several archs have
    n_kv_heads (2-8) smaller than the 16-way model axis, which would force
    GSPMD to replicate the cache per column (measured 16x decode-bytes blowup,
    EXPERIMENTS.md §Perf iteration 1).

    ``shard_seq`` (long_500k, batch 1): batch cannot shard, so sequence goes
    over dp axes as well."""
    dp = dp_axes(mesh)

    def spec_of(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        name = keys[-1]
        if name in ("k", "v", "xk", "xv"):      # [Lc, B, S, KV, dh]
            if shard_seq:
                return P(None, None, dp + ("model",), None, None)
            return P(None, dp, "model", None, None)
        if name == "wkv":                        # [Lc, B, H, dh, dh]
            return P(None, dp, "model", None, None)
        if name == "ssm":                        # [Lc, B, H, dh, ds]
            return P(None, dp, "model", None, None)
        if name == "conv":                       # [Lc, B, cw-1, d_in]
            return P(None, dp, None, "model")
        if name in ("st", "sc"):                 # [Lc, B, d]
            return P(None, dp, None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_of, cache_tree)


def put_named(tree, specs, mesh: Mesh):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)


def sanitize(spec_tree, struct_tree, mesh: Mesh):
    """Drop sharding on dimensions the mesh cannot divide evenly.

    jit in_shardings require divisibility; several archs have dims like
    d_ff=1368 (dec-s) or vocab=256206 (seamless) that do not divide a
    16-way axis. For compound axes, progressively drop leading axes
    (("pod","data") -> ("data",)) before giving up."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix_leaf(spec: P, leaf):
        if not isinstance(spec, P):
            return spec
        dims = getattr(leaf, "shape", None)
        if dims is None:
            return spec
        out = []
        for i, entry in enumerate(spec):
            if entry is None or i >= len(dims):
                out.append(None if i >= len(dims) else entry)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            while axes:
                prod = 1
                for a in axes:
                    prod *= sizes.get(a, 1)
                if dims[i] % prod == 0:
                    break
                axes = axes[1:]
            out.append(tuple(axes) if len(axes) > 1
                       else (axes[0] if axes else None))
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    return jax.tree.map(fix_leaf, spec_tree, struct_tree,
                        is_leaf=lambda x: isinstance(x, P))
