"""The model stack: decoder-only / encoder-decoder transformers over four
block families (dense, moe, hybrid attn∥mamba, rwkv6), with heterogeneous
layer patterns (gemma3 local:global), KV / ring / SSM caches, and three
execution modes:

  * ``train``   — full sequence, no cache, flash attention
  * ``prefill`` — full sequence, builds the cache (serving step 1)
  * ``decode``  — one token against the cache (serving steady state)

Compile economy (DESIGN.md §8): layers are stacked per layer-class and the
stack is applied by a ``lax.scan`` over groups of ``period`` layers, so HLO
size is O(period), independent of depth — required to compile llama3-405b's
126 layers on one host core with 512 fake devices.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import (decode_attention, flash_attention,
                                    prefill_cache, update_cache)
from repro.models.config import ModelConfig
from repro.models.ctx import constrain
from repro.models.layers import positional_rotate, rms_norm, swiglu

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale=0.02, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _init_block_class(key, cfg: ModelConfig, n: int, cross: bool) -> Params:
    """Stacked params for `n` layers of one class."""
    d, f = cfg.d_model, cfg.d_ff
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = jnp.dtype(cfg.dtype)
    ks = iter(jax.random.split(key, 64))
    p: Params = {}
    if cfg.block == "rwkv6":
        D = H * dh
        def v(shape, scale=0.02):
            return _dense_init(next(ks), (n,) + shape, scale, dt)
        p = dict(
            ln1=jnp.ones((n, d), dt), ln2=jnp.ones((n, d), dt),
            mu_r=v((d,), 0.5), mu_k=v((d,), 0.5), mu_v=v((d,), 0.5),
            mu_g=v((d,), 0.5), mu_w=v((d,), 0.5),
            w_r=v((d, D)), w_k=v((d, D)), w_v=v((d, D)), w_g=v((d, D)),
            w_o=v((D, d)),
            w0=v((D,), 0.5), w_lora_a=v((d, 64)), w_lora_b=v((64, D)),
            bonus_u=v((H, dh), 0.5), ln_x=jnp.ones((n, D), dt),
            mu_ck=v((d,), 0.5), mu_cr=v((d,), 0.5),
            w_ck=v((d, f)), w_cv=v((f, d)), w_cr=v((d, d)),
        )
        return p

    p["ln1"] = jnp.ones((n, d), dt)
    p["wq"] = _dense_init(next(ks), (n, d, H * dh), dtype=dt)
    p["wk"] = _dense_init(next(ks), (n, d, KV * dh), dtype=dt)
    p["wv"] = _dense_init(next(ks), (n, d, KV * dh), dtype=dt)
    p["wo"] = _dense_init(next(ks), (n, H * dh, d), dtype=dt)
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n, H * dh), dt)
        p["bk"] = jnp.zeros((n, KV * dh), dt)
        p["bv"] = jnp.zeros((n, KV * dh), dt)
    p["ln2"] = jnp.ones((n, d), dt)
    if cfg.block == "moe":
        E = cfg.n_experts
        p["router"] = _dense_init(next(ks), (n, d, E), dtype=jnp.float32)
        p["wg"] = _dense_init(next(ks), (n, E, d, f), dtype=dt)
        p["wu"] = _dense_init(next(ks), (n, E, d, f), dtype=dt)
        p["wd"] = _dense_init(next(ks), (n, E, f, d), dtype=dt)
    else:
        p["wg"] = _dense_init(next(ks), (n, d, f), dtype=dt)
        p["wu"] = _dense_init(next(ks), (n, d, f), dtype=dt)
        p["wd"] = _dense_init(next(ks), (n, f, d), dtype=dt)
    if cfg.block == "hybrid":
        d_in = H * dh
        ds, cw = cfg.ssm_state, cfg.conv_width
        p["mamba"] = ssm_lib.MambaParams(
            w_in=_dense_init(next(ks), (n, d, 2 * d_in), dtype=dt),
            conv_w=_dense_init(next(ks), (n, cw, d_in), 0.2, dt),
            w_bcdt=_dense_init(next(ks), (n, d_in, 2 * ds + H), dtype=dt),
            a_log=jnp.zeros((n, H, ds), jnp.float32),
            dt_bias=jnp.zeros((n, H), jnp.float32),
            d_skip=jnp.ones((n, H), jnp.float32),
            w_out=_dense_init(next(ks), (n, d_in, d), dtype=dt),
        )
        p["ln_attn_out"] = jnp.ones((n, d), dt)
        p["ln_ssm_out"] = jnp.ones((n, d), dt)
    if cross:
        p["lnx"] = jnp.ones((n, d), dt)
        p["xwq"] = _dense_init(next(ks), (n, d, H * dh), dtype=dt)
        p["xwk"] = _dense_init(next(ks), (n, d, KV * dh), dtype=dt)
        p["xwv"] = _dense_init(next(ks), (n, d, KV * dh), dtype=dt)
        p["xwo"] = _dense_init(next(ks), (n, H * dh, d), dtype=dt)
    return p


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    k_emb, k_head, k_cls, k_enc = jax.random.split(key, 4)
    params: Params = {
        "embed": _dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dtype=dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(
            k_head, (cfg.d_model, cfg.vocab_size), dtype=dt)
    classes = {}
    for i, cls in enumerate(cfg.pattern_classes()):
        n = len(cfg.class_layers(cls))
        classes[cls] = _init_block_class(
            jax.random.fold_in(k_cls, i), cfg, n, cross=(cfg.arch == "encdec"))
    params["classes"] = classes
    if cfg.arch == "encdec":
        enc_cfg = ModelConfig(
            name=cfg.name + "-enc", n_layers=cfg.n_enc_layers,
            d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff,
            vocab_size=cfg.vocab_size, d_head=cfg.d_head, block="dense",
            qkv_bias=cfg.qkv_bias, rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps, act=cfg.act, dtype=cfg.dtype)
        params["encoder"] = {
            "classes": {"global": _init_block_class(
                k_enc, enc_cfg, cfg.n_enc_layers, cross=False)},
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, B: int, max_seq: int,
               enc_len: int = 0) -> Params:
    """Per-class decode caches. Local (sliding) classes get ring buffers of
    size ``cfg.window``; global classes get full-length buffers."""
    dt = jnp.dtype(cfg.dtype)
    H, KV, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    caches: Params = {"classes": {}}
    for cls in cfg.pattern_classes():
        n = len(cfg.class_layers(cls))
        c: Params = {}
        if cfg.block == "rwkv6":
            c["wkv"] = jnp.zeros((n, B, H, dh, dh), jnp.float32)
            c["st"] = jnp.zeros((n, B, d), dt)
            c["sc"] = jnp.zeros((n, B, d), dt)
        else:
            S = cfg.window if (cls == "local" and cfg.window > 0) else max_seq
            c["k"] = jnp.zeros((n, B, S, KV, dh), dt)
            c["v"] = jnp.zeros((n, B, S, KV, dh), dt)
            if cfg.block == "hybrid":
                d_in = H * dh
                c["ssm"] = jnp.zeros((n, B, H, dh, cfg.ssm_state), jnp.float32)
                c["conv"] = jnp.zeros((n, B, cfg.conv_width - 1, d_in), dt)
            if cfg.arch == "encdec" and enc_len > 0:
                # cross-KV cache; enc_len=0 -> cross K/V recomputed from
                # enc_states every step (RALM re-encoding path)
                c["xk"] = jnp.zeros((n, B, enc_len, KV, dh), dt)
                c["xv"] = jnp.zeros((n, B, enc_len, KV, dh), dt)
        caches["classes"][cls] = c
    return caches


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _proj_qkv(cfg, p, x):
    B, T, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    return q, k, v


def _self_attention(cfg, p, h, positions, mode, cache, window, slots=None,
                    kv_len=None, attn_spec=None):
    """Returns (attn_out [B,T,d], new_cache).

    ``slots`` enables the batched-slot (KV-pool) decode path: the cache
    carries ``P`` pooled rows, ``h`` carries a wave of ``W`` active rows,
    and row ``w`` reads/writes pool row ``slots[w]``. New K/V are written
    at O(W) scatter cost; attention reads gather each wave row's slot.

    ``kv_len`` (static int) crops the attention read of full-length
    caches to the wave's block-aligned valid prefix — the engine derives
    it from the wave's max position on the host, so a ragged wave stops
    paying for the pool's ``max_seq`` padding (slots past every row's
    position carry exactly-zero weight, so cropping them is a no-op on
    the math). Ring caches are window-sized already and are never
    cropped. ``attn_spec`` picks the decode-attention kernel flavor."""
    B, T, _ = h.shape
    q, k, v = _proj_qkv(cfg, p, h)
    pos1d = positions[0] if positions.ndim == 3 else positions
    q = positional_rotate(q, positions, cfg)
    k = positional_rotate(k, positions, cfg)
    ring = window > 0
    new_cache = cache
    if mode == "decode":
        kc, vc = update_cache(cache["k"], cache["v"], k, v,
                              pos1d[:, 0], ring=ring, slots=slots)
        # crop BEFORE the slot gather: the gather then copies only the
        # valid-prefix blocks, not the pool's full padded seq axis —
        # at long max_seq the full-S gather dominates the whole step
        kc_r, vc_r = kc, vc
        if kv_len is not None and not ring and kv_len < kc.shape[1]:
            kc_r, vc_r = kc[:, :kv_len], vc[:, :kv_len]
        k_att = kc_r if slots is None else kc_r[slots]
        v_att = vc_r if slots is None else vc_r[slots]
        out = decode_attention(q, k_att, v_att, pos1d[:, 0], window=window,
                               ring=ring, spec=attn_spec)
        new_cache = dict(cache, k=kc, v=vc)
    else:
        out = flash_attention(q, k, v, pos1d, pos1d, causal=True,
                              window=window)
        if mode == "prefill":
            # bulk build (positions are 0..T-1 in prefill) — no scatter
            kc, vc = prefill_cache(cache["k"], cache["v"], k, v, ring=ring)
            new_cache = dict(cache, k=kc, v=vc)
    out = out.reshape(B, T, cfg.n_heads * cfg.d_head)
    return out @ p["wo"], new_cache


def _cross_attention(cfg, p, h, enc_states, mode, cache, slots=None):
    """Decoder cross-attention over encoder states (RETRO/EncDec path)."""
    B, T, _ = h.shape
    hn = rms_norm(h, p["lnx"], cfg.norm_eps)
    q = (hn @ p["xwq"]).reshape(B, T, cfg.n_heads, cfg.d_head)
    if mode == "decode" and cache is not None and "xk" in cache:
        xk, xv = cache["xk"], cache["xv"]
        if slots is not None:           # pooled cross-KV: gather wave rows
            xk, xv = xk[slots], xv[slots]
    else:
        S = enc_states.shape[1]
        xk = (enc_states @ p["xwk"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
        xv = (enc_states @ p["xwv"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    S = xk.shape[1]
    qpos = jnp.zeros((B, T), jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    out = flash_attention(q, xk, xv, qpos, kpos, causal=False)
    out = out.reshape(B, T, cfg.n_heads * cfg.d_head)
    new_cache = cache
    if mode == "prefill" and cache is not None and "xk" in cache:
        new_cache = dict(cache, xk=xk.astype(cache["xk"].dtype),
                         xv=xv.astype(cache["xv"].dtype))
    return h + out @ p["xwo"], new_cache


def _ffn(cfg, p, x):
    if cfg.block == "moe":
        B, T, d = x.shape
        flat = x.reshape(B * T, d)
        out = moe_lib.moe_ffn(flat, p["router"], p["wg"], p["wu"], p["wd"],
                              cfg.top_k, act=cfg.act)
        return out.reshape(B, T, d).astype(x.dtype)
    return swiglu(x, p["wg"], p["wu"], p["wd"], cfg.act)


def apply_block(cfg: ModelConfig, p: Params, h: jnp.ndarray,
                positions: jnp.ndarray, mode: str, cache: Optional[Params],
                window: int, enc_states=None, slots=None, kv_len=None,
                attn_spec=None):
    """One layer. Returns (h, new_cache).

    With ``slots`` (batched-slot decode over a KV-cache pool) the cache
    leaves keep their pooled batch dim ``P``; recurrent states (SSM /
    conv / RWKV) are gathered to the wave rows for the step and scattered
    back, while attention K/V use the O(W)-write path in
    ``_self_attention``."""
    if cfg.block == "rwkv6":
        rp = ssm_lib.RWKV6Params(**{f: p[f] for f in
                                    ssm_lib.RWKV6Params._fields})
        if cache is not None:
            c = cache if slots is None else jax.tree.map(
                lambda a: a[slots], cache)
            st = ssm_lib.RWKVState(wkv=c["wkv"], shift_t=c["st"],
                                   shift_c=c["sc"])
        else:
            st = ssm_lib.rwkv6_init_state(h.shape[0], cfg.n_heads,
                                          cfg.d_head, cfg.d_model, h.dtype)
        y, wkv, sh_t = ssm_lib.rwkv6_time_mix_chunked(
            rp, rms_norm(h, p["ln1"], cfg.norm_eps), st, cfg.n_heads)
        h = h + y
        y2, sh_c = ssm_lib.rwkv6_channel_mix(
            rp, rms_norm(h, p["ln2"], cfg.norm_eps), st.shift_c)
        h = h + y2
        if cache is None:
            return h, None
        rows = dict(wkv=wkv, st=sh_t, sc=sh_c)
        if slots is None:
            return h, rows
        return h, {key: cache[key].at[slots].set(
            rows[key].astype(cache[key].dtype)) for key in rows}

    hn = rms_norm(h, p["ln1"], cfg.norm_eps)
    attn_out, new_cache = _self_attention(cfg, p, hn, positions, mode,
                                          cache if cache is not None else
                                          dict(k=None, v=None), window,
                                          slots=slots, kv_len=kv_len,
                                          attn_spec=attn_spec)
    if cache is None:
        new_cache = None
    if cfg.block == "hybrid":
        mp = jax.tree.map(lambda x: x, p["mamba"])
        sstate = None
        if cache is not None:
            sstate = ((cache["ssm"], cache["conv"]) if slots is None
                      else (cache["ssm"][slots], cache["conv"][slots]))
        ssm_out, (ssm_s, conv_s) = ssm_lib.mamba_scan(mp, hn, sstate)
        attn_out = 0.5 * (rms_norm(attn_out, p["ln_attn_out"], cfg.norm_eps)
                          + rms_norm(ssm_out, p["ln_ssm_out"], cfg.norm_eps))
        if cache is not None:
            if slots is None:
                new_cache = dict(new_cache, ssm=ssm_s,
                                 conv=conv_s.astype(cache["conv"].dtype))
            else:
                new_cache = dict(
                    new_cache,
                    ssm=cache["ssm"].at[slots].set(ssm_s),
                    conv=cache["conv"].at[slots].set(
                        conv_s.astype(cache["conv"].dtype)))
    h = h + attn_out
    if enc_states is not None and "xwq" in p:
        h, new_cache = _cross_attention(cfg, p, h, enc_states, mode,
                                        new_cache, slots=slots)
    h = h + _ffn(cfg, p, rms_norm(h, p["ln2"], cfg.norm_eps))
    return h, new_cache


# ---------------------------------------------------------------------------
# stack: scan over layer groups
# ---------------------------------------------------------------------------

def apply_stack(cfg: ModelConfig, classes_params: Params, h: jnp.ndarray,
                positions: jnp.ndarray, mode: str,
                caches: Optional[Params] = None, enc_states=None,
                remat: bool = False, slots=None, kv_len=None,
                attn_spec=None) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Apply all n_layers in order. Layers are grouped by the static
    ``layer_pattern`` cycle; a lax.scan over whole cycles keeps HLO small.

    ``slots`` (decode only): the caches are a KV-cache pool of ``P`` slot
    rows while ``h`` is one wave of ``W`` active rows — see
    ``decode_wave``. The scan carry stays pool-shaped throughout.
    ``kv_len``/``attn_spec`` are static decode-attention knobs (see
    ``_self_attention``) applied uniformly to every full-cache layer."""
    pattern = cfg.layer_pattern
    period = len(pattern)
    n_full, tail = divmod(cfg.n_layers, period)
    # per pattern-slot: (class name, #layers of that class per full cycle,
    #                    offset of this slot within the cycle's class layers)
    cnt = {c: pattern.count(c) for c in set(pattern)}
    off = []
    seen: Dict[str, int] = {}
    for c in pattern:
        off.append(seen.get(c, 0))
        seen[c] = seen.get(c, 0) + 1

    def layer_at(params_c, idx):
        return jax.tree.map(lambda a: a[idx], params_c)

    def apply_cycle(carry, g):
        h, caches_ = carry
        # pin activations to batch-over-dp: without this hint the SPMD
        # partitioner follows the FSDP weight sharding and replicates the
        # batch while splitting d — measured 34 TB/layer of activation
        # traffic for llama3-405b bwd (EXPERIMENTS.md §Perf iteration 5)
        h = constrain(h, "dp", None, None)
        for s, cls in enumerate(pattern):
            idx = g * cnt[cls] + off[s]
            p = layer_at(classes_params[cls], idx)
            window = cfg.window if cls == "local" else 0
            cache = (jax.tree.map(lambda a: a[idx], caches_["classes"][cls])
                     if caches_ is not None else None)
            h, new_cache = apply_block(cfg, p, h, positions, mode, cache,
                                       window, enc_states, slots=slots,
                                       kv_len=kv_len, attn_spec=attn_spec)
            if caches_ is not None:
                upd = jax.tree.map(
                    lambda a, nc: jax.lax.dynamic_update_index_in_dim(
                        a, nc.astype(a.dtype), idx, 0),
                    caches_["classes"][cls], new_cache)
                caches_ = dict(caches_,
                               classes=dict(caches_["classes"], **{cls: upd}))
        return (h, caches_), None

    body = jax.checkpoint(apply_cycle) if remat else apply_cycle
    if n_full > 0:
        (h, caches), _ = jax.lax.scan(body, (h, caches),
                                      jnp.arange(n_full))
    for t in range(tail):  # remainder layers, unrolled (< period of them)
        cls = pattern[t]
        idx = n_full * cnt[cls] + off[t]
        p = layer_at(classes_params[cls], idx)
        window = cfg.window if cls == "local" else 0
        cache = (jax.tree.map(lambda a: a[idx], caches["classes"][cls])
                 if caches is not None else None)
        h, new_cache = apply_block(cfg, p, h, positions, mode, cache, window,
                                   enc_states, slots=slots, kv_len=kv_len,
                                   attn_spec=attn_spec)
        if caches is not None:
            upd = jax.tree.map(
                lambda a, nc: jax.lax.dynamic_update_index_in_dim(
                    a, nc.astype(a.dtype), idx, 0),
                caches["classes"][cls], new_cache)
            caches = dict(caches, classes=dict(caches["classes"],
                                               **{cls: upd}))
    return h, caches


# ---------------------------------------------------------------------------
# full model entry points
# ---------------------------------------------------------------------------

def encode(params: Params, cfg: ModelConfig, enc_embeds: jnp.ndarray
           ) -> jnp.ndarray:
    """Encoder forward (bidirectional dense stack). enc_embeds [B, S, d]."""
    enc_cfg = ModelConfig(
        name=cfg.name + "-enc", n_layers=cfg.n_enc_layers,
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, d_ff=cfg.d_ff, vocab_size=cfg.vocab_size,
        d_head=cfg.d_head, block="dense", qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps, act=cfg.act,
        dtype=cfg.dtype)
    B, S, _ = enc_embeds.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = enc_embeds

    # bidirectional: reuse the stack in train mode but patch causality by
    # running attention non-causally — encoder blocks are dense/global only.
    classes = params["encoder"]["classes"]
    p_all = classes["global"]

    def body(h, idx):
        p = jax.tree.map(lambda a: a[idx], p_all)
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q, k, v = _proj_qkv(enc_cfg, p, hn)
        q = positional_rotate(q, pos, enc_cfg)
        k = positional_rotate(k, pos, enc_cfg)
        out = flash_attention(q, k, v, pos, pos, causal=False)
        out = out.reshape(B, S, cfg.n_heads * cfg.d_head)
        h = h + out @ p["wo"]
        h = h + swiglu(rms_norm(h, p["ln2"], cfg.norm_eps),
                       p["wg"], p["wu"], p["wd"], cfg.act)
        return h, None

    h, _ = jax.lax.scan(body, h, jnp.arange(cfg.n_enc_layers))
    return rms_norm(h, params["encoder"]["final_norm"], cfg.norm_eps)


def embed_tokens(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["embed"][tokens]


def unembed(params: Params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return h @ head


def forward(params: Params, cfg: ModelConfig,
            tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            mode: str = "train",
            caches: Optional[Params] = None,
            enc_states: Optional[jnp.ndarray] = None,
            remat: bool = False, return_hidden: bool = False, slots=None,
            kv_len=None, attn_spec=None):
    """Full forward. Provide `tokens` [B,T] or `embeds` [B,T,d] (modality
    stubs). Returns (logits [B,T,V], caches[, hidden])."""
    h = embed_tokens(params, tokens) if embeds is None else embeds
    h = constrain(h, "dp", None, None)
    B, T = h.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    h, caches = apply_stack(cfg, params["classes"], h, positions, mode,
                            caches, enc_states, remat=remat, slots=slots,
                            kv_len=kv_len, attn_spec=attn_spec)
    h = constrain(h, "dp", None, None)
    logits = unembed(params, cfg, h)
    if return_hidden:
        return logits, caches, h
    return logits, caches


def decode_step(params: Params, cfg: ModelConfig, caches: Params,
                token: jnp.ndarray, position: jnp.ndarray,
                enc_states: Optional[jnp.ndarray] = None,
                return_hidden: bool = False, attn_spec=None):
    """One serving step. token [B,1] int32; position [B] int32.
    Returns (logits [B,V], new caches[, hidden [B,d]]). The hidden state is
    the RALM retrieval query (paper step 1, kNN-LM style)."""
    B = token.shape[0]
    pos = position[:, None]
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    out = forward(params, cfg, tokens=token, positions=pos, mode="decode",
                  caches=caches, enc_states=enc_states,
                  return_hidden=return_hidden, attn_spec=attn_spec)
    if return_hidden:
        logits, caches, h = out
        return logits[:, 0], caches, h[:, 0]
    logits, caches = out
    return logits[:, 0], caches


def decode_wave(params: Params, cfg: ModelConfig, caches: Params,
                token: jnp.ndarray, slots: jnp.ndarray,
                position: jnp.ndarray,
                enc_states: Optional[jnp.ndarray] = None,
                return_hidden: bool = False, kv_len=None, attn_spec=None):
    """One serving step for a whole wave over a slotted KV-cache pool.

    ``caches`` hold ``P`` pooled slot rows (built with
    ``init_cache(cfg, P, ...)``); ``token`` [W,1] / ``slots`` [W] /
    ``position`` [W] describe the wave: row ``w`` advances the sequence
    living in pool slot ``slots[w]`` at absolute position ``position[w]``.
    ``enc_states`` (encdec) is already gathered to wave rows [W, S, d].

    ``kv_len`` (static) crops every full-cache attention read to the
    wave's block-aligned valid prefix; ``attn_spec`` selects the
    decode-attention kernel (grouped ref / streaming Pallas / legacy
    einsum oracle) — see ``models/attention.decode_attention``.

    Returns (logits [W,V], new pool caches[, hidden [W,d]]). One call =
    one LM dispatch for every active sequence, regardless of how many
    requests the wave spans — the ChamLM analogue of the retrieval
    service's coalesced batch (paper §5 batched GPU pool)."""
    W = token.shape[0]
    pos = position[:, None]
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, W, 1))
    out = forward(params, cfg, tokens=token, positions=pos, mode="decode",
                  caches=caches, enc_states=enc_states, slots=slots,
                  return_hidden=return_hidden, kv_len=kv_len,
                  attn_spec=attn_spec)
    if return_hidden:
        logits, caches, h = out
        return logits[:, 0], caches, h[:, 0]
    logits, caches = out
    return logits[:, 0], caches


def lm_loss(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            remat: bool = True) -> jnp.ndarray:
    """Next-token cross-entropy (mean over tokens; labels < 0 = ignore).

    batch keys: "tokens" [B,T] or "embeds" [B,T,d] (modality stubs);
    "labels" [B,T]; optional "positions" ([B,T] or [3,B,T] for mrope);
    optional "enc_embeds" [B,S,d] (encdec: retrieved-chunk embeddings)."""
    enc_states = (encode(params, cfg, batch["enc_embeds"])
                  if "enc_embeds" in batch else None)
    logits, _ = forward(params, cfg, tokens=batch.get("tokens"),
                        embeds=batch.get("embeds"),
                        positions=batch.get("positions"), mode="train",
                        enc_states=enc_states, remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
