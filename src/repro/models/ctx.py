"""Activation-sharding context: lets layer code apply
``with_sharding_constraint`` hints without threading mesh axis names through
every call signature.

The step builders (launch/steps.py) set the context; layer code calls
``constrain(x, *dims)`` with logical dim tags:
  "dp"     -> the compound data-parallel axes ("pod","data")
  "model"  -> the tensor-parallel axis
  None     -> unsharded
Outside any context (unit tests, single-device runs) it is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_CTX: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "shard_ctx", default=None)


@contextlib.contextmanager
def activation_sharding(dp_axes: Tuple[str, ...], model_axis: str = "model",
                        kv_batch="dp", kv_seq="model"):
    """kv_batch / kv_seq: logical tags for the KV-cache batch and sequence
    dims (long_500k flips them: batch 1 cannot shard, sequence takes all
    axes)."""
    tok = _CTX.set(dict(dp=tuple(dp_axes), model=model_axis,
                        kv_batch=kv_batch, kv_seq=kv_seq))
    try:
        yield
    finally:
        _CTX.reset(tok)


def kv_tags():
    ctx = _CTX.get()
    if ctx is None:
        return None
    return ctx["kv_batch"], ctx["kv_seq"]


def constrain(x, *dims):
    """dims: one tag per array dim ("dp" | "model" | None | ("dp","model"))."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    entries = []
    for d in dims:
        if d is None:
            entries.append(None)
        elif d == "dp":
            entries.append(ctx["dp"] if len(ctx["dp"]) > 1 else
                           (ctx["dp"][0] if ctx["dp"] else None))
        elif d == "model":
            entries.append(ctx["model"])
        elif isinstance(d, tuple):
            flat = []
            for e in d:
                if e == "dp":
                    flat.extend(ctx["dp"])
                elif e == "model":
                    flat.append(ctx["model"])
            entries.append(tuple(flat))
        else:
            entries.append(d)
    # divisibility guard: skip constraint if any dim cannot divide
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except Exception:
        return x
