"""State-space sequence mixers: a Mamba-style selective-SSM head (the parallel
branch of Hymba blocks) and the RWKV-6 "Finch" time/channel mix.

Both are written as (a) a parallel form scanning time with ``lax.scan``
(training/prefill) and (b) a single-step form for O(1)-state decode — the
property that makes these archs the designated ``long_500k`` cells
(DESIGN.md §5).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Mamba-style selective SSM head (Hymba's parallel branch)
# ---------------------------------------------------------------------------

class MambaParams(NamedTuple):
    w_in: jnp.ndarray       # [d_model, 2*d_in]   (x and gate z)
    conv_w: jnp.ndarray     # [conv_width, d_in]  depthwise causal conv
    w_bcdt: jnp.ndarray     # [d_in, 2*ds + H]    B, C, dt projections
    a_log: jnp.ndarray      # [H, ds]             -exp(a_log) = A diagonal
    dt_bias: jnp.ndarray    # [H]
    d_skip: jnp.ndarray     # [H]
    w_out: jnp.ndarray      # [d_in, d_model]


def _ssm_step(h, inputs, a):
    """h [B,H,dh,ds]; one selective-SSM step (diag A, shared B/C per head)."""
    xt, bt, ct, dt = inputs     # [B,H,dh], [B,ds], [B,ds], [B,H]
    da = jnp.exp(dt[..., None] * a[None])                    # [B,H,ds]
    h = h * da[:, :, None, :] + (dt[..., None, None]
                                 * xt[..., None]
                                 * bt[:, None, None, :])     # [B,H,dh,ds]
    yt = jnp.einsum("bhds,bs->bhd", h, ct)
    return h, yt


def mamba_scan(p: MambaParams, x: jnp.ndarray, state=None
               ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """x [B,T,d_model] -> (y [B,T,d_model], (ssm_state, conv_state)).

    state: optional (ssm [B,H,dh,ds], conv [B,conv_w-1,d_in]) to resume."""
    B, T, _ = x.shape
    cw, d_in = p.conv_w.shape
    H, ds = p.a_log.shape
    dh = d_in // H
    xz = x @ p.w_in
    xi, z = jnp.split(xz, 2, axis=-1)                        # [B,T,d_in] each

    conv_prev = (jnp.zeros((B, cw - 1, d_in), x.dtype)
                 if state is None else state[1])
    xi_pad = jnp.concatenate([conv_prev, xi], axis=1)
    # depthwise causal conv
    xc = sum(xi_pad[:, i:i + T] * p.conv_w[i][None, None]
             for i in range(cw))
    xc = jax.nn.silu(xc)

    bcdt = xc @ p.w_bcdt
    b_t = bcdt[..., :ds]
    c_t = bcdt[..., ds:2 * ds]
    dt = jax.nn.softplus(bcdt[..., 2 * ds:] + p.dt_bias)     # [B,T,H]
    a = -jnp.exp(p.a_log.astype(jnp.float32))                # [H,ds]

    xh = xc.reshape(B, T, H, dh)
    h0 = (jnp.zeros((B, H, dh, ds), jnp.float32)
          if state is None else state[0])

    def step(h, ins):
        return _ssm_step(h, ins, a)

    hT, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
         jnp.moveaxis(b_t.astype(jnp.float32), 1, 0),
         jnp.moveaxis(c_t.astype(jnp.float32), 1, 0),
         jnp.moveaxis(dt.astype(jnp.float32), 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, d_in)           # [B,T,d_in]
    y = y + xc * p.d_skip.repeat(dh)[None, None]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p.w_out
    conv_state = xi_pad[:, T:] if cw > 1 else conv_prev
    return y, (hT, conv_state)


def mamba_decode(p: MambaParams, x: jnp.ndarray, state
                 ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Single-token step: x [B,1,d_model], state from mamba_scan."""
    return mamba_scan(p, x, state)


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) — data-dependent decay linear recurrence
# ---------------------------------------------------------------------------

class RWKV6Params(NamedTuple):
    # time mix
    mu_r: jnp.ndarray       # [d]   token-shift mix coefficients
    mu_k: jnp.ndarray       # [d]
    mu_v: jnp.ndarray       # [d]
    mu_g: jnp.ndarray       # [d]
    mu_w: jnp.ndarray       # [d]
    w_r: jnp.ndarray        # [d, H*dh]
    w_k: jnp.ndarray        # [d, H*dh]
    w_v: jnp.ndarray        # [d, H*dh]
    w_g: jnp.ndarray        # [d, H*dh]
    w_o: jnp.ndarray        # [H*dh, d]
    # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
    w0: jnp.ndarray         # [H*dh]
    w_lora_a: jnp.ndarray   # [d, 64]
    w_lora_b: jnp.ndarray   # [64, H*dh]
    bonus_u: jnp.ndarray    # [H, dh]
    ln_x: jnp.ndarray       # [H*dh] per-head group-norm scale
    # channel mix
    mu_ck: jnp.ndarray      # [d]
    mu_cr: jnp.ndarray      # [d]
    w_ck: jnp.ndarray       # [d, f]
    w_cv: jnp.ndarray       # [f, d]
    w_cr: jnp.ndarray       # [d, d]


class RWKVState(NamedTuple):
    wkv: jnp.ndarray        # [B, H, dh, dh] f32
    shift_t: jnp.ndarray    # [B, d] last token (time-mix shift)
    shift_c: jnp.ndarray    # [B, d] last token (channel-mix shift)


def rwkv6_init_state(B: int, H: int, dh: int, d: int, dtype) -> RWKVState:
    return RWKVState(
        wkv=jnp.zeros((B, H, dh, dh), jnp.float32),
        shift_t=jnp.zeros((B, d), dtype),
        shift_c=jnp.zeros((B, d), dtype),
    )


def _group_norm(y: jnp.ndarray, scale: jnp.ndarray, H: int) -> jnp.ndarray:
    """Per-head LayerNorm of the wkv readout (RWKV's ln_x)."""
    B, T, D = y.shape
    dh = D // H
    yh = y.reshape(B, T, H, dh).astype(jnp.float32)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 1e-5)
    return (yh.reshape(B, T, D) * scale).astype(y.dtype)


def rwkv6_time_mix(p: RWKV6Params, x: jnp.ndarray, state: RWKVState, H: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x [B,T,d] -> (y [B,T,d], wkv_state', shift'). Works for any T (T=1 is
    the decode step)."""
    B, T, d = x.shape
    D = p.w_r.shape[-1]
    dh = D // H
    x_prev = jnp.concatenate([state.shift_t[:, None], x[:, :-1]], axis=1)
    def mix(mu):
        return x + (x_prev - x) * mu[None, None]
    r = (mix(p.mu_r) @ p.w_r).reshape(B, T, H, dh)
    k = (mix(p.mu_k) @ p.w_k).reshape(B, T, H, dh)
    v = (mix(p.mu_v) @ p.w_v).reshape(B, T, H, dh)
    g = jax.nn.silu(mix(p.mu_g) @ p.w_g)                     # [B,T,D]
    wx = mix(p.mu_w)
    w_log = p.w0[None, None] + jnp.tanh(wx @ p.w_lora_a) @ p.w_lora_b
    # decay clamp w >= e^-8 (~3e-4/token — beyond any practical decay):
    # keeps the chunked form's within-chunk decay products inside f32 range
    w = jnp.exp(-jnp.clip(jnp.exp(w_log.astype(jnp.float32)), 0.0, 8.0))
    w = w.reshape(B, T, H, dh)

    u = p.bonus_u                                             # [H, dh]

    def step(s, ins):
        rt, kt, vt, wt = ins                                 # [B,H,dh] each
        kv = kt[..., :, None] * vt[..., None, :]             # [B,H,dh,dh]
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, yt

    sT, ys = jax.lax.scan(
        step, state.wkv,
        (jnp.moveaxis(r.astype(jnp.float32), 1, 0),
         jnp.moveaxis(k.astype(jnp.float32), 1, 0),
         jnp.moveaxis(v.astype(jnp.float32), 1, 0),
         jnp.moveaxis(w, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, D).astype(x.dtype)
    y = _group_norm(y, p.ln_x, H)
    y = (y * g.astype(y.dtype)) @ p.w_o
    return y, sT, x[:, -1]


def rwkv6_time_mix_chunked(p: RWKV6Params, x: jnp.ndarray, state: RWKVState,
                           H: int, chunk: int = 32
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunked-parallel RWKV-6 (GLA-style): identical math to
    ``rwkv6_time_mix`` but the per-token state recurrence is replaced by
    per-chunk matmuls, so the [H, dh, dh] state reads/writes HBM once per
    ``chunk`` tokens instead of every token (the measured 1.3e5 s/step
    memory wall of the naive scan; EXPERIMENTS.md §Perf iteration 8).

    Within a chunk of decay products a_j = prod_{l<j} w_l:
      y_j   = (r_j*a_j) @ S_0  +  sum_{i<j} ((r_j*a_j/(a_i w_i))·k_i) v_i
              + u·(r_j k_j) v_j
      S_out = D*S_0 + sum_i (D/(a_i w_i)) k_i (x) v_i,   D = prod_l w_l
    Computed in f32; chunk length bounds the decay-product dynamic range.
    """
    B, T, d = x.shape
    D = p.w_r.shape[-1]
    dh = D // H
    if T % chunk != 0 or T <= chunk:
        return rwkv6_time_mix(p, x, state, H)
    x_prev = jnp.concatenate([state.shift_t[:, None], x[:, :-1]], axis=1)
    def mix(mu):
        return x + (x_prev - x) * mu[None, None]
    r = (mix(p.mu_r) @ p.w_r).reshape(B, T, H, dh).astype(jnp.float32)
    k = (mix(p.mu_k) @ p.w_k).reshape(B, T, H, dh).astype(jnp.float32)
    v = (mix(p.mu_v) @ p.w_v).reshape(B, T, H, dh).astype(jnp.float32)
    g = jax.nn.silu(mix(p.mu_g) @ p.w_g)
    wx = mix(p.mu_w)
    w_log = p.w0[None, None] + jnp.tanh(wx @ p.w_lora_a) @ p.w_lora_b
    w = jnp.exp(-jnp.clip(jnp.exp(w_log.astype(jnp.float32)), 0.0, 8.0)
                ).reshape(B, T, H, dh)
    u = p.bonus_u.astype(jnp.float32)                        # [H, dh]

    C = chunk
    n = T // C
    rc = r.reshape(B, n, C, H, dh)
    kc = k.reshape(B, n, C, H, dh)
    vc = v.reshape(B, n, C, H, dh)
    wc = w.reshape(B, n, C, H, dh)
    # log-decays: L_excl[j] = sum_{l<j} logw_l; pairwise factors are
    # exp(L_j - L_i - logw_i). Normalizing both sides by the mid-chunk
    # cumlog keeps each factor within f32 even for fast-decay channels
    # (raw products underflow at w^C; measured 1.0 abs error unnormalized).
    logw = jnp.log(jnp.maximum(wc, 1e-38))
    cs = jnp.cumsum(logw, axis=2)                            # inclusive
    L_excl = cs - logw                                       # [B,n,C,H,dh]
    L_mid = cs[:, :, C // 2][:, :, None]                     # per-chunk ref
    Dk = jnp.exp(cs[:, :, -1])                               # [B,n,H,dh] <=1
    r_t = rc * jnp.exp(L_excl - L_mid)                       # intra r~_j
    k_t = kc * jnp.exp(L_mid - L_excl - logw)                # intra κ_i
    r_a = rc * jnp.exp(L_excl)                               # inter (<=1)
    k_s = kc * jnp.exp(cs[:, :, -1][:, :, None] - L_excl - logw)  # state(<=1)

    # intra-chunk strict-lower attention
    scores = jnp.einsum("bnchd,bnshd->bnhcs", r_t, k_t)      # [B,n,H,C,C]
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    y_intra = jnp.einsum("bnhcs,bnshd->bnchd", scores, vc)
    # diagonal (bonus-u) term
    diag = jnp.einsum("bnchd,hd,bnchd->bnch", rc, u, kc)     # r·u·k per tok
    y_intra = y_intra + diag[..., None] * vc

    # inter-chunk: scan over chunks carrying the [B,H,dh,dh] state
    def chunk_step(S, ins):
        r_aj, k_sj, vj, Dj = ins
        y_inter = jnp.einsum("bchk,bhkv->bchv", r_aj, S)
        S = Dj[..., None] * S + jnp.einsum("bchk,bchv->bhkv", k_sj, vj)
        return S, y_inter

    S_fin, y_inter = jax.lax.scan(
        chunk_step, state.wkv,
        (jnp.moveaxis(r_a, 1, 0), jnp.moveaxis(k_s, 1, 0),
         jnp.moveaxis(vc, 1, 0), jnp.moveaxis(Dk, 1, 0)))
    y = (y_intra + jnp.moveaxis(y_inter, 0, 1)).reshape(B, T, D)
    y = _group_norm(y.astype(x.dtype), p.ln_x, H)
    y = (y * g.astype(y.dtype)) @ p.w_o
    return y, S_fin, x[:, -1]


def rwkv6_channel_mix(p: RWKV6Params, x: jnp.ndarray, shift: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x_prev = jnp.concatenate([shift[:, None], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p.mu_ck[None, None]
    xr = x + (x_prev - x) * p.mu_cr[None, None]
    kk = jnp.square(jax.nn.relu(xk @ p.w_ck))
    out = jax.nn.sigmoid(xr @ p.w_cr) * (kk @ p.w_cv)
    return out, x[:, -1]
