"""Sharded checkpoint/restore with async snapshots and elastic resharding.

Layout: one directory per step containing a ``manifest.json`` (flat key ->
shape/dtype) and one ``.npy`` per leaf. Writes go to a temp dir + atomic
rename, so a crash mid-save never corrupts the latest valid checkpoint —
restore always picks the newest *complete* step directory (the paper-scale
requirement: a 1000-node job must survive any single write being killed).

Elastic resharding: leaves are saved as full (unsharded) arrays; restore
device_puts them under the *current* mesh's NamedShardings, so a job
checkpointed on N devices resumes on M devices unchanged. For 405B-scale
states a real deployment would write per-shard files; the format keeps a
``shard_id`` field for that extension.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names incl. ml_dtypes (bfloat16 saves as raw void)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = leaf
    return flat


def save(path: str | pathlib.Path, step: int, tree: Any) -> pathlib.Path:
    """Synchronous atomic checkpoint of a pytree."""
    root = pathlib.Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    manifest = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        logical = str(arr.dtype)
        if arr.dtype.kind not in "biufc":   # ml_dtypes (bf16 etc): raw bytes
            logical = arr.dtype.name
            arr = arr.view(np.uint8).reshape(arr.shape + (-1,)) \
                if arr.ndim else arr.view(np.uint8)
            arr = np.ascontiguousarray(arr)
        np.save(tmp / fname, arr)
        manifest[key] = dict(file=fname, shape=list(flat[key].shape),
                             dtype=logical, shard_id=0)
    (tmp / "manifest.json").write_text(json.dumps(
        dict(step=step, leaves=manifest), indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(path: str | pathlib.Path) -> Optional[int]:
    root = pathlib.Path(path)
    if not root.exists():
        return None
    steps = []
    for d in root.iterdir():
        if d.name.startswith("step_") and (d / "manifest.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(path: str | pathlib.Path, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings for elastic placement on the current mesh."""
    root = pathlib.Path(path)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())["leaves"]
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, like_leaf in flat_like.items():
        meta = manifest[key]
        arr = np.load(d / meta["file"])
        dt = _np_dtype(meta["dtype"])
        if str(arr.dtype) != meta["dtype"]:   # raw-byte ml_dtypes payload
            arr = arr.view(dt).reshape(tuple(meta["shape"]))
        want = tuple(getattr(like_leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
        sh = flat_sh.get(key)
        out[key] = (jax.device_put(arr, sh) if sh is not None
                    else jax.numpy.asarray(arr))
    # unflatten back into the structure of `like`
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    new_leaves = [out[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


class AsyncCheckpointer:
    """Overlap checkpoint I/O with training: snapshot to host sync (cheap),
    write in a background thread. ``wait()`` before exit/next save."""

    def __init__(self, path: str | pathlib.Path, keep: int = 3):
        self.path = pathlib.Path(path)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def _write():
            save(self.path, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(d for d in self.path.iterdir()
                       if d.name.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(d, ignore_errors=True)
