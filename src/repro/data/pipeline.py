"""Token data pipeline: deterministic, stateless, resumable.

Design goals (fault tolerance, DESIGN.md §2.6): the batch for step `s` is a
pure function of (seed, s) — no iterator state to checkpoint; a restarted
job that resumes at step s sees exactly the batches it would have seen.
Both sources implement that contract:

  * ``SyntheticTokens`` — hash-derived tokens, zero I/O (smoke tests,
    dry-run-adjacent examples).
  * ``MemmapTokens``    — flat binary token shards + np.memmap, the
    production path (pack once, stream forever).

Host sharding: every data-parallel host calls ``host_batch`` with its own
(host_id, num_hosts) and gets its slice; slices are disjoint and cover the
global batch.
"""
from __future__ import annotations

import dataclasses
import pathlib
import threading
import queue as queue_mod
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 1234


class SyntheticTokens:
    """Deterministic pseudo-corpus. Token stream = philox(seed, position);
    sequences are consecutive windows, batches are strided across the stream
    so every (step, row) maps to a unique document position."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(key=c.seed, counter=step))
        toks = rng.integers(0, c.vocab_size,
                            size=(c.global_batch, c.seq_len + 1),
                            dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch(self, step: int, host_id: int = 0, num_hosts: int = 1
                   ) -> Dict[str, np.ndarray]:
        b = self.batch(step)
        per = self.cfg.global_batch // num_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in b.items()}


class MemmapTokens:
    """Flat int32 token file; batch rows are deterministic strided windows."""

    def __init__(self, path: str | pathlib.Path, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len
        if self.n_windows < cfg.global_batch:
            raise ValueError("corpus too small for one global batch")

    @staticmethod
    def write_corpus(path: str | pathlib.Path, tokens: np.ndarray) -> None:
        np.asarray(tokens, np.int32).tofile(path)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        # deterministic shuffled window order per epoch
        epoch, within = divmod(step * c.global_batch, self.n_windows)
        rng = np.random.Generator(np.random.Philox(key=c.seed, counter=epoch))
        perm = rng.permutation(self.n_windows)
        idx = perm[(within + np.arange(c.global_batch)) % self.n_windows]
        rows = np.stack([self.data[i * c.seq_len: i * c.seq_len + c.seq_len + 1]
                         for i in idx])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    def host_batch(self, step: int, host_id: int = 0, num_hosts: int = 1
                   ) -> Dict[str, np.ndarray]:
        b = self.batch(step)
        per = self.cfg.global_batch // num_hosts
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in b.items()}


class Prefetcher:
    """Background-thread prefetch of the deterministic stream (overlaps host
    data work with device steps; depth 2 is enough since batches are cheap)."""

    def __init__(self, source, start_step: int, depth: int = 2,
                 host_id: int = 0, num_hosts: int = 1):
        self.source = source
        self.q: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self.host_id, self.num_hosts = host_id, num_hosts
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            batch = self.source.host_batch(s, self.host_id, self.num_hosts)
            while not self._stop.is_set():
                try:
                    self.q.put((s, batch), timeout=0.1)
                    break
                except queue_mod.Full:
                    continue
            s += 1

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
