"""Fault-tolerant retrieval dispatch under deterministic chaos.

The contract under test (docs/retrieval.md "Failure modes and
recovery"): the serving stack must survive a misbehaving vector-search
tier — replica crashes fail over, hangs hedge to a sibling after the
latency-quantile delay, transient errors retry, and a whole fault
domain going dark degrades to *exact top-k over the survivors* instead
of wedging the decode loop. All of it must be provably inert on the
happy path: with the FT layer armed but no faults injected, results
are bit-identical to the legacy direct dispatch and every fault
counter is zero.

Faults cannot happen for real in CI, so they are *injected* at the
scan boundary by a seeded ``FaultPlan`` (repro.retrieval.chaos) whose
outcomes are a pure function of (plan, flush, domain, replica,
attempt) — the seed matrix below (hang / crash / slow x local / router
pipeline) is the CI chaos-smoke job.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tf
from repro.retrieval import (ChaosInjector, FailoverConfig, FaultPlan,
                             FaultSpec, ReplicaGroup, RetrievalService,
                             ScanHang, ServiceConfig, crash_plan)
from repro.retrieval.replica import (EJECTED, HEALTHY, PROBATION,
                                     SUSPECT)
from repro.serve import (DatastoreBuilder, EngineConfig, RagConfig,
                         RalmEngine, RalmRequest)
from repro.serve.gateway import DegradeConfig, DegradePolicy


@pytest.fixture(scope="module")
def tiny_ralm():
    """Tiny decoder LM + 2-shard datastore over the deterministic-bigram
    corpus (token t -> (3t+1) mod 64) — two shards = two retrieval
    fault domains, the smallest world where partial results differ
    from total loss."""
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    start = rng.integers(0, 64, size=(64,))
    corpus = [start]
    for _ in range(31):
        corpus.append((3 * corpus[-1] + 1) % 64)
    corpus = np.stack(corpus, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8, list_cap=512,
                          num_shards=2).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    return cfg, params, corpus, ds, ccfg, rag


def _queries(ds, n=4, seed=3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, ds.index_cfg.dim))
                       .astype(np.float32))


def _svc(ds, ccfg, failover=None, chaos=None, **cfg_kw):
    svc = RetrievalService.local(
        ds.params, ds.shards, ccfg,
        ServiceConfig(measure=False, failover=failover, **cfg_kw))
    if chaos is not None:
        svc.install_chaos(chaos)
    return svc


def _search(svc, q):
    h = svc.submit(q)
    svc.flush()
    d, i = h.result()
    return np.asarray(d), np.asarray(i), h


#: FailoverConfig for failover tests: the long probation keeps a
#: faulted replica benched, so the surviving one serves deterministically
_NO_COMEBACK = FailoverConfig(replicas=2, probation_s=999.0)


# ---------------------------------------------------------------------------
# replica health state machine (fake clock, no service)
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_health_state_machine_walk():
    """healthy -> suspect -> ejected -> (cool-off) probation ->
    recovered; a probation failure re-ejects; a crash ejects from any
    state instantly."""
    clk = _Clock()
    trans = []
    g = ReplicaGroup(1, FailoverConfig(
        replicas=2, suspect_after=1, eject_after=3, probation_s=1.0,
        probation_successes=2), clock=clk,
        on_transition=lambda s, r, old, new: trans.append((old, new)))
    h = g.health[(0, 0)]
    g.report(0, 0, "timeout")
    assert h.state == SUSPECT
    g.report(0, 0, "timeout")
    g.report(0, 0, "timeout")
    assert h.state == EJECTED and g.ejections == 1
    assert g.pick(0, exclude={1}) is None      # cool-off not served
    clk.t = 1.5
    assert g.pick(0, exclude={1}) == 0         # probe resumes traffic
    assert h.state == PROBATION
    g.report(0, 0, "ok")
    assert h.state == PROBATION                # needs 2 successes
    g.report(0, 0, "ok")
    assert h.state == HEALTHY and g.recoveries == 1
    # probation failure: straight back to ejected
    g.report(0, 0, "error")
    g.report(0, 0, "error")
    g.report(0, 0, "error")
    clk.t = 3.0
    g.pick(0, exclude={1})
    g.report(0, 0, "error")
    assert h.state == EJECTED
    # crash ejects instantly, from any state
    h2 = g.health[(0, 1)]
    g.report(0, 1, "crash")
    assert h2.state == EJECTED
    assert (HEALTHY, SUSPECT) in trans and (SUSPECT, EJECTED) in trans
    assert (PROBATION, HEALTHY) in trans


def test_pick_routes_and_probes():
    clk = _Clock()
    g = ReplicaGroup(1, FailoverConfig(replicas=2, probation_s=1.0,
                                       probe_every=4), clock=clk)
    # healthy round-robin alternates (first pick is replicas[1])
    assert [g.pick(0) for _ in range(4)] == [1, 0, 1, 0]
    # an ejected replica is excluded until its cool-off is served,
    # then the probe cadence diverts traffic to it
    g.report(0, 0, "crash")
    assert all(g.pick(0) == 1 for _ in range(6))
    clk.t = 2.0
    picks = [g.pick(0) for _ in range(8)]
    assert 0 in picks and g.health[(0, 0)].state == PROBATION
    # suspects are also revisited on the cadence — a single timeout
    # must not bench a replica forever while its sibling is healthy
    g2 = ReplicaGroup(1, FailoverConfig(replicas=2, probe_every=2),
                      clock=clk)
    g2.report(0, 0, "timeout")
    assert g2.health[(0, 0)].state == SUSPECT
    assert 0 in [g2.pick(0) for _ in range(4)]


def test_hedge_delay_and_validation():
    g = ReplicaGroup(2, FailoverConfig(replicas=2, hedge_floor_s=0.005,
                                       hedge_quantile=0.5))
    assert g.hedge_delay_s() == 0.005          # cold reservoir: floor
    for _ in range(64):
        g.latency.add(0.02)
    assert g.hedge_delay_s() == pytest.approx(0.02, rel=0.05)
    with pytest.raises(ValueError, match="replica"):
        ReplicaGroup(0, FailoverConfig())
    with pytest.raises(ValueError, match="unknown outcome"):
        g.report(0, 0, "meh")


# ---------------------------------------------------------------------------
# chaos plans: determinism + JSON round-trip
# ---------------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(kind="meteor")
    with pytest.raises(ValueError, match="p must"):
        FaultSpec(kind="hang", p=1.5)


def test_chaos_outcomes_deterministic():
    plan = FaultPlan.make(
        [FaultSpec(kind="crash", shard=0, start_flush=2, stop_flush=4),
         FaultSpec(kind="slow", p=0.5, slow_s=0.01)], seed=11)
    a, b = ChaosInjector(plan), ChaosInjector(plan)
    grid = [(f, s, r, t) for f in range(8) for s in range(2)
            for r in range(2) for t in range(2)]
    out_a = [a.outcome(*g) for g in grid]
    out_b = [b.outcome(*g) for g in grid]
    assert out_a == out_b                      # pure function of the plan
    assert a.counts() == b.counts()
    # rule order: the narrow crash rule wins inside its window
    assert a.outcome(2, 0, 0, 0).kind == "crash"
    assert a.outcome(4, 0, 0, 0) is None or \
        a.outcome(4, 0, 0, 0).kind == "slow"   # window closed
    # p=0.5 really splits, and the attempt index is part of the key
    hits = [a.outcome(f, 1, 0, 0) for f in range(64)]
    frac = sum(o is not None for o in hits) / 64
    assert 0.2 < frac < 0.8
    assert any((a.outcome(f, 1, 0, 0) is None) !=
               (a.outcome(f, 1, 0, 1) is None) for f in range(64))


def test_fault_plan_json_roundtrip(tmp_path):
    plan = FaultPlan.make(
        [FaultSpec(kind="hang", shard=1, replica=0, start_flush=3),
         FaultSpec(kind="error", p=0.25)], seed=42, realtime=True)
    path = str(tmp_path / "plan.json")
    plan.save(path)
    assert FaultPlan.load(path) == plan
    obj = json.loads(plan.to_json())           # the --chaos surface
    assert obj["seed"] == 42 and len(obj["faults"]) == 2


# ---------------------------------------------------------------------------
# service-level dispatch: inertness, failover, hedging, partials
# ---------------------------------------------------------------------------

def test_ft_layer_inert_without_faults(tiny_ralm):
    """FT armed but fault-free == legacy direct dispatch, bit for bit,
    with every fault counter zero."""
    _, _, _, ds, ccfg, _ = tiny_ralm
    q = _queries(ds)
    d0, i0, _ = _search(_svc(ds, ccfg), q)
    svc = _svc(ds, ccfg, failover=FailoverConfig(replicas=2))
    d1, i1, h = _search(svc, q)
    np.testing.assert_array_equal(d0, d1)
    np.testing.assert_array_equal(i0, i1)
    assert not h.partial and h.live_fraction == 1.0
    st = svc.stats
    assert (st.ft_timeouts == st.ft_hedges == st.ft_retries ==
            st.ft_crashes == st.ft_ejections == st.ft_recoveries ==
            st.ft_partial_flushes == st.ft_partial_rows == 0)


@pytest.mark.parametrize("kind,counter", [
    ("crash", "ft_crashes"), ("hang", "ft_hedges"),
    ("error", "ft_retries")])
def test_replica_fault_fails_over_full_quality(tiny_ralm, kind, counter):
    """One replica of every domain faults on the first pick (RR starts
    at replica 1): the dispatch fails over / hedges / retries to the
    sibling and serves bit-identical full-quality results."""
    _, _, _, ds, ccfg, _ = tiny_ralm
    q = _queries(ds)
    d0, i0, _ = _search(_svc(ds, ccfg), q)
    plan = FaultPlan.make([FaultSpec(kind=kind, replica=1)])
    svc = _svc(ds, ccfg, failover=_NO_COMEBACK, chaos=plan)
    d1, i1, h = _search(svc, q)
    np.testing.assert_array_equal(d0, d1)
    np.testing.assert_array_equal(i0, i1)
    assert not h.partial
    assert getattr(svc.stats, counter) >= 1
    assert svc.stats.ft_partial_flushes == 0


def test_hang_keeps_hedging_until_ejection(tiny_ralm):
    """A persistently hanging replica is not benched-forever in
    SUSPECT: the probe cadence keeps revisiting it, each visit hedges,
    and the failure streak reaches ejection."""
    _, _, _, ds, ccfg, _ = tiny_ralm
    plan = FaultPlan.make([FaultSpec(kind="hang", replica=0)])
    svc = _svc(ds, ccfg, failover=FailoverConfig(
        replicas=2, probation_s=999.0, probe_every=2), chaos=plan)
    q = _queries(ds, n=2)
    for _ in range(16):
        _search(svc, q)
    st = svc.stats
    assert st.ft_hedges >= 4 and st.ft_timeouts >= 4
    assert st.ft_ejections == 2                # one per domain
    assert svc.replicas.state_counts()[EJECTED] == 2
    assert st.ft_partial_flushes == 0          # sibling always covered


def test_shard_down_serves_exact_prefix_over_survivors(tiny_ralm):
    """Both replicas of domain 0 crash: the flush serves the truncated
    top-k' of the surviving shard — the first k'(S) columns equal the
    exact single-shard search, the tail is the (+inf, -1) padding
    sentinel — and the partial accounting fires."""
    _, _, _, ds, ccfg, _ = tiny_ralm
    q = _queries(ds)
    svc = _svc(ds, ccfg, failover=_NO_COMEBACK,
               chaos=crash_plan(shard=0, replica=-1))
    d1, i1, h = _search(svc, q)
    assert h.partial and h.live_fraction == 0.5
    dr, ir, _ = _search(RetrievalService.local(
        ds.params, [ds.shards[1]], ccfg, ServiceConfig(measure=False)), q)
    kk = ccfg.k_prime(2)                       # survivor contributes k'
    np.testing.assert_array_equal(i1[:, :kk], ir[:, :kk])
    np.testing.assert_allclose(d1[:, :kk], dr[:, :kk], rtol=1e-5)
    assert (i1[:, kk:] == -1).all() and np.isinf(d1[:, kk:]).all()
    st = svc.stats
    assert st.ft_crashes == 2 and st.ft_ejections == 2
    assert st.ft_partial_flushes == 1
    assert st.ft_partial_rows == q.shape[0]


def test_total_loss_sentinel_then_recovery(tiny_ralm):
    """Every replica of every domain crashes for a window: the flush
    serves the all-sentinel result (knnlm degrades to the bare LM on
    it) instead of raising; after the window the probation machine
    restores full-quality service and counts the recoveries."""
    _, _, _, ds, ccfg, _ = tiny_ralm
    q = _queries(ds, n=2)
    d0, i0, _ = _search(_svc(ds, ccfg), q)
    plan = crash_plan(shard=-1, replica=-1, start=0, stop=2)
    svc = _svc(ds, ccfg, failover=FailoverConfig(
        replicas=2, probation_s=0.0, probation_successes=1,
        probe_every=2), chaos=plan)
    d1, i1, h = _search(svc, q)                # flush 0: total loss
    assert h.partial and h.live_fraction == 0.0
    assert (i1 == -1).all() and np.isinf(d1).all()
    for _ in range(4):                         # flushes past the window
        d2, i2, h2 = _search(svc, q)
    np.testing.assert_array_equal(d2, d0)
    np.testing.assert_array_equal(i2, i0)
    assert not h2.partial
    assert svc.stats.ft_recoveries >= 2        # both domains healed
    assert svc.replicas.state_counts()[EJECTED] == 0


def test_allow_partial_false_raises_but_never_wedges(tiny_ralm):
    """allow_partial=False surfaces total loss as ScanHang — but the
    in-flight table must still drain: the failed entries resolve to the
    sentinel, num_inflight returns to zero (the flush-raise leak
    regression)."""
    _, _, _, ds, ccfg, _ = tiny_ralm
    svc = _svc(ds, ccfg,
               failover=FailoverConfig(replicas=1, allow_partial=False),
               chaos=crash_plan(replica=-1))
    h = svc.submit(_queries(ds, n=2))
    with pytest.raises(ScanHang):
        svc.flush()
    assert h.done()                            # sentinel-filled, not stuck
    d, i = h.result()
    assert (np.asarray(i) == -1).all() and h.partial
    assert svc.num_inflight == 0


def test_degraded_partial_sheds_the_tail(tiny_ralm):
    """The degrade ladder's partial-retrieval rung: one attempt per
    domain, no hedging into the tail — a hanging first pick turns into
    an immediate partial; clearing the rung restores failover."""
    _, _, _, ds, ccfg, _ = tiny_ralm
    plan = FaultPlan.make([FaultSpec(kind="hang", replica=1)])
    svc = _svc(ds, ccfg, failover=_NO_COMEBACK, chaos=plan)
    svc.set_degraded_partial(True)
    q = _queries(ds, n=2)
    d, i, h = _search(svc, q)                  # both domains: 1 hang each
    assert h.partial
    assert svc.stats.ft_hedges == 2            # exactly one round
    svc.set_degraded_partial(False)
    d2, i2, h2 = _search(svc, q)               # hedges to the sibling
    assert not h2.partial


# ---------------------------------------------------------------------------
# engine-level seed matrix (the CI chaos-smoke scenarios)
# ---------------------------------------------------------------------------

def _engine(tiny, failover=None, chaos=None, spec_k=0):
    cfg, params, _, ds, ccfg, rag = tiny
    ret = ds.async_retriever(ccfg, service_cfg=ServiceConfig(
        measure=False, failover=failover))
    if chaos is not None:
        ret.service.install_chaos(chaos)
    return RalmEngine.monolithic(params, cfg, rag, retriever=ret,
                                 speculate_k=spec_k)


def _run(eng, corpus, steps=8, n=2):
    done = []
    for i in range(n):
        eng.submit(RalmRequest(
            prompt=jnp.asarray(corpus[2 * i:2 * i + 2, :4]), steps=steps))
    done += eng.run()
    return done


@pytest.mark.parametrize("kind,seed", [
    ("hang", 0), ("crash", 0), ("slow", 7)])
def test_chaos_seed_matrix_token_parity(tiny_ralm, kind, seed):
    """Replica-level faults (the sibling always covers) must be
    invisible in the emitted tokens: greedy parity with a fault-free
    FT-off engine, zero partial steps, and the matching counter fires."""
    corpus = tiny_ralm[2]
    base = _run(_engine(tiny_ralm), corpus)
    plan = FaultPlan.make(
        [FaultSpec(kind=kind, replica=1, start_flush=2,
                   p=0.5 if kind == "slow" else 1.0,
                   slow_s=0.001 if kind == "slow" else 0.0)], seed=seed)
    eng = _engine(tiny_ralm, failover=_NO_COMEBACK, chaos=plan)
    out = _run(eng, corpus)
    for a, b in zip(base, out):
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens))
    assert all(r.partial_steps == 0 for r in out)
    st = eng.retriever.service.stats
    assert st.ft_partial_flushes == 0
    counter = dict(hang=st.ft_hedges, crash=st.ft_crashes,
                   slow=st.ft_timeouts + st.ft_hedges + st.ft_crashes)
    if kind != "slow":                         # slow w/o deadline: benign
        assert counter[kind] >= 1


def test_shard_outage_degrades_and_recovers_tokens(tiny_ralm):
    """Whole-domain outage mid-stream (sequential requests, so the
    flush window maps cleanly onto requests): every request still
    completes, the affected steps are counted per-request via
    partial_steps, and requests after the outage window return to
    baseline tokens."""
    corpus = tiny_ralm[2]

    def serve(eng):
        done = []
        for i in range(3):
            eng.submit(RalmRequest(
                prompt=jnp.asarray(corpus[2 * i:2 * i + 2, :4]), steps=8))
            done += eng.run()
        return done

    base = serve(_engine(tiny_ralm))
    plan = FaultPlan.make(
        [FaultSpec(kind="crash", shard=0, start_flush=4, stop_flush=12)])
    eng = _engine(tiny_ralm, failover=FailoverConfig(
        replicas=2, probation_s=0.0, probation_successes=1,
        probe_every=2), chaos=plan)
    out = serve(eng)
    assert len(out) == 3                       # zero failed requests
    st = eng.retriever.service.stats
    assert st.ft_partial_flushes > 0
    # one request per wave: per-request step accounting == flush count
    assert sum(r.partial_steps for r in out) == st.ft_partial_flushes
    assert out[0].partial_steps > 0 and out[-1].partial_steps == 0
    assert st.ft_recoveries >= 1
    # the last request runs entirely after the window: tokens recover
    np.testing.assert_array_equal(np.asarray(base[-1].tokens),
                                  np.asarray(out[-1].tokens))


def test_chaos_seed_matrix_router_pipeline():
    """The router (distributed) pipeline is ONE fault domain: a crashed
    or hung replica fails over to its sibling with bit-identical
    results; losing every replica degrades to the total-loss sentinel
    instead of raising. Subprocess: the mesh needs 8 fake devices."""
    import pathlib
    import subprocess
    import sys
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env = dict(PYTHONPATH=src, PATH="/usr/bin:/bin", HOME="/tmp",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.core.ivfpq import *
from repro.core.chamvs import ChamVSConfig
from repro.retrieval import (FailoverConfig, FaultPlan, FaultSpec,
                             RetrievalService, ServiceConfig, ShardRouter)
key = jax.random.PRNGKey(0)
cfg_i = IVFPQConfig(dim=64, nlist=64, m=8, list_cap=128)
vecs = jax.random.normal(key, (8192, 64))
params = train_ivfpq(key, vecs[:4096], cfg_i, kmeans_iters=6)
shards = build_shards(params, np.asarray(vecs), cfg_i, num_shards=4)
cfg = ChamVSConfig(ivfpq=cfg_i, nprobe=16, k=20, backend="ref")
q = jax.random.normal(jax.random.PRNGKey(1), (4, 64))

def svc(failover=None, plan=None):
    mesh = make_mesh((4, 2), ("data", "model"))
    router = ShardRouter(mesh, cfg, db_axes=("data",), query_axis="model")
    s = RetrievalService.distributed(router, params, shards,
                                     ServiceConfig(bucket_pow2=False,
                                                   failover=failover))
    if plan is not None:
        s.install_chaos(plan)
    return s

def search(s):
    h = s.submit(q); s.flush()
    d, i = h.result()
    return np.asarray(d), np.asarray(i), h

assert svc().pipeline.fault_domains == 1
d0, i0, _ = search(svc())
fo = FailoverConfig(replicas=2, probation_s=999.0)
for kind in ("crash", "hang"):
    plan = FaultPlan.make([FaultSpec(kind=kind, replica=1)])
    d1, i1, h = search(svc(fo, plan))
    assert np.array_equal(d0, d1) and np.array_equal(i0, i1), kind
    assert not h.partial, kind
d2, i2, h2 = search(svc(fo, FaultPlan.make(
    [FaultSpec(kind="crash", replica=-1)])))
assert h2.partial and h2.live_fraction == 0.0
assert (i2 == -1).all() and np.isinf(d2).all()
print("ROUTER_CHAOS_OK")
"""
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=540, env=env)
    assert p.returncode == 0, \
        f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-3000:]}"
    assert "ROUTER_CHAOS_OK" in p.stdout


def test_speculation_survives_partial_results(tiny_ralm):
    """Speculation x faults: a partial handle at harvest is flushed
    (never seeds the next point), verification still settles every
    point, and the run completes — no wedge, parity preserved outside
    the outage."""
    corpus = tiny_ralm[2]
    plan = FaultPlan.make(
        [FaultSpec(kind="crash", shard=0, start_flush=3, stop_flush=9)])
    eng = _engine(tiny_ralm, failover=FailoverConfig(
        replicas=2, probation_s=0.0, probation_successes=1,
        probe_every=2), chaos=plan, spec_k=1)
    out = _run(eng, corpus, n=2, steps=10)
    assert len(out) == 2
    st = eng.retriever.service.stats
    assert st.ft_partial_flushes > 0
    assert st.spec_issued > 0
    assert st.spec_accepted + st.spec_rollbacks == st.spec_verified
    assert eng.retriever.service.num_inflight == 0
    assert eng.pool.num_used == 0


# ---------------------------------------------------------------------------
# leak regression: cancel mid-search under speculation
# ---------------------------------------------------------------------------

def test_cancel_mid_wave_releases_everything(tiny_ralm):
    """A client disconnect mid-decode with speculation in flight must
    retire every in-flight search and return the KV slots: after the
    drain, num_inflight == 0 and the pool is empty."""
    corpus = tiny_ralm[2]
    eng = _engine(tiny_ralm, spec_k=1)
    rid = eng.submit(RalmRequest(prompt=jnp.asarray(corpus[0:2, :4]),
                                 steps=12))
    eng.submit(RalmRequest(prompt=jnp.asarray(corpus[4:6, :4]), steps=12))
    done = []
    for _ in range(3):
        done += eng.step()
    assert any(seq.spec_points for seq in eng.scheduler.active)
    assert eng.scheduler.cancel(rid)
    done += eng.run()
    by_id = {r.request_id: r for r in done}
    assert by_id[rid].cancelled
    svc = eng.retriever.service
    assert svc.num_inflight == 0
    assert eng.pool.num_used == 0
    assert eng.spec_stats.spec_discarded >= 1


# ---------------------------------------------------------------------------
# degrade ladder: the partial-retrieval rung
# ---------------------------------------------------------------------------

def test_ladder_includes_partial_rung_only_with_replicas(tiny_ralm):
    cfg, params, _, ds, ccfg, rag = tiny_ralm
    eng_ft = _engine(tiny_ralm, failover=FailoverConfig(replicas=2))
    names = [lv.name for lv in DegradePolicy(eng_ft).ladder]
    assert "partial-retrieval" in names
    assert names.index("partial-retrieval") < names.index("knn-off")
    eng_plain = _engine(tiny_ralm)
    names_plain = [lv.name for lv in DegradePolicy(eng_plain).ladder]
    assert "partial-retrieval" not in names_plain
    # and it can be configured away
    names_off = [lv.name for lv in DegradePolicy(
        eng_ft, DegradeConfig(partial_rung=False)).ladder]
    assert "partial-retrieval" not in names_off


def test_ladder_walk_sets_and_clears_partial_mode(tiny_ralm):
    """Sustained pressure walks nprobe -> interval -> partial-retrieval
    (service enters single-attempt mode); sustained calm walks back up
    and clears it; the recovered level reproduces baseline tokens."""
    corpus = tiny_ralm[2]
    eng = _engine(tiny_ralm, failover=FailoverConfig(replicas=2))
    base = _run(_engine(tiny_ralm), corpus)
    pol = DegradePolicy(eng, DegradeConfig(patience=1, recovery=1,
                                           high_watermark=4,
                                           low_watermark=1))
    svc = eng.retriever.service
    partial_idx = [lv.name for lv in pol.ladder].index("partial-retrieval")
    walked = []
    while pol.level < partial_idx:
        assert pol.observe(queue_depth=100)
        walked.append(pol.ladder[pol.level].name)
    assert svc._degraded_partial
    assert pol.ladder[pol.level].partial
    assert walked[0].startswith("nprobe") and "interval" in walked[-2]
    down = pol.transitions_down
    while pol.level > 0:
        assert pol.observe(queue_depth=0)
    assert not svc._degraded_partial
    assert pol.transitions_down == down and pol.transitions_up == down
    out = _run(eng, corpus)                    # recovered level: parity
    for a, b in zip(base, out):
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens))


# ---------------------------------------------------------------------------
# straggler watchdog + metrics plane
# ---------------------------------------------------------------------------

def test_wave_straggler_watchdog(tiny_ralm):
    """The scheduler feeds per-wave wall time into the shared
    StragglerMonitor: an outlier wave (>2x the rolling median) bumps
    the counter the metrics adapter exports."""
    eng = _engine(tiny_ralm)
    sched = eng.scheduler
    for _ in range(6):
        sched._record_wave(0.010)
    assert sched.straggler_events == 0
    sched._record_wave(0.100)
    assert sched.straggler_events == 1
    sched._record_wave(0.011)                  # normal waves stay quiet
    assert sched.straggler_events == 1


def test_fault_metrics_families(tiny_ralm):
    from repro.obs import MetricsRegistry, bind_engine_metrics
    corpus = tiny_ralm[2]
    eng = _engine(tiny_ralm, failover=_NO_COMEBACK,
                  chaos=crash_plan(replica=1))
    _run(eng, corpus, n=1, steps=4)
    eng.scheduler._record_wave(0.01)
    reg = MetricsRegistry()
    bind_engine_metrics(reg, eng)
    text = reg.render()
    assert 'ralm_retrieval_fault_total{kind="crash"}' in text
    assert 'ralm_retrieval_fault_total{kind="partial_flush"}' in text
    assert 'ralm_retrieval_fault_replicas{state="ejected"}' in text
    assert "ralm_retrieval_fault_dispatch_seconds" in text
    assert "ralm_wave_straggler_total" in text


# ---------------------------------------------------------------------------
# EngineConfig / launcher wiring
# ---------------------------------------------------------------------------

def test_engine_config_arms_fault_tolerance(tiny_ralm, tmp_path):
    cfg, params, _, ds, ccfg, rag = tiny_ralm
    path = str(tmp_path / "plan.json")
    crash_plan(replica=1).save(path)
    econfig = EngineConfig(model=cfg, rag=rag, async_retrieval=True,
                           shard_replicas=2, retrieval_deadline_s=0.05,
                           hedge_quantile=0.9, chaos_plan=path)
    eng = RalmEngine.from_config(econfig, params, ds, ccfg)
    svc = eng.retriever.service
    assert svc.replicas is not None
    assert svc.replicas.cfg.replicas == 2
    assert svc.replicas.cfg.dispatch_deadline_s == 0.05
    assert svc.replicas.cfg.hedge_quantile == 0.9
    assert svc.chaos is not None
    assert svc.chaos.plan.faults[0].kind == "crash"


def test_engine_config_ft_requires_async_retrieval(tiny_ralm):
    cfg, params, _, ds, ccfg, rag = tiny_ralm
    econfig = EngineConfig(model=cfg, rag=rag, async_retrieval=False,
                           shard_replicas=2)
    with pytest.warns(RuntimeWarning, match="async_retrieval"):
        eng = RalmEngine.from_config(econfig, params, ds, ccfg)
    assert getattr(eng.retriever, "service", None) is None
