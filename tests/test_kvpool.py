"""Wave-batched decode over the slotted KV-cache pool.

The load-bearing claims (ISSUE 3 acceptance criteria):

  * ``RalmScheduler.step`` issues exactly ONE LM decode dispatch per
    wave, however many sequences are active (dispatch counter);
  * greedy outputs are token-identical to the per-sequence oracle
    (``wave=False``) under mixed prompt lengths, mid-run admission,
    early finishers freeing slots, and slot reuse;
  * a fixed-capacity pool defers admission until completions free slots
    (continuous batching in units of KV slot rows).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tf
from repro.serve import (DatastoreBuilder, KVCachePool, RagConfig,
                         RalmEngine, RalmRequest)


@pytest.fixture(scope="module")
def tiny_ralm():
    """Tiny decoder LM + kNN-LM datastore over a deterministic-bigram
    corpus (token t -> (3t+1) mod 64) — the serving fixture."""
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    start = rng.integers(0, 64, size=(64,))
    corpus = [start]
    for _ in range(31):
        corpus.append((3 * corpus[-1] + 1) % 64)
    corpus = np.stack(corpus, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                          list_cap=512).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    return cfg, params, corpus, ds, ccfg, rag


def oracle_tokens(tiny, prompt, steps):
    """Per-sequence reference path (one dispatch per sequence)."""
    cfg, params, corpus, ds, ccfg, rag = tiny
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg),
                                wave=False)
    return np.asarray(eng.generate(jnp.asarray(prompt), steps=steps))


# ---------------------------------------------------------------------------
# KVCachePool unit behavior
# ---------------------------------------------------------------------------

def test_pool_slot_lifecycle():
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    pool = KVCachePool(cfg, capacity=4, max_seq=16)
    assert pool.num_free == 4 and pool.scratch == 4
    a = pool.alloc(2)
    b = pool.alloc(1)
    assert a.tolist() == [0, 1] and b.tolist() == [2]
    assert pool.num_used == 3
    pool.release(a)
    # lowest ids first -> deterministic slot reuse
    assert pool.alloc(2).tolist() == [0, 1]
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(2)
    assert pool.bucket(3) == 4 and pool.bucket(4) == 4 and pool.bucket(5) == 8


def test_oversized_request_rejected_at_submit(tiny_ralm):
    """A request that can NEVER fit the fixed pool fails in submit()
    instead of wedging the FIFO queue when admission reaches it."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg),
                                kv_slots=2)
    with pytest.raises(ValueError, match="never fit"):
        eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:3, :8]), steps=2))
    # the queue stays clean: valid work still flows
    out = eng.generate(jnp.asarray(corpus[:2, :8]), steps=2)
    assert out.shape == (2, 10)


def test_pool_fixed_capacity_cannot_grow():
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    pool = KVCachePool(cfg, capacity=2, max_seq=8, fixed=True)
    with pytest.raises(RuntimeError, match="fixed"):
        pool.grow_slots(4)


def test_pool_growth_preserves_written_rows():
    """Slot and sequence growth pad the pool without disturbing rows that
    prefill already wrote."""
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    pool = KVCachePool(cfg, capacity=2, max_seq=8)
    caches = tf.init_cache(cfg, 1, max_seq=8)
    marked = jax.tree.map(lambda a: jnp.ones_like(a), caches)
    slots = pool.alloc(1)
    pool.write_prefill(slots, marked)
    pool.grow_slots(4)
    pool.grow_seq(12)
    assert pool.capacity == 4 and pool.max_seq == 12
    cls = cfg.layer_pattern[0]
    k = pool.caches["classes"][cls]["k"]
    assert k.shape[2] == 12
    assert bool((k[:, slots[0], :8] == 1).all())      # written prefix intact
    assert bool((k[:, slots[0], 8:] == 0).all())      # extension zeroed
    assert pool.num_free == 3                          # old scratch + growth


# ---------------------------------------------------------------------------
# acceptance: one dispatch per wave
# ---------------------------------------------------------------------------

def test_one_decode_dispatch_per_wave(tiny_ralm):
    """Three concurrent requests, one LM dispatch per scheduler wave —
    versus one per sequence on the oracle path."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    for i in range(3):
        eng.submit(RalmRequest(prompt=jnp.asarray(corpus[i:i + 1, :8]),
                               steps=4))
    assert eng.step() == [] and eng.decode_dispatches == 0   # all at step 0
    before = eng.decode_dispatches
    eng.step()
    assert eng.decode_dispatches == before + 1               # ONE for 3 seqs
    eng.run()
    # steps 1..3 decode (step 0 consumes prefill logits): 3 waves total
    assert eng.decode_dispatches == 3
    assert eng.pool.stats.mean_wave() == pytest.approx(3.0)

    oracle = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg),
                                   wave=False)
    for i in range(3):
        oracle.submit(RalmRequest(prompt=jnp.asarray(corpus[i:i + 1, :8]),
                                  steps=4))
    oracle.run()
    assert oracle.decode_dispatches == 9                     # 3 seqs x 3


# ---------------------------------------------------------------------------
# acceptance: wave == oracle, token for token
# ---------------------------------------------------------------------------

def test_wave_parity_mixed_prompt_lengths(tiny_ralm):
    """Ragged prompts (5/8/11 tokens) share the pool; every request's
    greedy tokens must match its solo per-sequence run."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    specs = [(corpus[:2, :5], 6), (corpus[2:4, :8], 6), (corpus[4:5, :11], 4)]
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    rids = [eng.submit(RalmRequest(prompt=jnp.asarray(p), steps=s))
            for p, s in specs]
    by_id = {r.request_id: r.tokens for r in eng.run()}
    for rid, (p, s) in zip(rids, specs):
        assert (by_id[rid] == oracle_tokens(tiny_ralm, p, s)).all()


def test_wave_parity_mid_run_admission_and_early_finishers(tiny_ralm):
    """A request admitted mid-run joins the wave; a short request
    finishes early, frees its slots, and a queued request reuses them —
    all without perturbing anyone's tokens."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg),
                                kv_slots=4)
    ra = eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:2, :8]), steps=6))
    eng.step(); eng.step()                      # A is 2 tokens in
    rb = eng.submit(RalmRequest(prompt=jnp.asarray(corpus[2:4, :5]),
                                steps=2))       # joins mid-run (ragged)
    rc = eng.submit(RalmRequest(prompt=jnp.asarray(corpus[4:6, :6]),
                                steps=3))       # must wait for B's slots
    completions = []
    deferred = False
    while eng.scheduler.has_work:
        completions.extend(eng.step())
        deferred |= len(eng.scheduler.queue) > 0
    assert deferred                             # C actually queued on slots
    # B (2 steps) finishes first; A (6 steps, 2-step head start) beats C
    # (3 steps, admitted only once B freed its slots)
    assert [r.request_id for r in completions] == [rb, ra, rc]
    by_id = {r.request_id: r.tokens for r in completions}
    assert (by_id[ra] == oracle_tokens(tiny_ralm, corpus[:2, :8], 6)).all()
    assert (by_id[rb] == oracle_tokens(tiny_ralm, corpus[2:4, :5], 2)).all()
    assert (by_id[rc] == oracle_tokens(tiny_ralm, corpus[4:6, :6], 3)).all()
    assert eng.pool.num_free == 4               # everything released
    assert eng.pool.stats.high_water == 4       # B+C reused A-era rows
    assert eng.pool.stats.slot_grows == 0       # fixed pool never grew


def test_wave_parity_slot_reuse_back_to_back(tiny_ralm):
    """Slots freed by one request are re-prefilled by the next; stale
    cache contents from the previous occupant must not leak."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg),
                                kv_slots=2)
    out1 = np.asarray(eng.generate(jnp.asarray(corpus[:2, :8]), steps=5))
    out2 = np.asarray(eng.generate(jnp.asarray(corpus[6:8, :7]), steps=5))
    assert eng.pool.stats.allocs == 4 and eng.pool.stats.releases == 4
    assert (out1 == oracle_tokens(tiny_ralm, corpus[:2, :8], 5)).all()
    assert (out2 == oracle_tokens(tiny_ralm, corpus[6:8, :7], 5)).all()


def test_wave_pool_autogrow_parity(tiny_ralm):
    """Without ``kv_slots`` the pool doubles its rows and extends its
    sequence axis on demand; outputs stay oracle-identical."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    ra = eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:2, :6]), steps=3))
    eng.step()
    big = corpus[2:12, :12]                     # 10 rows, longer horizon
    rb = eng.submit(RalmRequest(prompt=jnp.asarray(big), steps=6))
    by_id = {r.request_id: r.tokens for r in eng.run()}
    assert eng.pool.stats.slot_grows >= 1 and eng.pool.stats.seq_grows >= 1
    assert (by_id[ra] == oracle_tokens(tiny_ralm, corpus[:2, :6], 3)).all()
    assert (by_id[rb] == oracle_tokens(tiny_ralm, big, 6)).all()


def test_wave_buckets_are_pow2(tiny_ralm):
    """Continuous batching sweeps the active row count; compiled wave
    shapes stay on pow2 buckets (bounded jit recompiles)."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    for i, steps in enumerate([5, 4, 3, 2, 1]):  # 5 rows, one drops per wave
        eng.submit(RalmRequest(prompt=jnp.asarray(corpus[i:i + 1, :8]),
                               steps=steps))
    eng.run()
    buckets = eng.pool.stats.buckets
    assert all(b & (b - 1) == 0 for b in buckets), buckets
    assert buckets <= {1, 2, 4, 8}


def test_wave_async_retriever_coalesces(tiny_ralm):
    """Wave decode composes with the async retrieval service: one LM
    dispatch AND one search dispatch per wave."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    aret = ds.async_retriever(ccfg)
    eng = RalmEngine.monolithic(params, cfg, rag, aret)
    eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:2, :8]), steps=4))
    eng.submit(RalmRequest(prompt=jnp.asarray(corpus[2:4, :8]), steps=4))
    eng.run()
    assert eng.decode_dispatches == 3            # steps 1..3 (step 0 free)
    st = aret.service.stats
    assert st.num_batches == 4                   # one search per wave
    assert st.max_coalesced == 4
