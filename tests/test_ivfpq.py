"""IVF-PQ correctness + invariants (core of ChamVS)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import ivfpq
from repro.core.ivfpq import (IVFPQConfig, build_shards, encode, exact_search,
                              merge_topk, recall_at_k, scan_ivf_index,
                              search_shard_ref, train_ivfpq)


def clustered_data(key, n, d, n_clusters=32, spread=0.05):
    """Synthetic data where IVF-PQ shines (and recall is meaningful)."""
    kc, kx, ka = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (n_clusters, d))
    assign = jax.random.randint(ka, (n,), 0, n_clusters)
    return centers[assign] + spread * jax.random.normal(kx, (n, d))


@pytest.fixture(scope="module")
def small_index():
    key = jax.random.PRNGKey(0)
    d, n = 32, 8192
    cfg = IVFPQConfig(dim=d, nlist=32, m=8, list_cap=512)
    vecs = clustered_data(key, n, d)
    params = train_ivfpq(key, vecs[:4096], cfg, kmeans_iters=10)
    shards = build_shards(params, np.asarray(vecs), cfg, num_shards=4)
    return cfg, params, shards, vecs


def test_encode_shapes_and_range(small_index):
    cfg, params, _, vecs = small_index
    codes, assign = encode(params, vecs[:100], cfg)
    assert codes.shape == (100, cfg.m)
    assert codes.dtype == jnp.uint8
    assert int(codes.max()) < cfg.ksub
    assert int(assign.max()) < cfg.nlist


def test_shard_balance_and_coverage(small_index):
    """Partition scheme 1 (paper §4.3): every list striped across shards;
    shard loads balanced; every vector appears exactly once."""
    cfg, params, shards, vecs = small_index
    n = vecs.shape[0]
    all_ids = np.concatenate([np.asarray(s.ids).ravel() for s in shards])
    valid = all_ids[all_ids >= 0]
    assert len(valid) == n
    assert len(np.unique(valid)) == n
    totals = [int(jnp.sum(s.list_len)) for s in shards]
    assert max(totals) - min(totals) <= cfg.nlist  # stripe remainder bound
    # per-list balance: lengths differ by at most 1 across shards
    lens = np.stack([np.asarray(s.list_len) for s in shards])
    assert int((lens.max(0) - lens.min(0)).max()) <= 1


def test_recall_reasonable(small_index):
    """R@10-in-top-100 (the paper's R@K regime, §6.1: R@100=93-94% scanning
    0.1% of the DB): on clustered data, the true 10 nearest neighbors must
    almost always appear among the returned 100 candidates."""
    cfg, params, shards, vecs = small_index
    q = vecs[:64] + 0.01  # near-duplicate queries
    _, probe = scan_ivf_index(params, q, nprobe=8)
    per = [search_shard_ref(params, s, q, probe, cfg, k=100) for s in shards]
    d, i = merge_topk(jnp.stack([p[0] for p in per]),
                      jnp.stack([p[1] for p in per]), 100)
    _, ti = exact_search(vecs, q, 10)
    r = float((i[:, :, None] == ti[:, None, :]).any(1).mean())
    assert r > 0.9, f"R10@100 {r}"


def test_nprobe_monotone_recall(small_index):
    """More probed lists -> recall never degrades (paper Table 1 semantics)."""
    cfg, params, shards, vecs = small_index
    q = vecs[100:132] + 0.01
    _, ti = exact_search(vecs, q, 10)
    recalls = []
    for nprobe in (1, 4, 16, 32):
        _, probe = scan_ivf_index(params, q, nprobe=nprobe)
        per = [search_shard_ref(params, s, q, probe, cfg, k=10)
               for s in shards]
        _, i = merge_topk(jnp.stack([p[0] for p in per]),
                          jnp.stack([p[1] for p in per]), 10)
        recalls.append(recall_at_k(i, ti))
    assert all(b >= a - 1e-6 for a, b in zip(recalls, recalls[1:])), recalls


def test_merged_equals_single_shard_run(small_index):
    """Sharded search == unsharded search (disaggregation is lossless)."""
    cfg, params, shards, vecs = small_index
    one = build_shards(params, np.asarray(vecs),
                       IVFPQConfig(dim=cfg.dim, nlist=cfg.nlist, m=cfg.m,
                                   list_cap=cfg.list_cap * 4), num_shards=1)
    q = vecs[200:216]
    _, probe = scan_ivf_index(params, q, nprobe=8)
    per = [search_shard_ref(params, s, q, probe, cfg, k=10) for s in shards]
    d4, i4 = merge_topk(jnp.stack([p[0] for p in per]),
                        jnp.stack([p[1] for p in per]), 10)
    d1, i1 = search_shard_ref(
        params, one[0], q, probe,
        IVFPQConfig(dim=cfg.dim, nlist=cfg.nlist, m=cfg.m,
                    list_cap=cfg.list_cap * 4), 10)
    np.testing.assert_allclose(np.asarray(d4), np.asarray(d1), rtol=1e-5)
    assert (np.asarray(i4) == np.asarray(i1)).all()


def test_adc_approximates_true_distance(small_index):
    """PQ ADC distance ~ true L2^2 (quantization error bounded on
    clustered data): rank correlation must be strongly positive."""
    cfg, params, shards, vecs = small_index
    q = vecs[300:308]
    _, probe = scan_ivf_index(params, q, nprobe=32)
    luts = ivfpq.compute_luts(params, q, probe, cfg)
    codes = shards[0].codes[probe]
    ids = shards[0].ids[probe]
    d_adc = ivfpq.adc_scan_ref(luts, codes)
    valid = np.asarray(ids) >= 0
    da = np.asarray(d_adc)[valid]
    iv = np.asarray(ids)[valid]
    true_d = np.sum((np.asarray(q)[
        np.repeat(np.arange(8), valid.reshape(8, -1).sum(-1))]
        - np.asarray(vecs)[iv]) ** 2, -1)
    corr = np.corrcoef(da, true_d)[0, 1]
    assert corr > 0.9, corr


@given(st.integers(2, 6), st.integers(1, 5), st.integers(3, 17))
def test_merge_topk_is_global_topk(num_shards, nq, k):
    """Property: merging per-shard top-k of disjoint candidate sets equals
    the global top-k (the hierarchical aggregation invariant, paper step 8)."""
    rng = np.random.default_rng(num_shards * 100 + nq * 10 + k)
    per_shard = 2 * k + 3
    d = rng.normal(size=(num_shards, nq, per_shard)).astype(np.float32)
    ids = np.arange(num_shards * nq * per_shard, dtype=np.int32).reshape(
        num_shards, nq, per_shard)
    tops = []
    for s in range(num_shards):
        neg, pos = jax.lax.top_k(-jnp.asarray(d[s]), k)
        tops.append((-neg, jnp.take_along_axis(jnp.asarray(ids[s]), pos, 1)))
    md, mi = merge_topk(jnp.stack([t[0] for t in tops]),
                        jnp.stack([t[1] for t in tops]), k)
    flat_d = d.transpose(1, 0, 2).reshape(nq, -1)
    ref = np.sort(flat_d, axis=1)[:, :k]
    np.testing.assert_allclose(np.asarray(md), ref, rtol=1e-6)


def test_list_cap_overflow_raises():
    key = jax.random.PRNGKey(1)
    cfg = IVFPQConfig(dim=16, nlist=4, m=4, list_cap=8)
    vecs = clustered_data(key, 512, 16, n_clusters=4)
    params = train_ivfpq(key, vecs, cfg, kmeans_iters=4)
    with pytest.raises(ValueError, match="cap"):
        build_shards(params, np.asarray(vecs), cfg, num_shards=2)
