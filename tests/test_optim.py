"""Optimizer + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.optim.adamw import (AdamWConfig, apply_updates,
                               clip_by_global_norm, init_opt_state,
                               lr_schedule)
from repro.optim.compression import dequantize_int8, quantize_int8


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, grad_clip=100.0,
                      state_dtype="float32")
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params, cfg)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.15)


def test_bf16_states_still_converge():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, state_dtype="bfloat16")
    target = jnp.array([0.5, -1.5])
    params = {"w": jnp.zeros(2)}
    state = init_opt_state(params, cfg)
    assert state.m["w"].dtype == jnp.bfloat16
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.2)


def test_grad_clip():
    g = {"a": jnp.ones(100) * 10}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 100.0) < 1e-3
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4
    # below threshold: unchanged
    g2 = {"a": jnp.ones(4) * 0.1}
    c2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.1, rtol=1e-5)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-6          # peak at end of warmup
    assert lrs[100] <= 1e-4 * 1.01             # decays to min ratio
    assert all(b <= a + 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


@given(st.integers(0, 200))
def test_int8_roundtrip_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * (seed + 1)
    q, scale = quantize_int8(x)
    back = dequantize_int8(q, scale)
    # deterministic rounding error <= scale/2 per element
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-6


def test_stochastic_rounding_unbiased():
    x = jnp.full((20000,), 0.3)
    q, scale = quantize_int8(x * 100, key=jax.random.PRNGKey(0))
    back = dequantize_int8(q, scale)
    assert abs(float(back.mean()) - 30.0) < 0.05


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0,
                      total_steps=10, state_dtype="float32")
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    state = init_opt_state(params, cfg)
    p2, _, _ = apply_updates(params, g, state, cfg)
    assert float(p2["w"].mean()) < 1.0      # decayed
    assert float(p2["b"].mean()) == 1.0     # not decayed
