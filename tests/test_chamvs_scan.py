"""The fused streaming ChamVS scan (kernels/chamvs_scan) vs the staged
reference pipeline — the parity contract of this repo's §4 dataflow.

Three layers:
  * hypothesis property test at the kernel level: fused ``chamvs_scan``
    (Pallas interpret AND the vectorized ref backend) must equal the
    staged per-shard ADC -> mask -> exact top-k pipeline — dists and
    ids — over random (shards, queries, probes, cap, m, ksub, kk),
    including empty/short lists (``lens`` padding) and the ``idx == -1``
    sentinel;
  * end-to-end: ``search_single`` with ``fused=True`` vs ``fused=False``
    on a real trained index, both kernel backends;
  * the serving claim: the retrieval service's ``scan_dispatches``
    counter shows ONE scan dispatch per flushed wave regardless of
    shard count (the staged oracle shows one per shard).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.chamvs import ChamVSConfig, search_single
from repro.core.ivfpq import (IVFPQConfig, adc_scan_ref, build_shards,
                              train_ivfpq)
from repro.kernels.chamvs_scan.kernel import fused_scan
from repro.kernels.chamvs_scan.ops import chamvs_scan
from repro.kernels.chamvs_scan.ref import ref_chamvs_scan
from repro.kernels.registry import REF, PALLAS_INTERPRET
from repro.retrieval.service import RetrievalService, ServiceConfig


# ---------------------------------------------------------------------------
# the staged reference pipeline (per-shard ADC -> mask -> exact top-k),
# the oracle the fused kernel must reproduce bit-for-bit on ids
# ---------------------------------------------------------------------------

def _staged_pipeline(luts, codes, gids, lens, kk):
    S, nq, nprobe, cap, _ = codes.shape
    out_d, out_i = [], []
    for s in range(S):
        d = adc_scan_ref(luts, codes[s])                  # [nq, np, cap]
        valid = jnp.arange(cap)[None, None, :] < lens[s][..., None]
        d = jnp.where(valid, d, jnp.inf)
        flat_d = d.reshape(nq, -1)
        flat_i = gids[s].reshape(nq, -1)
        keep = min(kk, flat_d.shape[-1])
        neg, pos = jax.lax.top_k(-flat_d, keep)
        dd = -neg
        ii = jnp.take_along_axis(flat_i, pos, axis=-1)
        ii = jnp.where(jnp.isinf(dd), -1, ii)
        if keep < kk:
            dd = jnp.pad(dd, ((0, 0), (0, kk - keep)),
                         constant_values=jnp.inf)
            ii = jnp.pad(ii, ((0, 0), (0, kk - keep)), constant_values=-1)
        out_d.append(dd)
        out_i.append(ii)
    return jnp.stack(out_d), jnp.stack(out_i)


def _random_case(seed, S, nq, nprobe, cap, m, ksub, zero_lens=False):
    rng = np.random.default_rng(seed)
    luts = jnp.asarray(rng.normal(size=(nq, nprobe, m, ksub)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, ksub, size=(S, nq, nprobe, cap, m)),
                        jnp.uint8)
    if zero_lens:
        lens = np.zeros((S, nq, nprobe), np.int64)
    else:
        # include empty (0) and full (cap) lists in the draw
        lens = rng.integers(0, cap + 1, size=(S, nq, nprobe))
    gids = rng.integers(0, 100_000, size=(S, nq, nprobe, cap))
    gids = np.where(np.arange(cap)[None, None, None] < lens[..., None],
                    gids, -1)
    return (luts, codes, jnp.asarray(gids, jnp.int32),
            jnp.asarray(lens, jnp.int32))


def _assert_parity(got, want):
    gd, gi = np.asarray(got[0]), np.asarray(got[1])
    wd, wi = np.asarray(want[0]), np.asarray(want[1])
    np.testing.assert_array_equal(gi, wi)
    assert (np.isinf(gd) == np.isinf(wd)).all()
    finite = np.isfinite(wd)
    np.testing.assert_allclose(gd[finite], wd[finite], rtol=1e-5, atol=1e-5)


@given(st.integers(1, 3),          # S — shard count
       st.integers(1, 6),          # nq
       st.integers(1, 3),          # nprobe
       st.integers(1, 24),         # cap — probed-list slice length
       st.integers(1, 4),          # m — PQ sub-spaces
       st.sampled_from([4, 16]),   # ksub
       st.integers(1, 8),          # kk — truncated queue length
       st.integers(0, 2 ** 31 - 1))
def test_fused_equals_staged_property(S, nq, nprobe, cap, m, ksub, kk, seed):
    """Property: fused chamvs_scan == staged ref pipeline, dists AND
    ids, for every backend, over random shapes incl. short lists."""
    case = _random_case(seed, S, nq, nprobe, cap, m, ksub)
    want = _staged_pipeline(*case, kk)
    _assert_parity(chamvs_scan(*case, kk, spec=REF), want)
    _assert_parity(chamvs_scan(*case, kk, spec=PALLAS_INTERPRET), want)


def test_fused_all_empty_lists_returns_sentinels():
    """Every list empty -> every slot is the (+inf, -1) sentinel."""
    case = _random_case(0, 2, 4, 2, 8, 2, 16, zero_lens=True)
    for spec in (REF, PALLAS_INTERPRET):
        d, i = chamvs_scan(*case, 5, spec=spec)
        assert np.isinf(np.asarray(d)).all()
        assert (np.asarray(i) == -1).all()


def test_fused_kk_exceeds_candidates_pads():
    """kk larger than the whole candidate pool pads with (+inf, -1) —
    the kernel's queue does this naturally, the ref path explicitly."""
    case = _random_case(1, 1, 2, 1, 3, 2, 4)
    want = _staged_pipeline(*case, 9)
    for spec in (REF, PALLAS_INTERPRET):
        got = chamvs_scan(*case, 9, spec=spec)
        _assert_parity(got, want)
    # the pool is 1 probe x cap 3 = 3 < kk = 9: the tail must be padded
    assert (np.asarray(want[1])[..., 3:] == -1).all()


def test_fused_tile_q_sweep():
    """The query-tile heuristic must not change results (tile_q divides
    nq at 8/4/1; sweep all three explicitly)."""
    case = _random_case(2, 2, 8, 2, 12, 2, 16)
    want = _staged_pipeline(*case, 4)
    for tile_q in (8, 4, 1):
        got = fused_scan(*case, 4, tile_q=tile_q, interpret=True)
        _assert_parity(got, want)


def test_ref_fused_matches_kernel_module_ref():
    case = _random_case(3, 2, 3, 2, 10, 3, 16)
    _assert_parity(ref_chamvs_scan(*case, 6), _staged_pipeline(*case, 6))


# ---------------------------------------------------------------------------
# end-to-end over a real trained index
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_index():
    key = jax.random.PRNGKey(0)
    icfg = IVFPQConfig(dim=32, nlist=16, m=8, list_cap=256)
    vecs = jax.random.normal(key, (2048, 32))
    params = train_ivfpq(key, vecs[:1024], icfg, kmeans_iters=4)
    shards = build_shards(params, np.asarray(vecs), icfg, num_shards=4)
    queries = jax.random.normal(jax.random.PRNGKey(1), (6, 32))
    return icfg, params, shards, queries


def test_search_single_memoizes_service(small_index):
    """Repeated one-shot searches over the same index reuse one
    service — the fused shard stack is packed once, not per call."""
    from repro.core import chamvs

    icfg, params, shards, q = small_index
    cfg = ChamVSConfig(ivfpq=icfg, nprobe=4, k=8, backend="ref")
    chamvs._SERVICE_MEMO.clear()
    search_single(params, shards, q, cfg)
    assert len(chamvs._SERVICE_MEMO) == 1
    svc = next(iter(chamvs._SERVICE_MEMO.values()))
    search_single(params, shards, q[:2], cfg)
    assert len(chamvs._SERVICE_MEMO) == 1
    assert next(iter(chamvs._SERVICE_MEMO.values())) is svc
    # a different config is a different service
    import dataclasses
    search_single(params, shards, q, dataclasses.replace(cfg, nprobe=8))
    assert len(chamvs._SERVICE_MEMO) == 2


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_search_single_fused_equals_staged(small_index, backend):
    icfg, params, shards, q = small_index
    mk = lambda fused: ChamVSConfig(ivfpq=icfg, nprobe=8, k=10,
                                    backend=backend, fused=fused)
    df, i_f = search_single(params, shards, q, mk(True))
    ds, i_s = search_single(params, shards, q, mk(False))
    assert (np.asarray(i_f) == np.asarray(i_s)).all()
    finite = np.isfinite(np.asarray(ds))
    np.testing.assert_allclose(np.asarray(df)[finite],
                               np.asarray(ds)[finite], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# the serving claim: one scan dispatch per flushed wave, any shard count
# ---------------------------------------------------------------------------

def _count_pallas_calls(jaxpr) -> int:
    """Recursively count pallas_call primitives in a (closed) jaxpr."""
    import jax.core

    def walk(j):
        n = 0
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                vs = v if isinstance(v, (list, tuple)) else (v,)
                for x in vs:
                    if isinstance(x, jax.core.ClosedJaxpr):
                        n += walk(x.jaxpr)
                    elif isinstance(x, jax.core.Jaxpr):
                        n += walk(x)
        return n

    return walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)


@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_fused_graph_contains_single_scan_kernel(small_index, num_shards):
    """The structural ground truth behind ``stats.scan_dispatches``:
    the fused scan stage's traced graph contains exactly ONE
    ``pallas_call`` no matter the shard count, while the staged oracle
    contains one per shard. (The service counter is derived from the
    pipeline's shape; this test pins the shape itself, so a regression
    that sneaks a per-shard loop back into the fused path fails here.)
    """
    from repro.core.chamvs import stack_shards
    from repro.retrieval.service import _scan_stage, _scan_stage_fused

    icfg, params, _, q = small_index
    vecs = jax.random.normal(jax.random.PRNGKey(3), (1024, 32))
    shards = build_shards(params, np.asarray(vecs), icfg,
                          num_shards=num_shards)
    # nlist=16 < PALLAS_MIN_NLIST: the probe stage routes to ref, so
    # every pallas_call in the graph is a chamvs scan kernel
    cfg = ChamVSConfig(ivfpq=icfg, nprobe=4, k=8, backend="pallas")
    kk = cfg.k_prime(num_shards)
    fused = jax.make_jaxpr(
        lambda qq: _scan_stage_fused(params, stack_shards(shards), qq,
                                     cfg=cfg, kk=kk))(q)
    staged = jax.make_jaxpr(
        lambda qq: _scan_stage(params, tuple(shards), qq,
                               cfg=cfg, kk=kk))(q)
    assert _count_pallas_calls(fused) == 1
    assert _count_pallas_calls(staged) == num_shards


@pytest.mark.parametrize("num_shards", [1, 2, 4])
def test_one_scan_dispatch_per_wave(small_index, num_shards):
    icfg, params, _, q = small_index
    vecs = jax.random.normal(jax.random.PRNGKey(2), (2048, 32))
    shards = build_shards(params, np.asarray(vecs), icfg,
                          num_shards=num_shards)
    cfg = ChamVSConfig(ivfpq=icfg, nprobe=4, k=8, backend="ref")
    svc = RetrievalService.local(params, shards, cfg,
                                 ServiceConfig(measure=False))
    for _ in range(3):              # three waves: submit + submit + flush
        svc.submit(q[:2])
        svc.submit(q[2:4])
        svc.flush()
    assert svc.stats.num_batches == 3
    assert svc.stats.scan_dispatches == 3      # == waves, NOT shards*waves
    snap = svc.stats.snapshot()
    assert snap["scan_dispatches"] == 3

    staged = RetrievalService.local(
        params, shards, cfg, ServiceConfig(measure=False,
                                           kernel_fused=False))
    staged.submit(q[:2])
    staged.flush()
    assert staged.stats.scan_dispatches == num_shards
