"""The serving front door: HTTP gateway, admission control, degradation.

The load-bearing claims, in test order:

  * admission is correct bookkeeping (token-bucket math, round-robin
    fairness) — pure unit tests on an injected clock, no jax;
  * the degradation ladder steps down under sustained pressure and
    back up on recovery, actually mutating the live engine (interval,
    mode, nprobe), with hysteresis — unit tests on a stub engine;
  * tokens streamed over real HTTP/SSE are byte-identical to the
    in-process greedy engine (serving is a transport, not a model
    change — the same parity discipline as tests/test_serve.py);
  * two tenants' streams interleave (continuous batching is visible
    through the network layer, not just in-process);
  * a mid-stream disconnect releases the client's KV slots: with a
    1-slot pool, a second request completes only if the first's
    abandoned slot was reclaimed;
  * over-quota is a 429 and a full pipeline is a 503, both with
    Retry-After — bounded responses, not unbounded queueing.

HTTP tests share one module-scoped gateway (jit caches are global, so
the extra engines for the disconnect/backpressure tests are cheap).
"""
import dataclasses
import json
import socket
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.chamvs import ChamVSConfig, IVFPQConfig
from repro.models import transformer as tf
from repro.serve import (DatastoreBuilder, RagConfig, RalmEngine,
                         RalmRequest)
from repro.serve.gateway import (AdmissionController, DegradeConfig,
                                 DegradePolicy, Gateway, GatewayConfig,
                                 TenantQuota, TokenBucket)

# ---------------------------------------------------------------------------
# admission control (pure host-side units)
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _req(tenant="default", rows=1, rid=None):
    return RalmRequest(prompt=jnp.zeros((rows, 4), jnp.int32), steps=1,
                       tenant=tenant, request_id=rid)


def test_token_bucket_rate_and_burst():
    clock = FakeClock()
    b = TokenBucket(TenantQuota(rate=2.0, burst=2.0), clock=clock)
    assert b.try_take() is None and b.try_take() is None  # burst of 2
    wait = b.try_take()                                   # bucket empty
    assert wait == pytest.approx(0.5)                     # 1 token / 2 rps
    clock.t += 0.5
    assert b.try_take() is None                           # refilled
    # unmetered tenants never wait
    free = TokenBucket(TenantQuota(), clock=clock)
    assert all(free.try_take() is None for _ in range(100))


def test_admission_quota_429_and_depth_503():
    clock = FakeClock()
    ctl = AdmissionController(
        max_queue_depth=2,
        quotas={"metered": TenantQuota(rate=1.0, burst=1.0)}, clock=clock)
    ok = ctl.offer(_req("metered"))
    assert ok.admitted
    over = ctl.offer(_req("metered"))              # burst spent
    assert (not over.admitted and over.status == 429
            and over.retry_after_s > 0)
    assert ctl.offer(_req("other")).admitted       # other tenant unaffected
    full = ctl.offer(_req("other"))                # pending == depth bound
    assert not full.admitted and full.status == 503
    # scheduler-side load counts against the same bound
    ctl2 = AdmissionController(max_queue_depth=2, clock=clock)
    deep = ctl2.offer(_req(), in_system=2)
    assert not deep.admitted and deep.status == 503
    assert ctl.stats()["rejected_quota"] == 1
    assert ctl.stats()["rejected_capacity"] == 1


def test_admission_round_robin_fairness():
    """A burst from one tenant cannot monopolize release order."""
    ctl = AdmissionController(max_queue_depth=100)
    for i in range(4):
        ctl.offer(_req("hog", rid=i))
    ctl.offer(_req("mouse", rid=100))
    order = [ctl.take(lambda r: True).tenant for _ in range(5)]
    assert order.index("mouse") <= 1               # released 1st or 2nd
    assert ctl.take(lambda r: True) is None


def test_admission_take_respects_fits_and_cancel():
    ctl = AdmissionController(max_queue_depth=10)
    ctl.offer(_req("a", rows=4, rid=1))
    ctl.offer(_req("b", rows=1, rid=2))
    # only 2 rows free: tenant a's head doesn't fit, b's does — a is
    # skipped this round instead of head-of-line blocking everyone
    got = ctl.take(lambda r: r.prompt.shape[0] <= 2)
    assert got is not None and got.tenant == "b"
    assert ctl.take(lambda r: r.prompt.shape[0] <= 2) is None
    assert ctl.cancel(1) and not ctl.cancel(1)     # drop a's queued head
    assert ctl.pending == 0


# ---------------------------------------------------------------------------
# degradation policy (stub engine: no jax work, real config mutation)
# ---------------------------------------------------------------------------


class _StubRetriever:
    def __init__(self, nprobe):
        self.cfg = ChamVSConfig(IVFPQConfig(dim=32, nlist=8, m=8),
                                nprobe=nprobe, k=8)


class _StubEngine:
    def __init__(self, nprobe=4, interval=1):
        self.rag = RagConfig(mode="knnlm", interval=interval, k=8)
        self.retriever = _StubRetriever(nprobe)


def test_degrade_ladder_shape():
    pol = DegradePolicy(_StubEngine(nprobe=8))
    names = [lv.name for lv in pol.ladder]
    assert names[0] == "baseline" and names[-1] == "knn-off"
    nprobes = [lv.nprobe for lv in pol.ladder]
    assert nprobes[:4] == [8, 4, 2, 1]             # halving rungs
    assert pol.ladder[-2].interval > pol.ladder[0].interval
    # an engine already running retrieval-free has nothing to shed
    bare = _StubEngine()
    bare.rag = RagConfig(mode="none")
    assert len(DegradePolicy(bare).ladder) == 1


def test_degrade_steps_down_and_recovers_with_hysteresis():
    eng = _StubEngine(nprobe=4, interval=1)
    pol = DegradePolicy(eng, DegradeConfig(high_watermark=4,
                                           low_watermark=1, patience=3,
                                           recovery=5))
    # two pressured ticks then calm: patience not met, no transition
    assert not pol.observe(10) and not pol.observe(10)
    assert not pol.observe(0) and pol.level == 0
    # sustained pressure: step down once per `patience` ticks
    for _ in range(2):
        pol.observe(10)
    assert pol.observe(10) and pol.level == 1
    assert eng.retriever.cfg.nprobe == 2           # applied to the engine
    # keep pressing all the way to the knn-off rung — and no further
    for _ in range(3 * len(pol.ladder)):
        pol.observe(10)
    assert pol.level == len(pol.ladder) - 1
    assert eng.rag.mode == "none"
    # mid-band depth (between watermarks) resets both counters
    pol.observe(3)
    # sustained calm: climb back one rung per `recovery` ticks
    for _ in range(5 * len(pol.ladder)):
        pol.observe(0)
    assert pol.level == 0
    assert eng.rag.mode == "knnlm" and eng.rag.interval == 1
    assert eng.retriever.cfg.nprobe == 4           # baseline restored
    st = pol.stats()
    assert st["transitions_down"] == len(pol.ladder) - 1
    assert st["transitions_up"] == len(pol.ladder) - 1
    assert len(pol.history) == st["transitions_down"] + st["transitions_up"]


# ---------------------------------------------------------------------------
# the HTTP gateway itself
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_ralm():
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    start = rng.integers(0, 64, size=(64,))
    corpus = [start]
    for _ in range(31):
        corpus.append((3 * corpus[-1] + 1) % 64)
    corpus = np.stack(corpus, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                          list_cap=512).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    return cfg, params, corpus, ds, ccfg, rag


def _engine(tiny_ralm, **kw):
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    kw.setdefault("max_seq", 64)
    kw.setdefault("kv_slots", 8)
    kw.setdefault("attn_seq_block", 64)
    return RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg), **kw)


@pytest.fixture(scope="module")
def gw(tiny_ralm):
    gateway = Gateway(_engine(tiny_ralm), GatewayConfig(
        quotas=(("metered", TenantQuota(rate=0.001, burst=1.0)),)))
    gateway.start_background()
    yield gateway
    gateway.shutdown()


def _post(port, payload, tenant=None, timeout=300.0):
    """One POST /v1/completions over a raw socket; returns (status,
    header dict, body bytes). Raw sockets (not http.client) so the SSE
    read loop and the disconnect test control the connection exactly."""
    body = json.dumps(payload).encode()
    tenant_h = f"X-Tenant: {tenant}\r\n" if tenant else ""
    req = (f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n{tenant_h}"
           f"Content-Length: {len(body)}\r\n\r\n").encode() + body
    s = socket.create_connection(("127.0.0.1", port), timeout=timeout)
    s.sendall(req)
    raw = b""
    while b"\r\n\r\n" not in raw:
        raw += s.recv(4096)
    head, rest = raw.split(b"\r\n\r\n", 1)
    lines = head.decode().split("\r\n")
    status = int(lines[0].split()[1])
    headers = {}
    for ln in lines[1:]:
        k, v = ln.split(":", 1)
        headers[k.strip().lower()] = v.strip()
    return s, status, headers, rest


def _drain_sse(s, rest=b""):
    """Read SSE events until [DONE]; returns (token list, final chunk,
    per-token wall-clock arrival times)."""
    buf, toks, stamps, final = rest, [], [], None
    while b"data: [DONE]\n\n" not in buf:
        data = s.recv(4096)
        assert data, "connection closed before [DONE]"
        buf += data
    s.close()
    for event in buf.decode().split("\n\n"):
        if not event.startswith("data: ") or event == "data: [DONE]":
            continue
        obj = json.loads(event[6:])
        choice = obj["choices"][0]
        if choice["finish_reason"] is None:
            toks += [int(t) for t in choice["text"].split()]
            stamps.append(time.perf_counter())
        else:
            final = obj
    return toks, final, stamps


def _greedy_ref(tiny_ralm, prompt, steps):
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    out = eng.generate(jnp.asarray([prompt]), steps=steps)
    return np.asarray(out)[0, len(prompt):].tolist()


def test_http_streaming_greedy_parity(gw, tiny_ralm):
    """Tokens streamed over the wire == the in-process greedy engine."""
    corpus = tiny_ralm[2]
    prompt = corpus[0, :8].tolist()
    s, status, headers, rest = _post(
        gw.port, {"prompt": prompt, "max_tokens": 6, "stream": True})
    assert status == 200
    assert headers["content-type"] == "text/event-stream"
    toks, final, _ = _drain_sse(s, rest)
    assert final["choices"][0]["finish_reason"] == "length"
    assert final["ralm"]["degrade_levels"] == [0]   # unloaded: baseline
    assert final["ralm"]["ttft_ms"] > 0
    assert toks == _greedy_ref(tiny_ralm, prompt, 6)


def test_http_blocking_completion_and_usage(gw, tiny_ralm):
    corpus = tiny_ralm[2]
    prompt = corpus[1, :8].tolist()
    s, status, _, rest = _post(gw.port,
                               {"prompt": prompt, "max_tokens": 4})
    while True:
        data = s.recv(4096)
        if not data:
            break
        rest += data
    s.close()
    assert status == 200
    obj = json.loads(rest)
    assert obj["usage"] == {"prompt_tokens": 8, "completion_tokens": 4,
                            "total_tokens": 12}
    toks = [int(t) for t in obj["choices"][0]["text"].split()]
    assert toks == _greedy_ref(tiny_ralm, prompt, 4)


def test_http_multi_tenant_streams_interleave(gw, tiny_ralm):
    """Two tenants streaming concurrently: both greedy-correct, and
    both *observed active at once* on the engine — continuous batching
    visible through the network layer. The second client launches only
    after the first is live (pure wall-clock racing is flaky: a tiny
    model drains 10 steps faster than a socket handshake)."""
    corpus = tiny_ralm[2]
    pa, pb = corpus[2, :8].tolist(), corpus[3, :8].tolist()
    out = {}

    def client(name, prompt):
        s, status, _, rest = _post(
            gw.port, {"prompt": prompt, "max_tokens": 32, "stream": True},
            tenant=name)
        assert status == 200
        out[name] = _drain_sse(s, rest)

    ta = threading.Thread(target=client, args=("alice", pa))
    ta.start()
    deadline = time.time() + 120
    while gw.scheduler.num_active < 1 and time.time() < deadline:
        time.sleep(0.002)
    assert gw.scheduler.num_active >= 1, "first stream never started"
    tb = threading.Thread(target=client, args=("bob", pb))
    tb.start()
    saw_both = False
    while ta.is_alive() and time.time() < deadline:
        if gw.scheduler.num_active >= 2:
            saw_both = True
            break
        time.sleep(0.002)
    ta.join()
    tb.join()
    assert saw_both, "streams were never active concurrently"
    assert out["alice"][0] == _greedy_ref(tiny_ralm, pa, 32)
    assert out["bob"][0] == _greedy_ref(tiny_ralm, pb, 32)
    assert out["alice"][1]["ralm"]["tenant"] == "alice"
    assert out["bob"][1]["ralm"]["tenant"] == "bob"


def test_http_429_over_quota(gw, tiny_ralm):
    corpus = tiny_ralm[2]
    prompt = corpus[4, :8].tolist()
    s, status, _, rest = _post(gw.port,
                               {"prompt": prompt, "max_tokens": 1},
                               tenant="metered")
    while s.recv(4096):
        pass
    s.close()
    assert status == 200                      # burst of 1 admits the first
    s, status, headers, _ = _post(gw.port,
                                  {"prompt": prompt, "max_tokens": 1},
                                  tenant="metered")
    s.close()
    assert status == 429
    assert int(headers["retry-after"]) >= 1
    assert gw.admission.rejected_quota >= 1


def test_http_400_bad_requests(gw):
    for payload in ({"prompt": [999], "max_tokens": 1},     # out of vocab
                    {"prompt": [], "max_tokens": 1},        # empty
                    {"prompt": [1, 2], "max_tokens": 0},    # no tokens
                    {"prompt": [1, 2], "max_tokens": 10_000},
                    {"prompt": [1] * 60, "max_tokens": 60}):  # > max_seq
        s, status, _, _ = _post(gw.port, payload)
        s.close()
        assert status == 400, payload


def test_http_statsz_surfaces_queue_observability(gw):
    s = socket.create_connection(("127.0.0.1", gw.port), timeout=30)
    s.sendall(b"GET /statsz HTTP/1.1\r\nHost: t\r\n\r\n")
    raw = b""
    while True:
        data = s.recv(4096)
        if not data:
            break
        raw += data
    s.close()
    stats = json.loads(raw.split(b"\r\n\r\n", 1)[1])
    sched = stats["scheduler"]
    for key in ("queued_requests", "active_requests", "active_rows",
                "queue_age_max_s", "tenant_depth"):
        assert key in sched
    assert stats["admission"]["admitted"] >= 1
    assert stats["degrade"]["level_name"] == "baseline"
    assert stats["kv_pool"]["capacity"] == 8
    assert stats["completions"] >= 1 and stats["tokens_out"] >= 1


def test_disconnect_releases_kv_slot(tiny_ralm):
    """kv_slots=1: a second request can only complete if the first
    client's mid-stream disconnect released its slot."""
    gateway = Gateway(_engine(tiny_ralm, kv_slots=1), GatewayConfig())
    gateway.start_background()
    try:
        corpus = tiny_ralm[2]
        prompt = corpus[5, :8].tolist()
        s, status, _, rest = _post(
            gateway.port,
            {"prompt": prompt, "max_tokens": 40, "stream": True})
        assert status == 200
        buf = rest
        while buf.count(b"\n\n") < 2:          # a couple of live tokens
            buf += s.recv(4096)
        s.close()                              # walk away mid-stream
        # the slot must come back: this request needs the whole pool
        s2, status2, _, rest2 = _post(
            gateway.port,
            {"prompt": prompt, "max_tokens": 4, "stream": True},
            timeout=300.0)
        assert status2 == 200
        toks, final, _ = _drain_sse(s2, rest2)
        assert toks == _greedy_ref(tiny_ralm, prompt, 4)
        deadline = time.time() + 30
        while gateway.disconnects < 1 and time.time() < deadline:
            time.sleep(0.01)
        assert gateway.disconnects == 1
        assert gateway.engine.pool.num_used == 0
        assert gateway.scheduler.num_active == 0
    finally:
        gateway.shutdown()


def test_backpressure_503_when_pipeline_full(tiny_ralm):
    """max_queue_depth=1: with one request in flight, the next offer is
    a bounded 503 + Retry-After instead of unbounded queueing."""
    gateway = Gateway(_engine(tiny_ralm),
                      GatewayConfig(max_queue_depth=1))
    gateway.start_background()
    try:
        corpus = tiny_ralm[2]
        prompt = corpus[6, :8].tolist()
        s1, status1, _, rest1 = _post(
            gateway.port,
            {"prompt": prompt, "max_tokens": 40, "stream": True})
        assert status1 == 200
        buf = rest1
        while b"\n\n" not in buf:              # request 1 is live
            buf += s1.recv(4096)
        s2, status2, headers2, _ = _post(
            gateway.port, {"prompt": prompt, "max_tokens": 1})
        s2.close()
        assert status2 == 503
        assert int(headers2["retry-after"]) >= 1
        assert gateway.admission.rejected_capacity >= 1
        _drain_sse(s1, buf)                    # let request 1 finish
    finally:
        gateway.shutdown()


def test_string_prompt_toy_codec(gw, tiny_ralm):
    """OpenAI-style string prompts ride the documented byte codec."""
    s, status, _, rest = _post(gw.port,
                               {"prompt": "hello", "max_tokens": 2})
    while True:
        data = s.recv(4096)
        if not data:
            break
        rest += data
    s.close()
    assert status == 200
    obj = json.loads(rest)
    ref_prompt = [ord(c) % 64 for c in "hello"]
    toks = [int(t) for t in obj["choices"][0]["text"].split()]
    assert toks == _greedy_ref(tiny_ralm, ref_prompt, 2)


def test_scheduler_cancel_and_queue_stats(tiny_ralm):
    """Satellite surface: queue depth/age/tenant stats + cancel, driven
    in-process (no HTTP)."""
    eng = _engine(tiny_ralm, kv_slots=1)
    corpus = tiny_ralm[2]
    r1 = RalmRequest(prompt=jnp.asarray(corpus[:1, :8]), steps=3,
                     tenant="a")
    r2 = RalmRequest(prompt=jnp.asarray(corpus[1:2, :8]), steps=3,
                     tenant="b")
    eng.submit(r1)
    eng.submit(r2)
    st = eng.scheduler.queue_stats()
    assert st["queued_requests"] == 2 and st["active_requests"] == 0
    assert st["tenant_depth"] == {"a": 1, "b": 1}
    assert st["queue_age_max_s"] >= 0.0
    eng.step()                                  # r1 starts (1 slot)
    assert eng.scheduler.queued_requests == 1
    assert eng.scheduler.cancel(r2.request_id)  # queued: dropped now
    assert eng.scheduler.queued_requests == 0
    assert eng.scheduler.cancel(r1.request_id)  # active: flagged
    (resp,) = eng.step()                        # cleaned up next step
    assert resp.cancelled and resp.request_id == r1.request_id
    assert eng.pool.num_used == 0
    assert not eng.scheduler.cancel(999)        # unknown id
