"""The fused Pallas decode-attention kernel (kernels/decode_attn) vs the
grouped ref oracle vs the legacy einsum path — the LM-side parity
contract of this repo's decode hot loop (ISSUE 5 acceptance criteria).

Four layers:
  * hypothesis + parametrized property tests at the attention level:
    pallas ≡ ref ≡ legacy einsum over random B/S/H/KV/D, ragged
    positions, ring=True/False, window>0, GQA ratios incl. KV=1 (MQA);
  * the dispatch-shape claim: the fused ``decode_wave`` graph contains
    exactly ONE attention ``pallas_call`` per step;
  * serve-level: greedy outputs token-identical with the kernel on
    ("pallas") vs off ("einsum" legacy oracle) vs the grouped ref
    default, and vs the per-sequence oracle loop;
  * observability: ``PoolStats.blocks_skipped``/``blocks_total`` record
    the ragged-wave savings and ``decode_compiles`` the jit churn.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import get_arch
from repro.kernels import registry
from repro.kernels.decode_attn.kernel import fused_decode_attention
from repro.kernels.decode_attn.ops import (count_skipped_blocks,
                                           pallas_decode_attention)
from repro.kernels.decode_attn.ref import ref_decode_attention
from repro.models import transformer as tf
from repro.models.attention import decode_attention, decode_attention_einsum
from repro.serve import (DatastoreBuilder, KVCachePool, RagConfig,
                         RalmEngine, RalmRequest)

PALLAS = registry.KernelSpec(backend="pallas", interpret=True)


def _case(seed, B, S, KV, qkv, D, ring):
    rng = np.random.default_rng(seed)
    H = KV * qkv
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    # ragged: rows at wildly different fill levels; ring positions may
    # exceed S (wrapped buffer)
    hi = 3 * S if ring else S - 1
    pos = jnp.asarray(rng.integers(0, hi + 1, size=(B,)), jnp.int32)
    return q, k, v, pos


def _assert_parity(got, want):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(1, 5),            # B
       st.integers(1, 40),           # S
       st.sampled_from([1, 2, 4]),   # KV heads
       st.sampled_from([1, 2, 4]),   # q heads per KV head (1 = MHA-ish,
       #                               KV=1 & qkv>1 = MQA)
       st.sampled_from([4, 16]),     # D
       st.sampled_from([0, 3, 9]),   # window
       st.booleans(),                # ring
       st.integers(0, 2 ** 31 - 1))
def test_pallas_equals_ref_equals_einsum_property(B, S, KV, qkv, D, window,
                                                  ring, seed):
    q, k, v, pos = _case(seed, B, S, KV, qkv, D, ring)
    want = decode_attention_einsum(q, k, v, pos, window, ring)
    _assert_parity(decode_attention(q, k, v, pos, window, ring), want)
    _assert_parity(decode_attention(q, k, v, pos, window, ring,
                                    spec=PALLAS), want)


@pytest.mark.parametrize("B,S,KV,qkv,D,window,ring", [
    (3, 24, 2, 2, 8, 0, False),      # GQA, plain linear cache
    (4, 33, 4, 1, 16, 0, False),     # MHA, odd seq axis (blk = divisor)
    (2, 16, 1, 4, 8, 0, False),      # MQA (KV=1)
    (2, 16, 2, 2, 8, 5, False),      # linear cache + sliding window
    (2, 8, 2, 2, 8, 0, True),        # ring cache, wrapped positions
    (2, 8, 2, 2, 8, 8, True),        # ring cache of size == window
    (5, 20, 3, 2, 4, 7, False),      # non-pow2 everything
])
def test_pallas_equals_ref_equals_einsum(B, S, KV, qkv, D, window, ring):
    """Non-hypothesis grid so parity runs even without hypothesis."""
    q, k, v, pos = _case(0, B, S, KV, qkv, D, ring)
    want = decode_attention_einsum(q, k, v, pos, window, ring)
    _assert_parity(decode_attention(q, k, v, pos, window, ring), want)
    _assert_parity(decode_attention(q, k, v, pos, window, ring,
                                    spec=PALLAS), want)
    _assert_parity(ref_decode_attention(q, k, v, pos, window, ring), want)


def test_kernel_tile_sweep():
    """Explicit (tile_b, blk) combinations must not change results —
    including blk splits that make whole blocks skippable."""
    q, k, v, _ = _case(1, 8, 32, 2, 2, 8, False)
    pos = jnp.asarray([3, 3, 3, 3, 9, 9, 9, 9], jnp.int32)  # short rows
    want = decode_attention_einsum(q, k, v, pos)
    for tile_b in (8, 4, 2, 1):
        for blk in (32, 16, 8, 4):
            got = fused_decode_attention(q, k, v, pos, tile_b=tile_b,
                                         blk=blk, interpret=True)
            _assert_parity(got, want)


def test_kernel_skip_arithmetic():
    """The host-side skip counter mirrors the kernel's tile predicate:
    short row tiles skip the blocks past their max position."""
    pos = np.array([3, 3, 3, 3, 17, 17, 17, 17])
    # tile_b=4: tile 0 (max pos 3) needs 1 of 4 blocks, tile 1 (max pos
    # 17) needs 3 of 4 -> 4 skipped of 8
    skipped, total = count_skipped_blocks(pos, S=32, blk=8, tile_b=4)
    assert (skipped, total) == (4, 8)
    # one tile of 8 rows (4 blocks): max pos 17 -> skip only the last
    skipped, total = count_skipped_blocks(pos, S=32, blk=8, tile_b=8)
    assert (skipped, total) == (1, 4)
    # window slides past the leading blocks (linear cache)
    skipped, total = count_skipped_blocks(
        np.array([30, 30, 31, 31]), S=32, blk=8, tile_b=4, window=4)
    assert (skipped, total) == (3, 4)


def test_multi_token_q_falls_back_to_ref():
    """T>1 is outside the streaming kernel's contract: routed to the
    grouped ref with a recorded fallback, same numerics."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(2, 3, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
    pos = jnp.asarray([5, 11], jnp.int32)
    registry.reset_warnings()
    with pytest.warns(RuntimeWarning, match="decode_attn"):
        got = pallas_decode_attention(q, k, v, pos, spec=PALLAS)
    assert registry.fallback_count("decode_attn") == 1
    _assert_parity(got, ref_decode_attention(q, k, v, pos))


def test_decode_wave_graph_has_one_attention_pallas_call():
    """The structural claim: with the Pallas spec, one fused
    ``decode_wave`` step contains exactly ONE attention ``pallas_call``
    (the layer stack is a lax.scan over one grouped body), and none
    with the ref/einsum specs."""
    from tests.test_chamvs_scan import _count_pallas_calls

    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    caches = tf.init_cache(cfg, 5, max_seq=32)     # 4 slots + scratch
    tok = jnp.zeros((4, 1), jnp.int32)
    slots = jnp.arange(4, dtype=jnp.int32)
    pos = jnp.asarray([3, 5, 7, 9], jnp.int32)

    def wave(spec):
        return jax.make_jaxpr(
            lambda c, t, s, p: tf.decode_wave(
                params, cfg, c, t, s, p, kv_len=16, attn_spec=spec)
        )(caches, tok, slots, pos)

    assert _count_pallas_calls(wave(PALLAS)) == 1
    assert _count_pallas_calls(wave(registry.REF)) == 0
    assert _count_pallas_calls(wave(None)) == 0


# ---------------------------------------------------------------------------
# pool seq-axis alignment + observability
# ---------------------------------------------------------------------------

def test_pool_seq_block_alignment():
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    pool = KVCachePool(cfg, capacity=2, max_seq=20, seq_block=16)
    assert pool.max_seq == 32                       # aligned up
    cls = cfg.layer_pattern[0]
    assert pool.caches["classes"][cls]["k"].shape[2] == 32
    pool.grow_seq(33)
    assert pool.max_seq == 48                       # growth stays aligned
    # attn_len: block-aligned valid prefix, clamped to the pool
    assert pool.attn_len(3, bucket=2) == 16
    assert pool.attn_len(16, bucket=2) == 32
    assert pool.attn_len(200, bucket=2) == 48
    st = pool.stats
    assert st.blocks_total == 9 and st.blocks_skipped == (2 + 1 + 0)
    # graph keys carry the pool shape too: growth retraces every bucket
    assert st.compiled == {(2, 16, 2, 48), (2, 32, 2, 48), (2, 48, 2, 48)}
    assert st.decode_compiles == 3
    pool.grow_seq(64)
    assert pool.attn_len(3, bucket=2) == 16
    assert st.decode_compiles == 4          # same bucket/kv_len, new shape


@pytest.fixture(scope="module")
def tiny_ralm():
    """Same serving fixture family as tests/test_kvpool.py."""
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    start = rng.integers(0, 64, size=(64,))
    corpus = [start]
    for _ in range(31):
        corpus.append((3 * corpus[-1] + 1) % 64)
    corpus = np.stack(corpus, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                          list_cap=512).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    return cfg, params, corpus, ds, ccfg, rag


def _serve_tokens(tiny, attn_backend, **kw):
    cfg, params, corpus, ds, ccfg, rag = tiny
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg),
                                attn_backend=attn_backend, **kw)
    specs = [(corpus[:2, :5], 6), (corpus[2:4, :8], 6), (corpus[4:5, :11], 4)]
    rids = [eng.submit(RalmRequest(prompt=jnp.asarray(p), steps=s))
            for p, s in specs]
    by_id = {r.request_id: r.tokens for r in eng.run()}
    return [by_id[rid] for rid in rids], eng


def test_serve_parity_kernel_on_vs_off(tiny_ralm):
    """Greedy serve outputs are token-identical with the decode-attn
    kernel on (pallas) vs off (legacy einsum) vs the grouped ref
    default, ragged prompts included — and match the per-sequence
    oracle loop."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    want, _ = _serve_tokens(tiny_ralm, "einsum")
    for backend in (None, "ref", "pallas"):
        got, _ = _serve_tokens(tiny_ralm, backend, max_seq=64)
        for a, b in zip(got, want):
            assert (a == b).all(), backend
    oracle = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg),
                                   wave=False)
    for tokens, (p, s) in zip(want, [(corpus[:2, :5], 6),
                                     (corpus[2:4, :8], 6),
                                     (corpus[4:5, :11], 4)]):
        assert (tokens == np.asarray(
            oracle.generate(jnp.asarray(p), steps=s))).all()


def test_serve_blocks_skipped_and_compile_churn(tiny_ralm):
    """Ragged-wave savings and jit churn are observable: short waves in
    an over-provisioned pool skip most seq blocks, and the decode-graph
    count stays at O(buckets x lengths), not O(waves)."""
    _, eng = _serve_tokens(tiny_ralm, None, max_seq=64)
    ps = eng.pool.stats
    assert eng.pool.max_seq == 64 and eng.pool.seq_block == 16
    # positions never exceed 14 -> every wave crops to 16 of 64 slots
    assert ps.blocks_total == 4 * ps.waves
    assert ps.blocks_skipped == 3 * ps.waves
    assert ps.skip_fraction() == pytest.approx(0.75)
    # one (bucket, kv_len, pool shape) graph per wave bucket: far fewer
    # than waves
    assert ps.decode_compiles < ps.waves
    assert all(key[1] == 16 for key in ps.compiled)
