"""Multi-device semantics, run in subprocesses (8 fake CPU devices) because
the XLA device count must be fixed before jax initializes — and the main
pytest process must keep seeing 1 device (assignment requirement)."""
import pathlib
import subprocess
import sys

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str) -> str:
    env = dict(PYTHONPATH=SRC, PATH="/usr/bin:/bin",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               HOME="/tmp")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=540, env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-3000:]}"
    return p.stdout


def test_distributed_search_matches_single():
    """shard_map ChamVS over an 8-device mesh == single-process reference
    (disaggregated memory nodes are semantically invisible, paper §4.3)."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh, use_mesh
from repro.core.ivfpq import *
from repro.core.chamvs import *
key = jax.random.PRNGKey(0)
cfg_i = IVFPQConfig(dim=64, nlist=64, m=8, list_cap=128)
vecs = jax.random.normal(key, (8192, 64))
params = train_ivfpq(key, vecs[:4096], cfg_i, kmeans_iters=6)
shards = build_shards(params, np.asarray(vecs), cfg_i, num_shards=4)
cfg = ChamVSConfig(ivfpq=cfg_i, nprobe=16, k=20, backend="ref")
q = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
d0, i0 = search_single(params, shards, q, cfg)
mesh = make_mesh((4, 2), ("data", "model"))
stacked = jax.device_put(stack_shards(shards), NamedSharding(mesh, P("data")))
search = make_distributed_search(mesh, cfg, db_axes=("data",), query_axis="model")
with use_mesh(mesh):
    d1, i1 = jax.jit(search)(params, stacked, q)
assert np.allclose(d0, d1, rtol=1e-5), "dists diverge"
assert (np.asarray(i0) == np.asarray(i1)).all(), "ids diverge"
print("DIST_SEARCH_OK")
""")
    assert "DIST_SEARCH_OK" in out


def test_probe_split_search():
    """Batch-1 long-context mode: nprobe split over the TP axis."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh, use_mesh
from repro.core.ivfpq import *
from repro.core.chamvs import *
key = jax.random.PRNGKey(0)
cfg_i = IVFPQConfig(dim=32, nlist=32, m=8, list_cap=256)
vecs = jax.random.normal(key, (4096, 32))
params = train_ivfpq(key, vecs[:2048], cfg_i, kmeans_iters=6)
shards = build_shards(params, np.asarray(vecs), cfg_i, num_shards=2)
cfg = ChamVSConfig(ivfpq=cfg_i, nprobe=8, k=10, backend="ref")
q = jax.random.normal(jax.random.PRNGKey(1), (1, 32))
d0, i0 = search_single(params, shards, q, cfg)
mesh = make_mesh((2, 4), ("data", "model"))
stacked = jax.device_put(stack_shards(shards), NamedSharding(mesh, P("data")))
search = make_distributed_search(mesh, cfg, db_axes=("data",),
                                 query_axis="model", nq=1)  # 1 % 4 -> probe split
with use_mesh(mesh):
    d1, i1 = jax.jit(search)(params, stacked, q)
assert np.allclose(np.asarray(d0), np.asarray(d1), rtol=1e-5)
assert (np.asarray(i0) == np.asarray(i1)).all()
print("PROBE_SPLIT_OK")
""")
    assert "PROBE_SPLIT_OK" in out


def test_distributed_retrieval_service():
    """RetrievalService over a ShardRouter (the disaggregated service
    tier): coalesced submissions against the mesh == single-process
    search, including the query-split row-multiple padding (5 rows on a
    2-column query split pad to 6, results slice back to 5)."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.core.ivfpq import *
from repro.core.chamvs import ChamVSConfig, search_single
from repro.retrieval import RetrievalService, ServiceConfig, ShardRouter
key = jax.random.PRNGKey(0)
cfg_i = IVFPQConfig(dim=64, nlist=64, m=8, list_cap=128)
vecs = jax.random.normal(key, (8192, 64))
params = train_ivfpq(key, vecs[:4096], cfg_i, kmeans_iters=6)
shards = build_shards(params, np.asarray(vecs), cfg_i, num_shards=4)
cfg = ChamVSConfig(ivfpq=cfg_i, nprobe=16, k=20, backend="ref")
q = jax.random.normal(jax.random.PRNGKey(1), (5, 64))
d0, i0 = search_single(params, shards, q, cfg)
mesh = make_mesh((4, 2), ("data", "model"))
router = ShardRouter(mesh, cfg, db_axes=("data",), query_axis="model")
assert router.query_size == 2
svc = RetrievalService.distributed(router, params, shards,
                                   ServiceConfig(bucket_pow2=False))
h1 = svc.submit(q[:2]); h2 = svc.submit(q[2:])   # 5 rows -> pad to 6
svc.flush()
d1 = np.concatenate([np.asarray(h1.result()[0]), np.asarray(h2.result()[0])])
i1 = np.concatenate([np.asarray(h1.result()[1]), np.asarray(h2.result()[1])])
assert svc.stats.num_batches == 1 and svc.stats.max_coalesced == 5
assert np.allclose(np.asarray(d0), d1, rtol=1e-5, atol=1e-5)
assert (np.asarray(i0) == i1).all()
print("DIST_SERVICE_OK")
""")
    assert "DIST_SERVICE_OK" in out


def test_distributed_gather():
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh, use_mesh
from repro.core.chamvs import make_distributed_gather
mesh = make_mesh((4, 2), ("data", "model"))
table = jnp.arange(800, dtype=jnp.int32) * 3
tsh = jax.device_put(table, NamedSharding(mesh, P(("data", "model"))))
ids = jnp.array([[0, 799, 400], [123, 7, 650]], jnp.int32)
g = make_distributed_gather(mesh, ("data", "model"))
with use_mesh(mesh):
    got = jax.jit(g)(tsh, ids)
assert (np.asarray(got) == np.asarray(table)[np.asarray(ids)]).all()
print("DGATHER_OK")
""")
    assert "DGATHER_OK" in out


def test_compressed_psum_and_dp_training():
    """int8-compressed gradient all-reduce stays close to exact psum."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import make_mesh, shard_map, use_mesh
from repro.optim.compression import compressed_psum
mesh = make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
def body(xs):
    g = {"w": xs[0]}
    exact = jax.lax.psum(g["w"], "data")
    comp = compressed_psum(g, "data")["w"]
    return exact, comp
f = shard_map(body, mesh=mesh, in_specs=(P("data"),), out_specs=(P(), P()),
              check_vma=False)
with use_mesh(mesh):
    exact, comp = jax.jit(f)(x)
err = float(jnp.abs(exact - comp).max() / jnp.abs(exact).max())
assert err < 0.05, err
print("CPSUM_OK", err)
""")
    assert "CPSUM_OK" in out


def test_elastic_resume_across_mesh_sizes():
    """Train 3 steps on a 4-device mesh, checkpoint, resume on a 2-device
    mesh — loss continues from the same value (elastic rescale)."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np, tempfile, pathlib
from repro.compat import use_mesh
from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import transformer as tf
from repro.models.sharding import param_specs, sanitize
from repro.optim import adamw
from repro.checkpoint import checkpoint as ck
from repro.runtime.fault_tolerance import elastic_restore
from repro.launch.mesh import make_mesh_for

cfg = get_arch('dec_s').reduced
ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20,
                         state_dtype='float32')
data = SyntheticTokens(DataConfig(seq_len=16, global_batch=8,
                                  vocab_size=cfg.vocab_size))
def step_fn(params, opt, batch):
    loss, g = jax.value_and_grad(lambda p: tf.lm_loss(p, cfg, batch,
                                                      remat=False))(params)
    params, opt, m = adamw.apply_updates(params, g, opt, ocfg)
    return params, opt, loss

tmp = tempfile.mkdtemp()
mesh4 = make_mesh_for(jax.devices()[:4], data=4)
with use_mesh(mesh4):
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params, ocfg)
    js = jax.jit(step_fn)
    for s in range(3):
        batch = jax.tree.map(jnp.asarray, data.host_batch(s))
        params, opt, loss3 = js(params, opt, batch)
    ck.save(tmp, 3, (params, opt))
    batch = jax.tree.map(jnp.asarray, data.host_batch(3))
    _, _, loss4_ref = js(params, opt, batch)

mesh2 = make_mesh_for(jax.devices()[:2], data=2)
specs = sanitize(param_specs(cfg, mesh2),
                 jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), cfg)),
                 mesh2)
like = jax.eval_shape(lambda: (tf.init_params(jax.random.PRNGKey(0), cfg),
                               adamw.init_opt_state(
                                   tf.init_params(jax.random.PRNGKey(0), cfg), ocfg)))
(restored, step) = elastic_restore(
    tmp, like, mesh2, (specs, adamw.OptState(
        step=jax.sharding.PartitionSpec(), m=specs, v=specs)))
params2, opt2 = restored
with use_mesh(mesh2):
    batch = jax.tree.map(jnp.asarray, data.host_batch(3))
    _, _, loss4_el = jax.jit(step_fn)(params2, opt2, batch)
# different device counts reduce in different orders -> small bf16
# numeric drift is expected; elastic resume must stay within it
assert abs(float(loss4_ref) - float(loss4_el)) < 1e-3, (loss4_ref, loss4_el)
print('ELASTIC_OK', float(loss4_ref), float(loss4_el))
""")
    assert "ELASTIC_OK" in out
