"""RALM integration math (kNN-LM interpolation, retrieval scheduling)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core.rag import (gather_payload, knnlm_interpolate,
                            retro_neighbor_tokens, should_retrieve)


def test_lambda_zero_recovers_lm():
    B, V, K = 4, 32, 8
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, V))
    d = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (B, K)))
    t = jax.random.randint(jax.random.PRNGKey(2), (B, K), 0, V)
    out = knnlm_interpolate(logits, d, t, lam=0.0, temperature=1.0)
    want = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_lambda_one_single_neighbor_is_spike():
    B, V = 2, 16
    logits = jnp.zeros((B, V))
    d = jnp.full((B, 1), 0.5)
    t = jnp.array([[3], [7]])
    out = knnlm_interpolate(logits, d, t, lam=1.0, temperature=1.0)
    p = np.exp(np.asarray(out))
    assert p[0, 3] > 0.999 and p[1, 7] > 0.999


@given(st.integers(0, 100), st.floats(0.0, 1.0), st.floats(0.5, 50.0))
def test_output_is_distribution(seed, lam, temp):
    B, V, K = 3, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    logits = jax.random.normal(ks[0], (B, V)) * 3
    d = jnp.abs(jax.random.normal(ks[1], (B, K))) * 10
    t = jax.random.randint(ks[2], (B, K), 0, V)
    out = knnlm_interpolate(logits, d, t, lam=lam, temperature=temp)
    p = np.exp(np.asarray(out, np.float64))
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-3)
    assert (p >= 0).all()


def test_missing_neighbors_graceful():
    """Rows whose every neighbor is missing fall back to the pure LM."""
    B, V, K = 2, 16, 4
    logits = jax.random.normal(jax.random.PRNGKey(0), (B, V))
    d = jnp.stack([jnp.full((K,), jnp.inf),
                   jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (K,)))])
    t = jnp.stack([jnp.full((K,), -1, jnp.int32),
                   jax.random.randint(jax.random.PRNGKey(2), (K,), 0, V)])
    out = knnlm_interpolate(logits, d, t, lam=0.5, temperature=1.0)
    want0 = jax.nn.log_softmax(logits[0].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want0),
                               rtol=1e-4, atol=1e-5)
    assert not np.isnan(np.asarray(out)).any()


def test_closer_neighbors_weigh_more():
    V = 8
    logits = jnp.zeros((1, V))
    d = jnp.array([[0.1, 5.0]])
    t = jnp.array([[2, 5]])
    out = knnlm_interpolate(logits, d, t, lam=0.9, temperature=1.0)
    p = np.exp(np.asarray(out[0]))
    assert p[2] > p[5]


def test_retrieval_schedule():
    assert bool(should_retrieve(jnp.asarray(0), 1))
    assert bool(should_retrieve(jnp.asarray(17), 1))
    assert bool(should_retrieve(jnp.asarray(0), 8))
    assert bool(should_retrieve(jnp.asarray(8), 8))
    assert not bool(should_retrieve(jnp.asarray(5), 8))
    # paper Table 2 intervals
    for interval in (8, 64, 512):
        fires = sum(bool(should_retrieve(jnp.asarray(s), interval))
                    for s in range(512))
        assert fires == 512 // interval


def test_payload_gather_and_chunks():
    table = jnp.arange(10, dtype=jnp.int32)
    ids = jnp.array([[0, 9, -1]])
    got = gather_payload(table, ids)
    assert got[0, 0] == 0 and got[0, 1] == 9
    chunks = jnp.arange(40, dtype=jnp.int32).reshape(10, 4)
    ct = retro_neighbor_tokens(chunks, ids)
    assert ct.shape == (1, 3, 4)
    assert (np.asarray(ct[0, 2]) == 0).all()      # missing -> PAD
    assert (np.asarray(ct[0, 1]) == np.arange(36, 40)).all()
