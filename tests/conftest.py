import os
import pathlib
import sys

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 CPU device
# (the 512-device override lives only in launch/dryrun.py). Multi-device
# tests spawn subprocesses (tests/test_distributed.py).
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from hypothesis import settings, HealthCheck

settings.register_profile(
    "ci", max_examples=20, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
settings.load_profile("ci")
