import pathlib
import sys
import types

import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 CPU device
# (the 512-device override lives only in launch/dryrun.py). Multi-device
# tests spawn subprocesses (tests/test_distributed.py).
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


@pytest.fixture(autouse=True)
def _reset_kernel_registry():
    """The kernel registry's one-time warnings and fallback counters are
    module-global ("once per process"); reset them around every test so
    no test leaks warning state into another — the bug the old
    ``ivf_scan.ops._pallas_fallback_warned`` global had."""
    from repro.kernels import registry

    registry.reset_warnings()
    yield
    registry.reset_warnings()

try:
    from hypothesis import settings, HealthCheck

    settings.register_profile(
        "ci", max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")
except ModuleNotFoundError:
    # hypothesis is an optional dev dependency (see requirements.txt).
    # Install a stub so `from hypothesis import given, strategies as st`
    # keeps importing; @given-decorated tests are skipped, everything
    # else in those modules still runs.
    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property-based test)")(fn)
        return deco

    def _strategy(*_args, **_kwargs):
        return None

    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = lambda name: _strategy          # PEP 562
    hyp.given = _given
    hyp.strategies = st
    hyp.settings = types.SimpleNamespace(
        register_profile=lambda *a, **k: None,
        load_profile=lambda *a, **k: None)
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
