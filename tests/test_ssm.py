"""SSM mixers: scan-vs-decode equivalence (the property that makes RWKV and
Hymba the long_500k cells — O(1)-state decode must equal the parallel form)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm


def make_rwkv_params(key, d, H, dh, f):
    D = H * dh
    ks = iter(jax.random.split(key, 32))
    def v(shape, s=0.2):
        return jax.random.normal(next(ks), shape, jnp.float32) * s
    return ssm.RWKV6Params(
        mu_r=v((d,)), mu_k=v((d,)), mu_v=v((d,)), mu_g=v((d,)), mu_w=v((d,)),
        w_r=v((d, D)), w_k=v((d, D)), w_v=v((d, D)), w_g=v((d, D)),
        w_o=v((D, d)), w0=v((D,)), w_lora_a=v((d, 64)), w_lora_b=v((64, D)),
        bonus_u=v((H, dh)), ln_x=jnp.ones((D,)),
        mu_ck=v((d,)), mu_cr=v((d,)),
        w_ck=v((d, f)), w_cv=v((f, d)), w_cr=v((d, d)))


def test_rwkv6_scan_equals_stepwise():
    B, T, d, H, dh, f = 2, 17, 32, 4, 8, 64
    p = make_rwkv_params(jax.random.PRNGKey(0), d, H, dh, f)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d), jnp.float32)
    st0 = ssm.rwkv6_init_state(B, H, dh, d, jnp.float32)
    y_full, sT_full, _ = ssm.rwkv6_time_mix(p, x, st0, H)
    # token-by-token
    st = st0
    ys = []
    for t in range(T):
        y, wkv, sh = ssm.rwkv6_time_mix(p, x[:, t:t + 1], st, H)
        st = ssm.RWKVState(wkv=wkv, shift_t=sh, shift_c=st.shift_c)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sT_full), np.asarray(st.wkv),
                               rtol=2e-4, atol=2e-4)


def test_rwkv6_channel_mix_shift():
    B, T, d, f = 2, 9, 16, 32
    p = make_rwkv_params(jax.random.PRNGKey(2), d, 2, 8, f)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, T, d))
    shift0 = jnp.zeros((B, d))
    y_full, _ = ssm.rwkv6_channel_mix(p, x, shift0)
    sh = shift0
    ys = []
    for t in range(T):
        y, sh = ssm.rwkv6_channel_mix(p, x[:, t:t + 1], sh)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=1e-4)


def test_rwkv6_decay_bounded():
    """Data-dependent decay w_t must lie in (0, 1) — stability invariant.

    Mirrors the implementation's decay clamp (ssm.py: exp(w_log) clipped
    to 8, i.e. w >= e^-8) — without it the raw exp underflows to 0 in
    f32 for extreme inputs, which is exactly what the clamp prevents."""
    B, T, d, H, dh, f = 1, 8, 16, 2, 8, 32
    p = make_rwkv_params(jax.random.PRNGKey(4), d, H, dh, f)
    x = 10.0 * jax.random.normal(jax.random.PRNGKey(5), (B, T, d))
    w_log = p.w0[None, None] + jnp.tanh(
        (x + 0) @ p.w_lora_a) @ p.w_lora_b
    w = jnp.exp(-jnp.clip(jnp.exp(w_log.astype(jnp.float32)), 0.0, 8.0))
    assert float(w.min()) > 0.0 and float(w.max()) < 1.0


def make_mamba_params(key, d, d_in, H, ds, cw):
    ks = iter(jax.random.split(key, 16))
    def v(shape, s=0.2):
        return jax.random.normal(next(ks), shape, jnp.float32) * s
    return ssm.MambaParams(
        w_in=v((d, 2 * d_in)), conv_w=v((cw, d_in)),
        w_bcdt=v((d_in, 2 * ds + H)), a_log=jnp.zeros((H, ds)),
        dt_bias=jnp.zeros((H,)), d_skip=jnp.ones((H,)), w_out=v((d_in, d)))


def test_mamba_scan_equals_stepwise():
    B, T, d, H, dh, ds, cw = 2, 11, 16, 2, 8, 4, 4
    d_in = H * dh
    p = make_mamba_params(jax.random.PRNGKey(0), d, d_in, H, ds, cw)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d), jnp.float32)
    y_full, (sT, convT) = ssm.mamba_scan(p, x)
    state = (jnp.zeros((B, H, dh, ds), jnp.float32),
             jnp.zeros((B, cw - 1, d_in), jnp.float32))
    ys = []
    for t in range(T):
        y, state = ssm.mamba_decode(p, x[:, t:t + 1], state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(state[0]),
                               rtol=2e-4, atol=2e-4)


def test_mamba_state_is_O1_in_seq():
    """State size independent of T (the sub-quadratic decode claim)."""
    B, d, H, dh, ds, cw = 1, 16, 2, 8, 4, 4
    d_in = H * dh
    p = make_mamba_params(jax.random.PRNGKey(2), d, d_in, H, ds, cw)
    for T in (4, 64):
        x = jax.random.normal(jax.random.PRNGKey(3), (B, T, d))
        _, (s, c) = ssm.mamba_scan(p, x)
        assert s.shape == (B, H, dh, ds)
        assert c.shape == (B, cw - 1, d_in)
