"""Attention paths: flash vs naive oracle, caches, ring buffers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.models.attention import (decode_attention, flash_attention,
                                    naive_attention, update_cache)


def make_qkv(seed, B, T, S, H, KV, D):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    return q, k, v


@given(st.integers(1, 3), st.integers(1, 70), st.sampled_from([1, 2, 4]),
       st.sampled_from([0, 5, 16]), st.booleans(), st.integers(0, 99))
def test_flash_matches_naive(B, T, qkv_ratio, window, causal, seed):
    H, KV, D = 4, 4 // qkv_ratio if 4 % qkv_ratio == 0 else 4, 16
    H = KV * qkv_ratio
    q, k, v = make_qkv(seed, B, T, T, H, KV, D)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    o1 = naive_attention(q, k, v, pos, pos, causal, window)
    o2 = flash_attention(q, k, v, pos, pos, causal, window,
                         q_block=16, k_block=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)


def test_flash_cross_attention_lengths():
    """Tq != Tk (encoder-decoder cross attention)."""
    B, T, S, H, KV, D = 2, 7, 33, 4, 2, 16
    q, k, v = make_qkv(0, B, T, S, H, KV, D)
    qpos = jnp.zeros((B, T), jnp.int32)
    kpos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o1 = naive_attention(q, k, v, qpos, kpos, causal=False)
    o2 = flash_attention(q, k, v, qpos, kpos, causal=False,
                         q_block=4, k_block=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [0, 8])
def test_incremental_decode_equals_full(window):
    """Prefill-then-decode token-by-token == one-shot causal attention."""
    B, T, H, KV, D = 2, 24, 4, 2, 16
    q, k, v = make_qkv(3, B, T, T, H, KV, D)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    full = naive_attention(q, k, v, pos, pos, True, window)
    ring = window > 0
    S = window if ring else T
    kc = jnp.zeros((B, S, KV, D))
    vc = jnp.zeros((B, S, KV, D))
    outs = []
    for t in range(T):
        kc, vc = update_cache(kc, vc, k[:, t:t + 1], v[:, t:t + 1],
                              jnp.full((B,), t), ring=ring)
        outs.append(decode_attention(q[:, t:t + 1], kc, vc,
                                     jnp.full((B,), t), window=window,
                                     ring=ring))
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc),
                               rtol=2e-4, atol=2e-4)


def test_batched_positions_decode():
    """Different sequence lengths per batch row (continuous batching)."""
    B, S, H, KV, D = 3, 32, 2, 2, 8
    q, k, v = make_qkv(5, B, 1, S, H, KV, D)
    kc = jnp.zeros((B, S, KV, D))
    vc = jnp.zeros((B, S, KV, D))
    positions = jnp.array([3, 17, 31])
    for b in range(B):
        for t in range(int(positions[b]) + 1):
            kb, vb = update_cache(kc[b:b+1], vc[b:b+1], k[b:b+1, t:t+1],
                                  v[b:b+1, t:t+1], jnp.array([t]))
            kc = kc.at[b:b+1].set(kb)
            vc = vc.at[b:b+1].set(vb)
    out = decode_attention(q, kc, vc, positions)
    for b in range(B):
        p = int(positions[b])
        pos_row = jnp.arange(p + 1)[None]
        ref = naive_attention(q[b:b+1], k[b:b+1, :p+1], v[b:b+1, :p+1],
                              jnp.array([[p]]), pos_row, True, 0)
        np.testing.assert_allclose(np.asarray(out[b:b+1]), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_fully_masked_rows_are_zero_not_nan():
    B, T, H, KV, D = 1, 4, 2, 2, 8
    q, k, v = make_qkv(9, B, T, T, H, KV, D)
    qpos = jnp.array([[0, 1, 2, 3]])
    kpos = jnp.array([[10, 11, 12, 13]])  # all in the future -> masked
    out = naive_attention(q, k, v, qpos, kpos, causal=True)
    assert not np.isnan(np.asarray(out)).any()
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
