"""Checkpoint roundtrip/atomicity + deterministic data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck
from repro.data.pipeline import (DataConfig, MemmapTokens, Prefetcher,
                                 SyntheticTokens)


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ck.save(tmp_path, 3, t)
    got, step = ck.restore(tmp_path, t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_complete_wins(tmp_path):
    t = tree()
    ck.save(tmp_path, 1, t)
    ck.save(tmp_path, 5, t)
    # simulate a crashed (incomplete) later write: tmp dir, no manifest
    (tmp_path / ".tmp_step_00000009").mkdir()
    assert ck.latest_step(tmp_path) == 5
    _, step = ck.restore(tmp_path, t)
    assert step == 5


def test_shape_mismatch_rejected(tmp_path):
    ck.save(tmp_path, 1, tree())
    bad = dict(tree(), a=jnp.zeros((3, 3)))
    with pytest.raises(ValueError, match="shape"):
        ck.restore(tmp_path, bad)


def test_async_checkpointer_gc(tmp_path):
    t = tree()
    saver = ck.AsyncCheckpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        saver.save(s, t)
    saver.wait()
    steps = sorted(d.name for d in tmp_path.iterdir()
                   if d.name.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_resumable():
    cfg = DataConfig(seq_len=8, global_batch=4, vocab_size=100)
    src = SyntheticTokens(cfg)
    b1 = src.batch(17)
    b2 = src.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(src.batch(18)["tokens"], b1["tokens"])
    # labels shifted by one against the token stream
    full = np.concatenate([b1["tokens"][:, :1], b1["labels"]], axis=1)
    np.testing.assert_array_equal(full[:, 1:], b1["labels"])


def test_host_sharding_disjoint_cover():
    cfg = DataConfig(seq_len=8, global_batch=8, vocab_size=50)
    src = SyntheticTokens(cfg)
    full = src.batch(3)["tokens"]
    parts = [src.host_batch(3, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_memmap_pipeline(tmp_path):
    corpus = np.arange(10000, dtype=np.int32) % 97
    path = tmp_path / "corpus.bin"
    MemmapTokens.write_corpus(path, corpus)
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=97)
    src = MemmapTokens(path, cfg)
    b = src.batch(0)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    np.testing.assert_array_equal(src.batch(5)["tokens"],
                                  src.batch(5)["tokens"])


def test_prefetcher_order():
    cfg = DataConfig(seq_len=4, global_batch=2, vocab_size=10)
    src = SyntheticTokens(cfg)
    pf = Prefetcher(src, start_step=10, depth=2)
    try:
        for want in (10, 11, 12):
            s, batch = next(pf)
            assert s == want
            np.testing.assert_array_equal(batch["tokens"],
                                          src.host_batch(want)["tokens"])
    finally:
        pf.close()
