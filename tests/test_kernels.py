"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.kernels.pq_adc.ops import pq_adc_topk, pq_shared_scan
from repro.kernels.pq_adc.ref import ref_adc
from repro.kernels.ivf_scan.ops import ivf_index_scan
from repro.kernels.ivf_scan.ref import ref_ivf_scan


# ---------------------------------------------------------------------------
# pq_adc
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbits", [4, 8])
@pytest.mark.parametrize("m", [4, 16, 32])
@pytest.mark.parametrize("n", [128, 1000, 2048])
def test_adc_topk_shape_sweep(nbits, m, n):
    ksub = 1 << nbits
    B, k = 3, 10
    key = jax.random.PRNGKey(m * n + nbits)
    luts = jax.random.normal(key, (B, m, ksub), jnp.float32)
    codes = jax.random.randint(jax.random.PRNGKey(1), (B, n, m), 0, ksub,
                               jnp.uint8)
    lens = jnp.array([n, max(n // 2, 1), min(k - 1, n)], jnp.int32)
    dp, ip = pq_adc_topk(luts, codes, lens, k, tile_n=256, backend="pallas")
    dr, ir = pq_adc_topk(luts, codes, lens, k, tile_n=256, backend="ref")
    finite = np.isfinite(np.asarray(dr))
    np.testing.assert_allclose(np.asarray(dp)[finite], np.asarray(dr)[finite],
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(ip) == np.asarray(ir)).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adc_dtype(dtype):
    B, n, m, ksub, k = 2, 512, 8, 16, 5
    luts = jax.random.normal(jax.random.PRNGKey(0), (B, m, ksub), dtype)
    codes = jax.random.randint(jax.random.PRNGKey(1), (B, n, m), 0, ksub,
                               jnp.uint8)
    lens = jnp.full((B,), n, jnp.int32)
    dp, _ = pq_adc_topk(luts, codes, lens, k, backend="pallas")
    dr, _ = pq_adc_topk(luts, codes, lens, k, backend="ref")
    np.testing.assert_allclose(np.asarray(dp, np.float32),
                               np.asarray(dr, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2)


@given(st.integers(1, 64), st.integers(0, 100))
def test_adc_single_matches_manual(n_rows, seed):
    """Tiny-case oracle vs hand-rolled python loop."""
    m, ksub = 4, 16
    rng = np.random.default_rng(seed)
    lut = rng.normal(size=(m, ksub)).astype(np.float32)
    codes = rng.integers(0, ksub, size=(n_rows, m)).astype(np.uint8)
    want = np.array([sum(lut[j, codes[i, j]] for j in range(m))
                     for i in range(n_rows)])
    got = ref_adc(jnp.asarray(lut), jnp.asarray(codes))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


@pytest.mark.parametrize("q,n,m,ksub", [(4, 512, 8, 16), (16, 300, 16, 16),
                                        (2, 128, 4, 256)])
def test_shared_scan_sweep(q, n, m, ksub):
    luts = jax.random.normal(jax.random.PRNGKey(0), (q, m, ksub), jnp.float32)
    codes = jax.random.randint(jax.random.PRNGKey(1), (n, m), 0, ksub,
                               jnp.uint8)
    sp = pq_shared_scan(luts, codes, tile_n=128, backend="pallas")
    sr = pq_shared_scan(luts, codes, tile_n=128, backend="ref")
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# ivf_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nq,nlist,d,nprobe", [
    (8, 512, 64, 16), (16, 1024, 128, 32), (4, 128, 32, 8)])
def test_ivf_scan_sweep(nq, nlist, d, nprobe):
    q = jax.random.normal(jax.random.PRNGKey(0), (nq, d))
    c = jax.random.normal(jax.random.PRNGKey(1), (nlist, d))
    dp, ip = ivf_index_scan(q, c, nprobe, backend="pallas")
    dr, ir = ref_ivf_scan(q, c, nprobe)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr), rtol=1e-4,
                               atol=1e-4)
    assert (np.asarray(ip) == np.asarray(ir)).all()


def test_ivf_scan_returns_true_l2():
    q = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    c = jax.random.normal(jax.random.PRNGKey(3), (128, 16))
    dp, ip = ivf_index_scan(q, c, 4, backend="pallas")
    manual = np.sum((np.asarray(q)[:, None] - np.asarray(c)[None]) ** 2, -1)
    want = np.sort(manual, axis=1)[:, :4]
    np.testing.assert_allclose(np.asarray(dp), want, rtol=1e-4, atol=1e-4)


def test_ivf_scan_small_nlist_fallback_warns_once():
    """backend="pallas" with nlist < PALLAS_MIN_NLIST routes to the ref
    scan — loudly, exactly once per process, with correct results."""
    import warnings

    from repro.kernels.ivf_scan import ops

    ops._pallas_fallback_warned = False
    q = jax.random.normal(jax.random.PRNGKey(4), (3, 16))
    c = jax.random.normal(jax.random.PRNGKey(5), (ops.PALLAS_MIN_NLIST // 2,
                                                  16))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        dp, ip = ivf_index_scan(q, c, 4, backend="pallas")
        # second call with a fresh shape retraces; still only one warning
        ivf_index_scan(q[:2], c, 4, backend="pallas")
    msgs = [w for w in caught if "PALLAS_MIN_NLIST" in str(w.message)]
    assert len(msgs) == 1 and issubclass(msgs[0].category, RuntimeWarning)
    dr, ir = ref_ivf_scan(q, c, 4)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr), rtol=1e-5,
                               atol=1e-5)
    assert (np.asarray(ip) == np.asarray(ir)).all()
