"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.kernels import registry
from repro.kernels.registry import PALLAS_INTERPRET, REF, KernelSpec
from repro.kernels.pq_adc.ops import pq_adc_topk, pq_shared_scan
from repro.kernels.pq_adc.ref import ref_adc
from repro.kernels.ivf_scan.ops import ivf_index_scan
from repro.kernels.ivf_scan.ref import ref_ivf_scan


# ---------------------------------------------------------------------------
# pq_adc
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbits", [4, 8])
@pytest.mark.parametrize("m", [4, 16, 32])
@pytest.mark.parametrize("n", [128, 1000, 2048])
def test_adc_topk_shape_sweep(nbits, m, n):
    ksub = 1 << nbits
    B, k = 3, 10
    key = jax.random.PRNGKey(m * n + nbits)
    luts = jax.random.normal(key, (B, m, ksub), jnp.float32)
    codes = jax.random.randint(jax.random.PRNGKey(1), (B, n, m), 0, ksub,
                               jnp.uint8)
    lens = jnp.array([n, max(n // 2, 1), min(k - 1, n)], jnp.int32)
    dp, ip = pq_adc_topk(luts, codes, lens, k, tile_n=256,
                         spec=PALLAS_INTERPRET)
    dr, ir = pq_adc_topk(luts, codes, lens, k, tile_n=256, spec=REF)
    finite = np.isfinite(np.asarray(dr))
    np.testing.assert_allclose(np.asarray(dp)[finite], np.asarray(dr)[finite],
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(ip) == np.asarray(ir)).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_adc_dtype(dtype):
    B, n, m, ksub, k = 2, 512, 8, 16, 5
    luts = jax.random.normal(jax.random.PRNGKey(0), (B, m, ksub), dtype)
    codes = jax.random.randint(jax.random.PRNGKey(1), (B, n, m), 0, ksub,
                               jnp.uint8)
    lens = jnp.full((B,), n, jnp.int32)
    dp, _ = pq_adc_topk(luts, codes, lens, k, spec=PALLAS_INTERPRET)
    dr, _ = pq_adc_topk(luts, codes, lens, k, spec=REF)
    np.testing.assert_allclose(np.asarray(dp, np.float32),
                               np.asarray(dr, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2)


@given(st.integers(1, 64), st.integers(0, 100))
def test_adc_single_matches_manual(n_rows, seed):
    """Tiny-case oracle vs hand-rolled python loop."""
    m, ksub = 4, 16
    rng = np.random.default_rng(seed)
    lut = rng.normal(size=(m, ksub)).astype(np.float32)
    codes = rng.integers(0, ksub, size=(n_rows, m)).astype(np.uint8)
    want = np.array([sum(lut[j, codes[i, j]] for j in range(m))
                     for i in range(n_rows)])
    got = ref_adc(jnp.asarray(lut), jnp.asarray(codes))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


@pytest.mark.parametrize("q,n,m,ksub", [(4, 512, 8, 16), (16, 300, 16, 16),
                                        (2, 128, 4, 256)])
def test_shared_scan_sweep(q, n, m, ksub):
    luts = jax.random.normal(jax.random.PRNGKey(0), (q, m, ksub), jnp.float32)
    codes = jax.random.randint(jax.random.PRNGKey(1), (n, m), 0, ksub,
                               jnp.uint8)
    sp = pq_shared_scan(luts, codes, tile_n=128, spec=PALLAS_INTERPRET)
    sr = pq_shared_scan(luts, codes, tile_n=128, spec=REF)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# ivf_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nq,nlist,d,nprobe", [
    (8, 512, 64, 16), (16, 1024, 128, 32), (4, 128, 32, 8)])
def test_ivf_scan_sweep(nq, nlist, d, nprobe):
    q = jax.random.normal(jax.random.PRNGKey(0), (nq, d))
    c = jax.random.normal(jax.random.PRNGKey(1), (nlist, d))
    dp, ip = ivf_index_scan(q, c, nprobe, spec=PALLAS_INTERPRET)
    dr, ir = ref_ivf_scan(q, c, nprobe)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr), rtol=1e-4,
                               atol=1e-4)
    assert (np.asarray(ip) == np.asarray(ir)).all()


def test_ivf_scan_returns_true_l2():
    q = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    c = jax.random.normal(jax.random.PRNGKey(3), (128, 16))
    dp, ip = ivf_index_scan(q, c, 4, spec=PALLAS_INTERPRET)
    manual = np.sum((np.asarray(q)[:, None] - np.asarray(c)[None]) ** 2, -1)
    want = np.sort(manual, axis=1)[:, :4]
    np.testing.assert_allclose(np.asarray(dp), want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# the kernel registry: fallback accounting + deprecated aliases
# ---------------------------------------------------------------------------

def test_ivf_scan_small_nlist_fallback_warns_once():
    """spec.backend="pallas" with nlist < PALLAS_MIN_NLIST routes to the
    ref scan — loudly, exactly once per registry-reset interval, counted
    in the registry, with correct results."""
    from repro.kernels.ivf_scan import ops

    q = jax.random.normal(jax.random.PRNGKey(4), (3, 16))
    c = jax.random.normal(jax.random.PRNGKey(5), (ops.PALLAS_MIN_NLIST // 2,
                                                  16))
    assert registry.fallback_count("ivf_index_scan") == 0
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        dp, ip = ivf_index_scan(q, c, 4, spec=PALLAS_INTERPRET)
        # second call with a fresh shape re-decides; still only one warning
        ivf_index_scan(q[:2], c, 4, spec=PALLAS_INTERPRET)
    msgs = [w for w in caught if "PALLAS_MIN_NLIST" in str(w.message)]
    assert len(msgs) == 1 and issubclass(msgs[0].category, RuntimeWarning)
    # ...but every routing decision is counted
    assert registry.fallback_count("ivf_index_scan") == 2
    assert registry.fallback_count() == 2
    dr, ir = ref_ivf_scan(q, c, 4)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr), rtol=1e-5,
                               atol=1e-5)
    assert (np.asarray(ip) == np.asarray(ir)).all()


def test_registry_reset_rearms_warning():
    """reset_warnings() re-arms the one-time warning and zeroes the
    counters (the conftest fixture calls it around every test, so the
    old module-global 'warned once per process' flag can't leak)."""
    from repro.kernels.ivf_scan import ops

    q = jax.random.normal(jax.random.PRNGKey(6), (2, 8))
    c = jax.random.normal(jax.random.PRNGKey(7), (ops.PALLAS_MIN_NLIST // 4,
                                                  8))
    for _ in range(2):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            ivf_index_scan(q, c, 2, spec=PALLAS_INTERPRET)
        assert sum("PALLAS_MIN_NLIST" in str(w.message) for w in caught) == 1
        assert registry.fallback_count("ivf_index_scan") == 1
        registry.reset_warnings()
    assert registry.fallback_count() == 0


def test_fallback_error_policy_raises():
    """fallback="error" turns a silent ref detour into a hard failure —
    deployment configs that must never serve ref numbers as pallas."""
    from repro.kernels.ivf_scan import ops

    q = jax.random.normal(jax.random.PRNGKey(8), (2, 8))
    c = jax.random.normal(jax.random.PRNGKey(9), (ops.PALLAS_MIN_NLIST // 4,
                                                  8))
    strict = KernelSpec(backend="pallas", fallback="error")
    with pytest.raises(registry.KernelFallbackError):
        ivf_index_scan(q, c, 2, spec=strict)


def test_deprecated_backend_kwargs_still_route():
    """The legacy backend=/interpret= kwargs keep working as deprecated
    aliases (warning once per op) and return identical results."""
    q = jax.random.normal(jax.random.PRNGKey(10), (4, 16))
    c = jax.random.normal(jax.random.PRNGKey(11), (128, 16))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        d_old, i_old = ivf_index_scan(q, c, 4, backend="pallas",
                                      interpret=True)
        ivf_index_scan(q, c, 4, backend="pallas")   # second: no new warning
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1 and "spec=" in str(deps[0].message)
    d_new, i_new = ivf_index_scan(q, c, 4, spec=PALLAS_INTERPRET)
    np.testing.assert_array_equal(np.asarray(i_old), np.asarray(i_new))
    np.testing.assert_allclose(np.asarray(d_old), np.asarray(d_new))


def test_legacy_positional_backend_string_still_routes():
    """The old signatures had ``backend`` where ``spec`` now sits; a
    bare string in that slot must behave as the deprecated alias, not
    crash with AttributeError downstream."""
    q = jax.random.normal(jax.random.PRNGKey(14), (4, 16))
    c = jax.random.normal(jax.random.PRNGKey(15), (128, 16))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        d_pos, i_pos = ivf_index_scan(q, c, 4, "pallas")
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    d_new, i_new = ivf_index_scan(q, c, 4, spec=PALLAS_INTERPRET)
    np.testing.assert_array_equal(np.asarray(i_pos), np.asarray(i_new))
    np.testing.assert_allclose(np.asarray(d_pos), np.asarray(d_new))


def test_kernel_spec_validation_and_tiles():
    with pytest.raises(ValueError):
        KernelSpec(backend="cuda")
    with pytest.raises(ValueError):
        KernelSpec(fallback="whatever")
    s = KernelSpec()
    assert s.pick_tile_q(16) == 8 and s.pick_tile_q(12) == 4 \
        and s.pick_tile_q(7) == 1
    assert s.pick_tile_c(1024) == 512 and s.pick_tile_c(256) == 128 \
        and s.pick_tile_c(96) == 96
    assert s.pick_tile_n(4096) == 512 and s.pick_tile_n(64) == 128
    assert KernelSpec(tile_q=4).pick_tile_q(16) == 4
    # explicit overrides that don't divide the axis round DOWN to a
    # legal tile instead of tripping the kernels' grid asserts
    assert KernelSpec(tile_q=8).pick_tile_q(12) == 6
    assert KernelSpec(tile_q=5).pick_tile_q(7) == 1
    assert KernelSpec(tile_c=100).pick_tile_c(128) == 64


def test_explicit_nondivisor_tile_override_still_runs():
    q = jax.random.normal(jax.random.PRNGKey(12), (12, 16))
    c = jax.random.normal(jax.random.PRNGKey(13), (128, 16))
    spec = KernelSpec(backend="pallas", tile_q=8, tile_c=100)
    dp, ip = ivf_index_scan(q, c, 4, spec=spec)
    dr, ir = ref_ivf_scan(q, c, 4)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr), rtol=1e-4,
                               atol=1e-4)
    assert (np.asarray(ip) == np.asarray(ir)).all()
