"""End-to-end behaviour: the paper's core functional claims on a small scale.

1. kNN-LM retrieval IMPROVES next-token prediction when the database
   contains the evaluation contexts (the RALM premise, paper §1-2).
2. The full generation loop runs with retrieval at the configured interval.
3. The disaggregated runtime produces the same tokens as the monolithic
   loop (disaggregation is a systems transform, not a model change).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.chamvs import ChamVSConfig
from repro.core.generate import RetrievalEngine, generate
from repro.core.ivfpq import IVFPQConfig, build_shards, train_ivfpq
from repro.core.rag import RagConfig, knnlm_interpolate
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def ralm_setup():
    """Tiny decoder LM + DB built from its own hidden states over a corpus
    with strong bigram structure (so neighbors are informative)."""
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # deterministic-bigram corpus: token t is followed by (3t+1) mod 64
    start = rng.integers(0, 64, size=(64,))
    corpus = [start]
    for _ in range(31):
        corpus.append((3 * corpus[-1] + 1) % 64)
    corpus = np.stack(corpus, axis=1).astype(np.int32)     # [64, 32]

    # datastore: hidden state of every prefix -> next token (kNN-LM)
    toks = jnp.asarray(corpus)
    _, _, hidden = tf.forward(params, cfg, tokens=toks, mode="train",
                              return_hidden=True)
    keys = np.asarray(hidden[:, :-1].astype(jnp.float32)).reshape(
        -1, cfg.d_model)
    nxt = np.asarray(corpus[:, 1:]).reshape(-1)
    icfg = IVFPQConfig(dim=cfg.d_model, nlist=8, m=8, list_cap=512,
                       residual=True)
    db_params = train_ivfpq(jax.random.PRNGKey(1), jnp.asarray(keys), icfg,
                            kmeans_iters=8)
    shards = build_shards(db_params, keys, icfg, num_shards=2)
    ccfg = ChamVSConfig(ivfpq=icfg, nprobe=4, k=8, backend="ref")
    engine = RetrievalEngine(params=db_params, shards=shards, cfg=ccfg,
                             payload_tokens=jnp.asarray(nxt))
    return cfg, params, corpus, engine


def test_knnlm_improves_nll(ralm_setup):
    """Retrieval-augmented NLL < pure-LM NLL on the memorized corpus —
    the reason RALMs beat much larger plain LMs (paper §1)."""
    cfg, params, corpus, engine = ralm_setup
    toks = jnp.asarray(corpus[:16])
    logits, _, hidden = tf.forward(params, cfg, tokens=toks, mode="train",
                                   return_hidden=True)
    # score position T-2 -> label T-1 for every row
    q = hidden[:, -2].astype(jnp.float32)
    labels = toks[:, -1]
    d, i = engine.search(q)
    knn_tok = jnp.where(i >= 0, engine.payload_tokens[jnp.maximum(i, 0)], -1)
    lm_lp = jax.nn.log_softmax(logits[:, -2].astype(jnp.float32), -1)
    mixed = knnlm_interpolate(logits[:, -2], d, knn_tok, lam=0.5,
                              temperature=10.0)
    nll_lm = -float(jnp.take_along_axis(lm_lp, labels[:, None], 1).mean())
    nll_knn = -float(jnp.take_along_axis(mixed, labels[:, None], 1).mean())
    assert nll_knn < nll_lm - 0.3, (nll_knn, nll_lm)


def test_generation_with_retrieval_runs(ralm_setup):
    cfg, params, corpus, engine = ralm_setup
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.5)
    prompt = jnp.asarray(corpus[:2, :4])
    trace = []
    out = generate(params, cfg, rag, prompt, steps=6, engine=engine,
                   trace=trace)
    assert out.shape == (2, 10)
    assert len(trace) == 6                      # interval-1: every step
    assert (np.asarray(out) >= 0).all()


def test_generation_interval_schedule(ralm_setup):
    cfg, params, corpus, engine = ralm_setup
    rag = RagConfig(mode="knnlm", interval=4, k=8)
    trace = []
    generate(params, cfg, rag, jnp.asarray(corpus[:1, :4]), steps=8,
             engine=engine, trace=trace)
    assert [t["step"] for t in trace] == [0, 4]


def test_knnlm_generation_reproduces_corpus(ralm_setup):
    """With lam≈1, generation must follow the memorized bigram chain even
    though the LM itself is untrained — retrieval carries the knowledge
    (the paper's knowledge-editing story)."""
    cfg, params, corpus, engine = ralm_setup
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    prompt = jnp.asarray(corpus[:4, :8])
    out = np.asarray(generate(params, cfg, rag, prompt, steps=8,
                              engine=engine))
    want = corpus[:4, :16]
    acc = (out[:, 8:] == want[:, 8:]).mean()
    assert acc > 0.8, acc
