"""The approximate hierarchical priority queue (paper §4.2.2, Figs. 7/8)."""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, strategies as st

from repro.core.approx_topk_math import (binom_pmf,
                                         queue_overflow_prob,
                                         resource_saving,
                                         truncated_queue_len)
from repro.kernels import registry
from repro.kernels.registry import PALLAS_INTERPRET, REF
from repro.kernels.topk.ops import approx_topk
from repro.kernels.topk.ref import ref_exact_topk


def test_binomial_matches_monte_carlo():
    """p(k) formula from the paper (§4.2.2) vs simulation."""
    rng = np.random.default_rng(0)
    K, nq, trials = 100, 16, 3000
    counts = np.zeros(K + 1)
    for _ in range(trials):
        q = rng.integers(0, nq, size=K)
        counts[np.bincount(q, minlength=nq).max()] += 1
    # P[a FIXED queue holds k] ~ binom; check the pmf over one queue
    one = np.zeros(K + 1)
    for _ in range(trials):
        one[(rng.integers(0, nq, size=K) == 0).sum()] += 1
    one /= trials
    for k in range(0, 20):
        assert abs(one[k] - binom_pmf(K, 1 / nq, k)) < 0.03


def test_paper_fig7_truncation_claim():
    """Paper: with 16 L1 queues and K=100, queues can truncate to ~20 while
    keeping >=99% of queries exact; our (conservative, union-bound) sizing
    must land at or below that and above the mean K/nq."""
    kp = truncated_queue_len(100, 16, eps=0.01)
    assert 100 / 16 < kp <= 20, kp
    assert queue_overflow_prob(100, 16, kp) <= 0.01
    assert queue_overflow_prob(100, 16, kp - 1) > 0.01  # minimality


def test_fig8_resource_saving_order_of_magnitude():
    """Fig. 8: saving grows with queue count, reaching ~an order of
    magnitude for many producers."""
    savings = [resource_saving(100, nq) for nq in (2, 8, 32, 128)]
    assert all(b >= a for a, b in zip(savings, savings[1:]))
    assert savings[-1] >= 8.0, savings


def test_overflow_prob_observed():
    """Empirical failure rate of truncated queues <= the bound."""
    rng = np.random.default_rng(1)
    K, nq = 50, 8
    kp = truncated_queue_len(K, nq, eps=0.05)
    fails = 0
    trials = 2000
    for _ in range(trials):
        owners = rng.integers(0, nq, size=K)
        if np.bincount(owners, minlength=nq).max() > kp:
            fails += 1
    assert fails / trials <= 0.05 + 0.02


@given(st.integers(1, 40), st.sampled_from([4, 8, 16]),
       st.integers(0, 1000))
def test_kernel_matches_approx_oracle(k, nblocks, seed):
    d = jax.random.normal(jax.random.PRNGKey(seed), (8, 512))
    dp, ip = approx_topk(d, k, num_blocks=nblocks, spec=PALLAS_INTERPRET)
    dr, ir = approx_topk(d, k, num_blocks=nblocks, spec=REF)
    np.testing.assert_array_equal(np.asarray(dp), np.asarray(dr))
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))


def test_kernel_exactness_rate():
    """Across random rows, truncated result == exact result for >= 1-eps
    of rows (the paper's 99% design point)."""
    d = jax.random.normal(jax.random.PRNGKey(7), (256, 2048))
    k, nb = 100, 16
    da, _ = approx_topk(d, k, num_blocks=nb, eps=0.01,
                        spec=PALLAS_INTERPRET)
    de, _ = ref_exact_topk(d, k)
    row_exact = np.all(np.asarray(da) == np.asarray(de), axis=1)
    assert row_exact.mean() >= 0.99, row_exact.mean()


def test_inf_padding_semantics():
    d = jnp.full((8, 256), jnp.inf).at[:, :3].set(
        jnp.arange(3, dtype=jnp.float32))
    dd, ii = approx_topk(d, 5, num_blocks=4, spec=PALLAS_INTERPRET)
    assert (np.asarray(ii[:, 3:]) == -1).all()
    np.testing.assert_array_equal(np.asarray(ii[:, :3]),
                                  np.tile(np.arange(3), (8, 1)))


def test_degenerate_tile_fallback_is_surfaced():
    """Satellite: the degenerate-tile route to the exact reference path
    used to be silent — "pallas" benchmark numbers could really be ref
    numbers. It now warns once and bumps the registry counter (while
    still returning the exact result)."""
    import warnings

    d = jax.random.normal(jax.random.PRNGKey(3), (4, 500))   # 500 % 16 != 0
    assert registry.fallback_count("approx_topk") == 0
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        da, ia = approx_topk(d, 10, num_blocks=16, spec=PALLAS_INTERPRET)
        approx_topk(d, 10, num_blocks=16, spec=PALLAS_INTERPRET)
    msgs = [w for w in caught if "degenerate tiling" in str(w.message)]
    assert len(msgs) == 1 and issubclass(msgs[0].category, RuntimeWarning)
    assert registry.fallback_count("approx_topk") == 2
    de, ie = ref_exact_topk(d, 10)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(de))
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ie))
    # a ref-backend request for the same shape is NOT a fallback
    registry.reset_warnings()
    approx_topk(d, 10, num_blocks=16, spec=REF)
    assert registry.fallback_count("approx_topk") == 0
