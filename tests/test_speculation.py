"""Speculative retrieval (RaLMSpec, arXiv 2401.14021): decode ahead on
stale neighbors, verify against the landed search, roll back on
mismatch.

The load-bearing claim is GREEDY PARITY: with verification on, a
speculating engine must emit token-identical sequences to the same
engine with speculation off, for every (interval, depth, admission
stagger, lam) — acceptance merely decides how much latency gets hidden,
never what gets emitted. The bigram corpus here is deliberately
speculation-hostile (consecutive queries retrieve different payload
tokens, so almost every point rolls back), which makes it the strongest
parity fixture: the rollback/replay path runs constantly and must still
reproduce the baseline stream.

Also covered: the KV-pool rewind contract (bookkeeping-only rollback +
replay == fresh decode; hard rejections for recurrent and deep-ring
rewinds), the stale-tolerant partial-hit query cache, the service-level
partial-batch stitch, degrade-ladder speculation flush, and the
``speculation`` stats plane.
"""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import get_arch
from repro.models import transformer as tf
from repro.retrieval import QueryCache, RetrievalService, ServiceConfig
from repro.serve import (DatastoreBuilder, RagConfig, RalmEngine,
                         RalmRequest)
from repro.serve.gateway import DegradePolicy


@pytest.fixture(scope="module")
def tiny_ralm():
    """Tiny decoder LM + datastore over a deterministic-bigram corpus
    (token t -> (3t+1) mod 64) — same fixture family as
    tests/test_serve.py."""
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    start = rng.integers(0, 64, size=(64,))
    corpus = [start]
    for _ in range(31):
        corpus.append((3 * corpus[-1] + 1) % 64)
    corpus = np.stack(corpus, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                          list_cap=512).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    return cfg, params, corpus, ds, ccfg, rag


def _build(tiny, spec_k, *, lam=None, interval=None, verify=True,
           cache=0):
    cfg, params, _, ds, ccfg, rag = tiny
    if lam is not None:
        rag = dataclasses.replace(rag, lam=lam)
    if interval is not None:
        rag = dataclasses.replace(rag, interval=interval)
    ret = ds.async_retriever(ccfg, service_cfg=ServiceConfig(
        measure=False, cache_entries=cache))
    return RalmEngine.monolithic(params, cfg, rag, retriever=ret,
                                 speculate_k=spec_k,
                                 speculate_verify=verify)


def _run(eng, prompts, steps=8, stagger=0):
    """Submit ``prompts`` (the first immediately, the rest after
    ``stagger`` scheduler steps — staggered admission means waves mix
    sequences at different depths) and return tokens per request in
    submission order."""
    done = []
    rids = [eng.submit(RalmRequest(prompt=prompts[0], steps=steps))]
    for _ in range(stagger):
        done += eng.step()
    rids += [eng.submit(RalmRequest(prompt=p, steps=steps))
             for p in prompts[1:]]
    done += eng.run()
    by_id = {r.request_id: np.asarray(r.tokens) for r in done}
    return [by_id[r] for r in rids]


def _prompts(corpus, n=2):
    return [jnp.asarray(corpus[2 * i:2 * i + 2, :4]) for i in range(n)]


# ---------------------------------------------------------------------------
# greedy parity: speculation + verification == speculation off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_k", [1, 2])
def test_greedy_parity(tiny_ralm, spec_k):
    prompts = _prompts(tiny_ralm[2])
    base = _run(_build(tiny_ralm, 0), prompts)
    eng = _build(tiny_ralm, spec_k)
    spec = _run(eng, prompts)
    for a, b in zip(base, spec):
        np.testing.assert_array_equal(a, b)
    st_ = eng.spec_stats
    assert st_.spec_issued > 0 and st_.spec_verified > 0
    assert st_.spec_accepted + st_.spec_rollbacks == st_.spec_verified


def test_greedy_parity_lm_dominant_mix(tiny_ralm):
    """Low lam: the LM logits dominate the mix, so accept/reject flips
    on small distance changes — parity must survive the rollbacks."""
    prompts = _prompts(tiny_ralm[2])
    base = _run(_build(tiny_ralm, 0, lam=0.25), prompts)
    eng = _build(tiny_ralm, 1, lam=0.25)
    spec = _run(eng, prompts)
    for a, b in zip(base, spec):
        np.testing.assert_array_equal(a, b)
    assert eng.spec_stats.spec_verified > 0


@pytest.mark.parametrize("interval,spec_k,stagger", [
    (1, 1, 2),     # every step due, waves at mixed depths
    (2, 2, 1),     # sparse retrieval, deeper outstanding window
    (3, 1, 0),     # interval coprime with the wave count
])
def test_greedy_parity_staggered(tiny_ralm, interval, spec_k, stagger):
    prompts = _prompts(tiny_ralm[2])
    base = _run(_build(tiny_ralm, 0, interval=interval), prompts,
                steps=9, stagger=stagger)
    eng = _build(tiny_ralm, spec_k, interval=interval)
    spec = _run(eng, prompts, steps=9, stagger=stagger)
    for a, b in zip(base, spec):
        np.testing.assert_array_equal(a, b)


_BASELINES = {}


@given(st.integers(1, 3), st.integers(1, 2), st.integers(0, 2),
       st.sampled_from([0.999, 0.5]))
def test_greedy_parity_random(tiny_ralm, interval, spec_k, stagger, lam):
    """Property form of the parity claim over random (interval, depth,
    stagger, lam) corners. Baselines are memoized per corner — the
    speculating engine is the subject under test."""
    key = (interval, stagger, lam)
    if key not in _BASELINES:
        _BASELINES[key] = _run(
            _build(tiny_ralm, 0, lam=lam, interval=interval),
            _prompts(tiny_ralm[2]), steps=7, stagger=stagger)
    eng = _build(tiny_ralm, spec_k, lam=lam, interval=interval)
    spec = _run(eng, _prompts(tiny_ralm[2]), steps=7, stagger=stagger)
    for a, b in zip(_BASELINES[key], spec):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# forced mismatch: rollback replay == the per-sequence oracle
# ---------------------------------------------------------------------------

def test_forced_mismatch_rollback_matches_oracle(tiny_ralm):
    """Poison every speculation seed with garbage neighbors (dists 0,
    ids 0 — a constant wrong payload token) so verification must reject
    and roll back, then check the emitted stream still equals the
    per-sequence oracle engine (wave=False, blocking searches)."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    prompt = jnp.asarray(corpus[0:2, :4])

    oracle_eng = RalmEngine.monolithic(params, cfg, rag,
                                       retriever=ds.retriever(ccfg),
                                       wave=False)
    oracle = np.asarray(oracle_eng.generate(prompt, steps=8))

    eng = _build(tiny_ralm, 1)
    eng.submit(RalmRequest(prompt=prompt, steps=8))
    done = []
    while eng.scheduler.has_work:
        done += eng.step()
        for seq in eng.scheduler.active:
            if seq.last_neighbors is not None:
                d, i = seq.last_neighbors
                seq.last_neighbors = (jnp.zeros_like(d),
                                      jnp.zeros_like(i))
    np.testing.assert_array_equal(oracle, np.asarray(done[0].tokens))
    st_ = eng.spec_stats
    assert st_.spec_rollbacks >= 1
    assert st_.spec_replayed_steps >= 0   # depth-1 replays can be empty
    assert st_.spec_replay.count == st_.spec_rollbacks


def test_no_verify_adopts_stale_neighbors(tiny_ralm):
    """verify=False trusts the speculated tokens outright: no
    rollbacks ever, and on this corpus (stale != real almost always)
    the stream is allowed to drift from baseline."""
    eng = _build(tiny_ralm, 1, verify=False)
    _run(eng, _prompts(tiny_ralm[2]))
    st_ = eng.spec_stats
    assert st_.spec_issued > 0
    assert st_.spec_rollbacks == 0 and st_.spec_verified == 0


# ---------------------------------------------------------------------------
# eligibility gates
# ---------------------------------------------------------------------------

def test_speculation_requires_wave_decode(tiny_ralm):
    cfg, params, _, ds, ccfg, rag = tiny_ralm
    ret = ds.async_retriever(ccfg,
                             service_cfg=ServiceConfig(measure=False))
    with pytest.warns(RuntimeWarning, match="wave"):
        eng = RalmEngine.monolithic(params, cfg, rag, retriever=ret,
                                    wave=False, speculate_k=1)
    assert eng.speculate_k == 0


def test_sampled_requests_never_speculate(tiny_ralm):
    """Sampling consumes rng state a rollback cannot restore — the
    per-row gate must keep sampled requests on the blocking path."""
    _, _, corpus, _, _, _ = tiny_ralm
    eng = _build(tiny_ralm, 1)
    eng.submit(RalmRequest(prompt=jnp.asarray(corpus[0:2, :4]), steps=6,
                           greedy=False, rng=jax.random.PRNGKey(7)))
    eng.run()
    assert eng.spec_stats.spec_issued == 0


# ---------------------------------------------------------------------------
# KV-pool rewind
# ---------------------------------------------------------------------------

def _force(eng, seq, toks):
    """Teacher-forced wave decode: consume ``seq.cur``, record the
    logits, emit the forced token. Returns host logits per step."""
    outs = []
    for t in toks:
        logits, _ = eng.dispatch_wave([seq])[0]
        outs.append(np.asarray(logits))
        eng._emit(seq, jnp.full((seq.cur.shape[0],), t, jnp.int32))
    return outs


def test_kvpool_rewind_replay_matches_fresh_decode(tiny_ralm):
    """Rewind is bookkeeping-only for linear caches: after rewinding a
    3-step speculation and replaying a DIFFERENT continuation, the
    logits must match a fresh sequence that decoded that continuation
    from scratch."""
    cfg, params, corpus, _, _, _ = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, RagConfig(mode="none"))
    prompt = jnp.asarray(corpus[0:2, :4])

    seq = eng.start(RalmRequest(prompt=prompt, steps=8))
    _force(eng, seq, [7, 11, 13, 17])       # step 0 + speculated 1..3
    assert seq.step == 4
    t0 = seq.t0
    eng.pool.rewind(seq.slots, keep_len=t0 + 1, old_len=t0 + 3)
    seq.step = 2                             # roll back to after token 7
    seq.cur = jnp.full((2, 1), 21, jnp.int32)
    replayed = _force(eng, seq, [23, 29])

    fresh = eng.start(RalmRequest(prompt=prompt, steps=8))
    ref = _force(eng, fresh, [7, 21, 23, 29])
    assert np.allclose(replayed[0], ref[2]) and \
        np.allclose(replayed[1], ref[3])
    ps = eng.pool.stats
    assert ps.rewinds == 1 and ps.rewound_tokens == 2 * 2


def test_kvpool_rewind_rejections(tiny_ralm):
    cfg, params, corpus, _, _, _ = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, RagConfig(mode="none"))
    seq = eng.start(RalmRequest(prompt=jnp.asarray(corpus[0:2, :4]),
                                steps=4))
    pool = eng.pool
    with pytest.raises(ValueError, match="keep_len"):
        pool.rewind(seq.slots, keep_len=0, old_len=4)
    with pytest.raises(ValueError, match="keep_len"):
        pool.rewind(seq.slots, keep_len=6, old_len=4)
    with pytest.raises(ValueError, match="keep_len"):
        pool.rewind(seq.slots, keep_len=4, old_len=pool.max_seq + 1)
    # recurrent state cannot be rewound at all
    pool.cfg = dataclasses.replace(cfg, ssm_state=16)
    with pytest.raises(ValueError, match="recurrent"):
        pool.rewind(seq.slots, keep_len=4, old_len=5)
    # ring caches alias mod the window: depth 1 ok, deeper rejected
    pool.cfg = dataclasses.replace(cfg, window=4,
                                   layer_pattern=("local",))
    pool.rewind(seq.slots, keep_len=4, old_len=5)
    with pytest.raises(ValueError, match="window"):
        pool.rewind(seq.slots, keep_len=4, old_len=6)


def test_engine_caps_depth_for_windowed_models(tiny_ralm):
    cfg, params, _, ds, ccfg, rag = tiny_ralm
    wcfg = dataclasses.replace(cfg, window=8, layer_pattern=("local",))
    wparams = tf.init_params(jax.random.PRNGKey(0), wcfg)
    ret = ds.async_retriever(ccfg,
                             service_cfg=ServiceConfig(measure=False))
    eng = RalmEngine.monolithic(wparams, wcfg, rag, retriever=ret,
                                speculate_k=3)
    assert eng.speculate_k == 3 and eng._spec_depth == 1


# ---------------------------------------------------------------------------
# stale-tolerant query cache
# ---------------------------------------------------------------------------

def _cache_rows(n, dim=8, seed=0):
    return np.random.default_rng(seed).normal(size=(n, dim)).astype(
        np.float32)


def test_query_cache_partial_hits():
    cache = QueryCache(capacity=8, partial=True)
    q = _cache_rows(4)
    assert cache.get_batch(q) is None              # cold: zero hits
    cache.put_batch(q[:2], np.ones((2, 3)), np.arange(6).reshape(2, 3))
    dists, ids, hit = cache.get_batch(q)
    assert hit.tolist() == [True, True, False, False]
    assert (ids[~hit] == -1).all() and (dists[~hit] == 0).all()
    assert (ids[0] == [0, 1, 2]).all()
    assert cache.hits == 2 and cache.misses == 6   # 4 cold + 2 now


def test_query_cache_legacy_all_or_nothing():
    cache = QueryCache(capacity=8)                 # partial=False default
    q = _cache_rows(3)
    cache.put_batch(q[:2], np.zeros((2, 3)), np.zeros((2, 3), np.int32))
    assert cache.get_batch(q) is None              # one row missing -> miss
    assert cache.misses == 3 and cache.hits == 0
    out = cache.get_batch(q[:2])
    assert out is not None and cache.hits == 2


def test_query_cache_generations_and_stale_serving():
    cache = QueryCache(capacity=8, partial=True)
    q = _cache_rows(2)
    cache.put_batch(q, np.ones((2, 3)), np.zeros((2, 3), np.int32))
    cache.mark_stale()
    assert cache.get_batch(q) is None              # fresh lookup: stale
    assert cache.stale == 2 and cache.misses == 2
    assert cache.contains(q[0], any_generation=True)
    assert not cache.contains(q[0])
    stale = cache.get_stale(q)                     # speculation seed path
    assert stale is not None and cache.stale_served == 2
    assert cache.get_stale(_cache_rows(2, seed=9)) is None
    cache.put_batch(q, np.ones((2, 3)), np.zeros((2, 3), np.int32))
    assert cache.get_batch(q) is not None          # re-put at current gen


# ---------------------------------------------------------------------------
# service: partial-batch stitch + stale lookup
# ---------------------------------------------------------------------------

def test_service_partial_batch_stitch(tiny_ralm):
    """A batch that half-hits the cache sends ONLY the missed rows to
    the kernel; the stitched result must equal the cacheless search."""
    _, _, _, ds, ccfg, _ = tiny_ralm
    rng = np.random.default_rng(3)
    qa = jnp.asarray(rng.normal(size=(4, ds.index_cfg.dim)).astype(np.float32))
    qb_new = jnp.asarray(rng.normal(size=(2, ds.index_cfg.dim)).astype(np.float32))
    qb = jnp.concatenate([qa[0:1], qb_new[0:1], qa[2:3], qb_new[1:2]])

    svc = RetrievalService.local(ds.params, ds.shards, ccfg,
                                 ServiceConfig(cache_entries=32,
                                               measure=False))
    assert svc.config.cache_partial and svc.cache.partial
    h = svc.submit(qa)
    svc.flush()
    h.result()
    disp0 = svc.stats.scan_dispatches
    h2 = svc.submit(qb)
    svc.flush()
    dists, ids = h2.result()
    assert svc.stats.scan_dispatches == disp0 + 1
    assert svc.stats.cache_hits == 2

    bare = RetrievalService.local(ds.params, ds.shards, ccfg,
                                  ServiceConfig(cache_entries=0,
                                                measure=False))
    hb = bare.submit(qb)
    bare.flush()
    bd, bi = hb.result()
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(bi))
    np.testing.assert_allclose(np.asarray(dists), np.asarray(bd),
                               rtol=1e-5)


def test_service_stale_lookup(tiny_ralm):
    _, _, _, ds, ccfg, _ = tiny_ralm
    q = jnp.asarray(np.random.default_rng(4).normal(
        size=(2, ds.index_cfg.dim)).astype(np.float32))
    svc = RetrievalService.local(ds.params, ds.shards, ccfg,
                                 ServiceConfig(cache_entries=32,
                                               measure=False))
    assert svc.stale_lookup(q) is None             # cold
    h = svc.submit(q)
    svc.flush()
    d0, i0 = h.result()
    svc.mark_cache_stale()
    hits0 = svc.stats.cache_hits
    got = svc.stale_lookup(q)                      # serves any generation
    assert got is not None
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(i0))
    assert svc.stats.cache_hits == hits0           # not a demand hit


# ---------------------------------------------------------------------------
# degrade ladder: quality knobs flush in-flight speculation
# ---------------------------------------------------------------------------

def test_degrade_flushes_speculation_and_keeps_cache(tiny_ralm):
    _, _, _, ds, ccfg, _ = tiny_ralm
    svc = RetrievalService.local(ds.params, ds.shards, ccfg,
                                 ServiceConfig(cache_entries=32,
                                               measure=False))
    q = jnp.asarray(np.random.default_rng(5).normal(
        size=(2, ds.index_cfg.dim)).astype(np.float32))
    h = svc.submit(q)
    svc.flush()
    h.result()

    eng = types.SimpleNamespace(
        rag=RagConfig(mode="knnlm", interval=1, k=8),
        retriever=types.SimpleNamespace(service=svc),
        flushed=0)
    eng.flush_speculation = lambda: setattr(eng, "flushed",
                                            eng.flushed + 1)
    pol = DegradePolicy(eng)
    cache = svc.cache
    gen0 = cache.generation
    pol.apply(1)                                   # nprobe/2 rung
    assert eng.flushed == 1
    assert svc.pipeline.cfg.nprobe == ccfg.nprobe // 2
    assert svc.cache is cache                      # kept, not dropped
    assert cache.generation == gen0 + 1            # but marked stale
    assert cache.get_stale(np.asarray(q)) is not None
    pol.apply(1)                                   # idempotent: no re-flush
    assert eng.flushed == 1


# ---------------------------------------------------------------------------
# stats plane
# ---------------------------------------------------------------------------

def test_spec_stats_snapshot_and_rates():
    from repro.retrieval.stats import RetrievalStats
    stats = RetrievalStats()
    snap = stats.snapshot()
    spec = snap["speculation"]
    for key in ("issued", "verified", "accepted", "rollbacks",
                "discarded", "replayed_steps", "acceptance_rate",
                "rollback_rate", "spec_wait", "spec_replay"):
        assert key in spec
    assert snap["cache_stale"] == 0
    stats.spec_verified = 4
    stats.spec_accepted = 3
    stats.spec_rollbacks = 1
    assert stats.spec_acceptance_rate() == pytest.approx(0.75)
    assert stats.spec_rollback_rate() == pytest.approx(0.25)


def test_spec_metrics_families(tiny_ralm):
    """bind_engine_metrics exports the ralm_spec_* families after a
    speculating run."""
    from repro.obs import MetricsRegistry, bind_engine_metrics
    eng = _build(tiny_ralm, 1)
    _run(eng, _prompts(tiny_ralm[2]), steps=6)
    reg = MetricsRegistry()
    bind_engine_metrics(reg, eng)
    text = reg.render()
    assert "ralm_spec_issued_total" in text
    assert 'ralm_spec_verified_total{outcome="accepted"}' in text
    assert 'ralm_spec_verified_total{outcome="rollback"}' in text
    assert "ralm_spec_landed_total" in text
    assert "ralm_spec_acceptance_rate" in text
    assert 'ralm_retrieval_cache_total{result="stale"}' in text
