"""MoE dispatch invariants (sort-based capacity dispatch)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.models.moe import load_balance_loss, moe_ffn, route_topk


def dense_reference(x, router_w, w_gate, w_up, w_down, top_k):
    """Compute-all-experts reference (no capacity drops)."""
    w, ids = route_topk(x, router_w, top_k)
    g = jnp.einsum("nd,edf->nef", x, w_gate)
    u = jnp.einsum("nd,edf->nef", x, w_up)
    y_all = jnp.einsum("nef,efd->ned", jax.nn.silu(g) * u, w_down)
    out = jnp.zeros_like(x)
    for j in range(top_k):
        sel = jnp.take_along_axis(y_all, ids[:, j][:, None, None], axis=1)
        out = out + w[:, j][:, None] * sel[:, 0]
    return out


@given(st.integers(0, 50), st.sampled_from([1, 2, 4]))
def test_moe_matches_dense_reference(seed, top_k):
    """With generous capacity (no drops), sorted dispatch == dense compute."""
    N, d, f, E = 64, 16, 32, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (N, d), jnp.float32)
    rw = jax.random.normal(ks[1], (d, E), jnp.float32)
    wg = jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.1
    wu = jax.random.normal(ks[3], (E, d, f), jnp.float32) * 0.1
    wd = jax.random.normal(ks[4], (E, f, d), jnp.float32) * 0.1
    got = moe_ffn(x, rw, wg, wu, wd, top_k, capacity_factor=float(E))
    want = dense_reference(x, rw, wg, wu, wd, top_k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_capacity_dropping_bounded():
    """With tight capacity, output is a (weighted) subset — never junk."""
    N, d, f, E, top_k = 128, 8, 16, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (N, d), jnp.float32)
    rw = jax.random.normal(ks[1], (d, E), jnp.float32)
    wg = jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.1
    wu = jax.random.normal(ks[3], (E, d, f), jnp.float32) * 0.1
    wd = jax.random.normal(ks[4], (E, f, d), jnp.float32) * 0.1
    tight = moe_ffn(x, rw, wg, wu, wd, top_k, capacity_factor=0.5)
    loose = moe_ffn(x, rw, wg, wu, wd, top_k, capacity_factor=8.0)
    assert np.isfinite(np.asarray(tight)).all()
    # tight output norm <= loose output norm + eps (drops only remove mass)
    tn = float(jnp.sum(tight * tight))
    ln = float(jnp.sum(loose * loose))
    assert tn <= ln * 1.05


def test_router_weights_normalized():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    rw = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
    w, ids = route_topk(x, rw, 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(ids) < 4).all()
    # top-k ids are distinct per token
    assert (np.asarray(ids[:, 0]) != np.asarray(ids[:, 1])).all()


def test_load_balance_loss_minimized_at_uniform():
    """Aux loss >= 1 always, == ~1 for a perfectly uniform router."""
    E = 4
    x = jnp.eye(E).repeat(8, axis=0)                # 4 token groups
    rw_uniform = jnp.zeros((E, E))
    l_uni = float(load_balance_loss(x, rw_uniform, 1))
    rw_collapsed = jnp.ones((E, E)) * jnp.array([10., 0, 0, 0])[None, :]
    l_col = float(load_balance_loss(x, rw_collapsed, 1))
    assert l_col > l_uni
    assert l_uni >= 0.99


def test_moe_grads_flow_to_all_used_experts():
    N, d, f, E, top_k = 32, 8, 16, 4, 2
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (N, d), jnp.float32)
    params = dict(
        rw=jax.random.normal(ks[1], (d, E)),
        wg=jax.random.normal(ks[2], (E, d, f)) * 0.1,
        wu=jax.random.normal(ks[3], (E, d, f)) * 0.1,
        wd=jax.random.normal(ks[4], (E, f, d)) * 0.1)
    g = jax.grad(lambda p: jnp.sum(moe_ffn(
        x, p["rw"], p["wg"], p["wu"], p["wd"], top_k) ** 2))(params)
    per_expert = jnp.sum(jnp.abs(g["wd"]), axis=(1, 2))
    assert (np.asarray(per_expert) > 0).all()
