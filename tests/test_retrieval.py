"""The ``repro.retrieval`` service tier: hierarchical merge, LRU query
cache, and the batched ``RetrievalService`` (in-flight table, deadline
micro-batching, coalescing, cache fast-path, per-stage stats).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.chamvs import ChamVSConfig, search_single, shard_search
from repro.core.ivfpq import (IVFPQConfig, build_shards, merge_topk,
                              scan_ivf_index, train_ivfpq)
from repro.retrieval import (QueryCache, RetrievalService, ServiceConfig,
                             flat_merge, hierarchical_merge)


# ---------------------------------------------------------------------------
# merge: hierarchical == flat == reference
# ---------------------------------------------------------------------------

def _random_candidates(rng, num_shards, nq, kk):
    """Distinct distances (a permutation), so top-K has a unique answer
    and flat/hierarchical must agree exactly."""
    d = rng.permutation(num_shards * nq * kk).astype(np.float32)
    d = d.reshape(num_shards, nq, kk)
    i = rng.integers(0, 10_000, size=(num_shards, nq, kk)).astype(np.int32)
    return jnp.sort(jnp.asarray(d), axis=-1), jnp.asarray(i)


def test_flat_merge_matches_legacy_merge_topk():
    rng = np.random.default_rng(0)
    d, i = _random_candidates(rng, num_shards=5, nq=3, kk=7)
    fd, fi = flat_merge(d, i, k=10)
    md, mi = merge_topk(d, i, 10)       # the ivfpq-level entry point
    assert (np.asarray(fd) == np.asarray(md)).all()
    assert (np.asarray(fi) == np.asarray(mi)).all()
    # ascending, exact global top-10 of each query's candidate union
    ref = np.sort(np.asarray(d).transpose(1, 0, 2).reshape(3, -1),
                  axis=-1)[:, :10]
    assert np.allclose(np.asarray(fd), ref)


@pytest.mark.parametrize("num_shards,fanout", [(1, 2), (2, 2), (5, 2),
                                               (7, 3), (8, 4), (9, 2)])
def test_hierarchical_merge_equals_flat(num_shards, fanout):
    rng = np.random.default_rng(num_shards * 10 + fanout)
    d, i = _random_candidates(rng, num_shards, nq=4, kk=6)
    fd, fi = flat_merge(d, i, k=9)
    hd, hi = hierarchical_merge(d, i, k=9, fanout=fanout)
    assert (np.asarray(fd) == np.asarray(hd)).all()
    assert (np.asarray(fi) == np.asarray(hi)).all()


def test_hierarchical_merge_single_shard_unsorted_input():
    """Regression: S == 1 skips the tree loop entirely, but the final
    selection must still sort/select rather than truncate raw input."""
    d = jnp.asarray([[[5.0, 1.0, 3.0, 2.0]]])
    i = jnp.asarray([[[50, 10, 30, 20]]], jnp.int32)
    hd, hi = hierarchical_merge(d, i, k=2, fanout=2)
    assert np.asarray(hd).tolist() == [[1.0, 2.0]]
    assert np.asarray(hi).tolist() == [[10, 20]]


def test_merge_pads_when_fewer_candidates_than_k():
    rng = np.random.default_rng(1)
    d, i = _random_candidates(rng, num_shards=2, nq=2, kk=3)
    for fn in (lambda: flat_merge(d, i, k=10),
               lambda: hierarchical_merge(d, i, k=10, fanout=2)):
        od, oi = fn()
        assert od.shape == (2, 10) and oi.shape == (2, 10)
        assert np.isinf(np.asarray(od)[:, 6:]).all()
        assert (np.asarray(oi)[:, 6:] == -1).all()


@given(st.integers(1, 12), st.integers(1, 4), st.integers(1, 24),
       st.integers(2, 4), st.integers(0, 2 ** 31 - 1))
def test_hierarchical_merge_is_global_topk(num_shards, nq, k, fanout, seed):
    """Property (satellite): hierarchical merge == flat global top-k for
    random shard counts / fanouts."""
    rng = np.random.default_rng(seed)
    kk = rng.integers(1, 9)
    d, i = _random_candidates(rng, num_shards, nq, int(kk))
    hd, hi = hierarchical_merge(d, i, k=k, fanout=fanout)
    fd, fi = flat_merge(d, i, k=k)
    assert (np.asarray(hd) == np.asarray(fd)).all()
    assert (np.asarray(hi) == np.asarray(fi)).all()
    # and flat is the true global top-k of each query's candidate union
    ref = np.sort(np.asarray(d).transpose(1, 0, 2).reshape(nq, -1),
                  axis=-1)
    width = min(k, ref.shape[-1])
    assert np.allclose(np.asarray(fd)[:, :width], ref[:, :width])


# ---------------------------------------------------------------------------
# cache: hit/miss semantics + LRU eviction order
# ---------------------------------------------------------------------------

def _rows(*vals, d=4):
    return np.stack([np.full((d,), v, np.float32) for v in vals])


def test_cache_hit_miss_counters():
    c = QueryCache(capacity=8)
    q = _rows(1.0, 2.0)
    assert c.get_batch(q) is None and c.misses == 2 and c.hits == 0
    c.put_batch(q, np.zeros((2, 3)), np.ones((2, 3), np.int32))
    got = c.get_batch(q)
    assert got is not None and c.hits == 2
    assert got[0].shape == (2, 3) and (got[1] == 1).all()


def test_cache_batch_lookup_is_all_or_nothing():
    c = QueryCache(capacity=8)
    c.put_batch(_rows(1.0), np.zeros((1, 3)), np.zeros((1, 3), np.int32))
    # one row cached + one not -> whole batch is a miss
    assert c.get_batch(_rows(1.0, 9.0)) is None
    assert c.misses == 2 and c.hits == 0


def test_cache_eviction_is_lru_order():
    c = QueryCache(capacity=2)
    mk = lambda v: (_rows(v), np.full((1, 2), v), np.full((1, 2), int(v)))
    for v in (1.0, 2.0):
        q, d, i = mk(v)
        c.put_batch(q, d, i)
    assert c.get_batch(_rows(1.0)) is not None   # refresh 1 -> LRU is 2
    q3, d3, i3 = mk(3.0)
    c.put_batch(q3, d3, i3)                      # evicts 2, not 1
    assert len(c) == 2
    assert c.contains(_rows(1.0)[0]) and c.contains(_rows(3.0)[0])
    assert not c.contains(_rows(2.0)[0])
    # and insertion order alone is FIFO when nothing is touched
    c2 = QueryCache(capacity=2)
    for v in (1.0, 2.0, 3.0):
        q, d, i = mk(v)
        c2.put_batch(q, d, i)
    assert not c2.contains(_rows(1.0)[0])
    assert c2.contains(_rows(2.0)[0]) and c2.contains(_rows(3.0)[0])


def test_cache_quantization_radius():
    c = QueryCache(capacity=4, quant=1e-2)
    c.put_batch(_rows(1.0), np.zeros((1, 2)), np.zeros((1, 2), np.int32))
    assert c.get_batch(_rows(1.001)) is not None    # same grid cell
    assert c.get_batch(_rows(1.4)) is None          # different cell


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_index():
    key = jax.random.PRNGKey(0)
    icfg = IVFPQConfig(dim=32, nlist=16, m=8, list_cap=256)
    vecs = jax.random.normal(key, (2048, 32))
    params = train_ivfpq(key, vecs[:1024], icfg, kmeans_iters=4)
    shards = build_shards(params, np.asarray(vecs), icfg, num_shards=4)
    cfg = ChamVSConfig(ivfpq=icfg, nprobe=8, k=10, backend="ref")
    queries = jax.random.normal(jax.random.PRNGKey(1), (6, 32))
    return params, shards, cfg, queries


def _reference(params, shards, cfg, queries):
    kk = cfg.k_prime(len(shards))
    _, probe = scan_ivf_index(params, queries, cfg.nprobe)
    per = [shard_search(params, s, queries, probe, cfg, kk) for s in shards]
    return merge_topk(jnp.stack([p[0] for p in per]),
                      jnp.stack([p[1] for p in per]), cfg.k)


def test_search_single_routes_through_service(small_index):
    """The legacy entry point and the service are one implementation."""
    params, shards, cfg, q = small_index
    d, i = search_single(params, shards, q, cfg)
    rd, ri = _reference(params, shards, cfg, q)
    assert (np.asarray(i) == np.asarray(ri)).all()
    assert np.allclose(np.asarray(d), np.asarray(rd), rtol=1e-5, atol=1e-5)


def test_service_coalesces_submissions(small_index):
    """Two sequences' queries -> ONE batched kernel dispatch, results
    identical to searching each alone (acceptance criterion)."""
    params, shards, cfg, q = small_index
    svc = RetrievalService.local(params, shards, cfg)
    h1 = svc.submit(q[:2])
    h2 = svc.submit(q[2:])
    assert not h1.done() and not h2.done()
    assert svc.num_pending_rows == 6 and svc.num_inflight == 2
    svc.flush()
    assert h1.done() and h2.done()
    assert svc.stats.num_batches == 1            # one coalesced dispatch
    assert svc.stats.max_coalesced == 6
    d1, i1 = h1.result()
    d2, i2 = h2.result()
    assert svc.num_inflight == 0                 # retired from the table
    rd, ri = _reference(params, shards, cfg, q)
    got_i = np.concatenate([np.asarray(i1), np.asarray(i2)])
    assert (got_i == np.asarray(ri)).all()


def test_service_result_forces_flush(small_index):
    """A handle can always be resolved: result() on a queued entry
    triggers the flush itself."""
    params, shards, cfg, q = small_index
    svc = RetrievalService.local(params, shards, cfg)
    h = svc.submit(q[:1])
    d, i = h.result()
    assert svc.stats.num_batches == 1 and d.shape == (1, cfg.k)


def test_service_max_batch_autoflush(small_index):
    params, shards, cfg, q = small_index
    svc = RetrievalService.local(params, shards, cfg,
                                 ServiceConfig(max_batch=4))
    h1 = svc.submit(q[:2])
    assert not h1.done()                          # 2 < max_batch
    h2 = svc.submit(q[2:4])                       # hits max_batch
    assert h1.done() and h2.done()
    assert svc.stats.num_batches == 1


def test_service_deadline_flush(small_index):
    """A submit after the oldest pending row exceeds deadline_s flushes
    the accumulated micro-batch (deadline-based batching)."""
    params, shards, cfg, q = small_index
    svc = RetrievalService.local(params, shards, cfg,
                                 ServiceConfig(deadline_s=0.01))
    h1 = svc.submit(q[:1])
    assert not h1.done()
    time.sleep(0.02)
    svc.submit(q[1:2])                            # deadline expired -> flush
    assert h1.done() and svc.stats.num_batches == 1
    # poll() alone also triggers it
    h3 = svc.submit(q[2:3])
    time.sleep(0.02)
    svc.poll()
    assert h3.done() and svc.stats.num_batches == 2


def test_service_cache_hit_skips_kernel(small_index):
    """Acceptance criterion: a cached query batch completes with NO new
    kernel dispatch, and returns identical results."""
    params, shards, cfg, q = small_index
    svc = RetrievalService.local(params, shards, cfg,
                                 ServiceConfig(cache_entries=64))
    d0, i0 = svc.search(q[:3])
    assert svc.stats.num_batches == 1
    assert svc.stats.cache_misses == 3
    h = svc.submit(q[:3])
    assert h.done()                               # answered at submit time
    d1, i1 = h.result()
    assert svc.stats.num_batches == 1             # kernel NOT dispatched
    assert svc.stats.cache_hits == 3
    assert (np.asarray(i0) == np.asarray(i1)).all()
    assert np.allclose(np.asarray(d0), np.asarray(d1))


def test_service_hierarchical_merge_matches_flat(small_index):
    params, shards, cfg, q = small_index
    flat = RetrievalService.local(params, shards, cfg)
    tree = RetrievalService.local(params, shards, cfg,
                                  ServiceConfig(merge_fanout=2))
    fd, fi = flat.search(q)
    td, ti = tree.search(q)
    assert (np.asarray(fi) == np.asarray(ti)).all()
    assert np.allclose(np.asarray(fd), np.asarray(td))


def test_service_stats_breakdown(small_index):
    params, shards, cfg, q = small_index
    svc = RetrievalService.local(params, shards, cfg)
    svc.search(q[:2])
    svc.search(q[2:4])
    snap = svc.stats.snapshot()
    assert snap["num_batches"] == 2 and snap["num_queries"] == 4
    for stage in ("queue_wait", "scan", "merge"):
        assert snap[stage]["count"] == 2, stage
        assert snap[stage]["mean_us"] >= 0.0
    assert snap["qps"] > 0
