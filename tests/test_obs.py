"""The observability plane: tracing, metrics, and their serving wiring.

The load-bearing claims, in test order:

  * a disabled tracer is genuinely free — ``span()`` returns one
    module-level null singleton and the hot path allocates NOTHING in
    ``repro.obs.trace`` (pinned with tracemalloc), so tracing can stay
    compiled into the wave loop;
  * a live tracer is thread-safe and bounded: concurrent spans from
    many threads land exactly once in a ring buffer that drops oldest
    instead of growing, and the export still validates;
  * the export speaks the Chrome trace-event contract — phases, X
    durations, flow-event pairing — checked by ``validate_chrome_trace``
    both positively (our own exports) and negatively (corrupted docs);
  * histogram bucket math follows Prometheus semantics (``le`` is an
    inclusive upper bound, cumulative series, ``+Inf`` == count) and
    reservoir quantiles track known distributions;
  * ``/metricsz`` renders parseable exposition text: valid sample/label
    syntax, one TYPE per family, no duplicate sample names;
  * a request's trace id flows through a REAL scheduler wave — admit,
    decode, search, finish, retrieval stages, KV alloc/release — and
    the flow arrow connects queue-wait to the first-token wave;
  * the same engine with tracing disabled records zero events over the
    same workload (the satellite overhead criterion, structurally).

The HTTP tests share one module-scoped gateway like tests/test_gateway.
"""
import dataclasses
import json
import re
import socket
import threading
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer as tf
from repro.obs import (DEFAULT_BUCKETS, Histogram, MetricsRegistry,
                       Reservoir, Tracer, validate_chrome_trace)
from repro.obs.trace import NULL_SPAN, NULL_TRACER
from repro.retrieval.stats import RetrievalStats, StageStat
from repro.serve import (DatastoreBuilder, RagConfig, RalmEngine,
                         RalmRequest)
from repro.serve.gateway import Gateway, GatewayConfig

# ---------------------------------------------------------------------------
# tracer core (no jax)
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def test_span_nesting_and_export():
    clock = FakeClock(5.0)
    tr = Tracer(clock=clock)
    with tr.span("outer", "wave", args={"rows": 2}):
        clock.t += 0.1
        with tr.span("inner", "wave"):
            clock.t += 0.2
        clock.t += 0.1
    doc = tr.export()
    assert doc["displayTimeUnit"] == "ms"
    assert validate_chrome_trace(doc) == []
    by_name = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    outer, inner = by_name["outer"], by_name["inner"]
    assert outer["args"] == {"rows": 2}
    assert outer["ts"] == pytest.approx(0.0)
    assert outer["dur"] == pytest.approx(0.4e6)
    # proper nesting: inner starts after outer and ends before it
    assert inner["ts"] == pytest.approx(0.1e6)
    assert inner["dur"] == pytest.approx(0.2e6)
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    # the track is announced exactly once
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert len(meta) == 1 and meta[0]["args"]["name"] == "wave"
    assert outer["tid"] == inner["tid"] == meta[0]["tid"]


def test_instant_flow_and_retroactive_complete():
    clock = FakeClock(5.0)
    tr = Tracer(clock=clock)
    clock.t = 6.0
    tr.instant("kvpool.alloc", "kvpool", args={"rows": 2})
    tr.flow_start(42, t_s=5.5)
    tr.flow_end(42, track="wave", t_s=6.0)
    tr.complete("queue.wait", "requests", t0_s=5.25, dur_s=0.5)
    tr.complete("clamped", "requests", t0_s=6.0, dur_s=-1.0)
    assert validate_chrome_trace(tr.export()) == []
    evs = {e["name"]: e for e in tr.events() if e["ph"] != "M"}
    inst = evs["kvpool.alloc"]
    assert inst["ph"] == "i" and inst["s"] == "t"
    assert inst["ts"] == pytest.approx(1.0e6)
    assert evs["queue.wait"]["ts"] == pytest.approx(0.25e6)
    assert evs["queue.wait"]["dur"] == pytest.approx(0.5e6)
    assert evs["clamped"]["dur"] == 0.0          # negative dur clamps
    flows = [e for e in tr.events() if e.get("cat") == "flow"]
    assert [e["ph"] for e in flows] == ["s", "f"]
    assert all(e["id"] == 42 for e in flows)
    assert flows[1]["bp"] == "e"                 # bind to enclosing slice


def test_ring_buffer_bounded():
    tr = Tracer(capacity=16)
    for i in range(100):
        tr.instant(f"e{i}", "t")
    evs = tr.events()
    assert len(evs) == 16                        # oldest fell off
    assert evs[-1]["name"] == "e99"              # newest survives


def test_clear_reemits_track_metadata():
    tr = Tracer()
    with tr.span("a", "wave"):
        pass
    with tr.span("b", "retrieval"):
        pass
    tr.clear()
    assert all(e["ph"] == "M" for e in tr.events())
    assert {e["args"]["name"] for e in tr.events()} == {"wave", "retrieval"}
    with tr.span("after", "wave"):
        pass
    doc = tr.export()
    assert validate_chrome_trace(doc) == []      # still self-contained
    assert any(e["name"] == "after" for e in doc["traceEvents"])


def test_tracer_thread_safety():
    tr = Tracer(capacity=1 << 15)
    nthreads, per = 8, 200

    def worker(i):
        track = f"t{i % 4}"
        for j in range(per):
            with tr.span(f"s{i}", track):
                pass
            tr.instant(f"i{i}", track)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(nthreads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events()
    # every event landed exactly once: 4 track announcements plus
    # (span + instant) * per * nthreads
    assert len(evs) == 4 + 2 * per * nthreads
    assert validate_chrome_trace(tr.export()) == []
    assert len({e["tid"] for e in evs}) == 4     # stable track ids


def test_disabled_tracer_is_null_and_silent():
    tr = Tracer(enabled=False)
    s1, s2 = tr.span("a", args={"x": 1}), tr.span("b")
    assert s1 is s2 is NULL_SPAN                 # one shared singleton
    with s1:
        pass
    tr.instant("i")
    tr.complete("c", "t", 0.0, 1.0)
    tr.flow_start(1)
    tr.flow_end(1)
    assert tr.events() == []
    assert len(NULL_TRACER.events()) == 0        # the module-global too


def test_overhead_guard_disabled_tracer():
    """The disabled hot path must not allocate inside repro.obs.trace:
    that is the mechanism behind the <2%% tokens/s acceptance bound."""
    from repro.obs import trace as trace_mod
    tr = Tracer(enabled=False)

    def hot_loop(n):
        for _ in range(n):
            with tr.span("hot", "wave"):
                pass
            tr.instant("hot", "wave")
            tr.flow_start(7)
            tr.flow_end(7)

    # first traced pass absorbs one-time interpreter caches (attributed
    # to the function bodies in trace.py); the measured pass must then
    # allocate NOTHING — any per-iteration allocation scales to > 0
    tracemalloc.start()
    hot_loop(2000)
    before = tracemalloc.take_snapshot()
    hot_loop(2000)
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    filt = [tracemalloc.Filter(True, trace_mod.__file__)]
    diff = after.filter_traces(filt).compare_to(
        before.filter_traces(filt), "lineno")
    assert sum(d.size_diff for d in diff) <= 0, \
        [(d.traceback, d.size_diff) for d in diff if d.size_diff > 0]


def test_validator_rejects_malformed_docs():
    assert validate_chrome_trace({"nope": 1})    # no traceEvents
    assert validate_chrome_trace("text")         # wrong type
    assert validate_chrome_trace([1, 2]) != []   # events must be dicts
    base = {"pid": 1, "tid": 1, "ts": 0.0, "name": "e"}
    assert validate_chrome_trace([{**base, "ph": "Q"}])   # unknown phase
    assert validate_chrome_trace([{**base, "ph": "X"}])   # X without dur
    assert validate_chrome_trace(
        [{**base, "ph": "X", "dur": -5}])                  # negative dur
    assert validate_chrome_trace([{"ph": "i", "ts": 0.0}])  # missing keys
    # flow pairing, both directions
    s = {**base, "ph": "s", "id": 9}
    f = {**base, "ph": "f", "id": 9}
    assert validate_chrome_trace([s]) != []      # start without finish
    assert validate_chrome_trace([f]) != []      # finish without start
    assert validate_chrome_trace([s, f]) == []   # paired: clean
    # a bare event list (no wrapper dict) is accepted
    assert validate_chrome_trace([{**base, "ph": "i"}]) == []


# ---------------------------------------------------------------------------
# metrics core (no jax)
# ---------------------------------------------------------------------------


def test_histogram_bucket_math():
    h = Histogram("t_seconds", "test", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.1, 0.5, 1.0, 7.0, 99.0):
        h.observe(v)
    lines = h.render()
    # le is an INCLUSIVE upper bound: 0.1 counts in le="0.1"
    assert 't_seconds_bucket{le="0.1"} 2' in lines
    assert 't_seconds_bucket{le="1"} 4' in lines
    assert 't_seconds_bucket{le="10"} 5' in lines
    assert 't_seconds_bucket{le="+Inf"} 6' in lines
    assert "t_seconds_count 6" in lines
    assert h.count == 6
    assert h.sum == pytest.approx(107.65)
    snap = h.snapshot()
    assert snap["count"] == 6 and snap["sum"] == pytest.approx(107.65)


def test_histogram_quantiles_track_distribution():
    h = Histogram("q_seconds", buckets=DEFAULT_BUCKETS)
    for i in range(1, 1001):
        h.observe(i / 1000.0)                    # uniform on (0, 1]
    assert h.quantile(0.50) == pytest.approx(0.5, abs=0.01)
    assert h.quantile(0.99) == pytest.approx(0.99, abs=0.01)
    snap = h.snapshot()
    assert snap["p50"] == pytest.approx(0.5, abs=0.01)
    assert snap["p99"] == pytest.approx(0.99, abs=0.01)


def test_reservoir_bounded_and_uniform():
    r = Reservoir(cap=256)
    for i in range(10_000):
        r.add(float(i))
    assert len(r) == 256 and r.n == 10_000       # bounded, counts all
    # a uniform sample of 0..9999: the median estimate is mid-range
    assert 3000 < r.quantile(0.5) < 7000
    assert Reservoir().quantile(0.5) == 0.0      # empty: defined


_LV = r'"(?:[^"\\\n]|\\.)*"'                             # label value
SAMPLE_RE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*'                         # metric name
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*=' + _LV +                # first label
    r'(,[a-zA-Z_][a-zA-Z0-9_]*=' + _LV + r')*\})?'       # more labels
    r' (-?\d+(\.\d+)?([eE][-+]?\d+)?|[+-]Inf|NaN)$')     # value


def _check_exposition(text):
    """Prometheus text-format invariants: every sample line parses, one
    TYPE per family, no duplicate sample names."""
    typed, seen = [], []
    for line in text.splitlines():
        if line.startswith("# TYPE "):
            typed.append(line.split()[2])
        elif line and not line.startswith("#"):
            assert SAMPLE_RE.match(line), f"bad sample line: {line!r}"
            seen.append(line.rsplit(" ", 1)[0])
    assert len(typed) == len(set(typed)), "duplicate TYPE declarations"
    assert len(seen) == len(set(seen)), "duplicate sample names"
    return typed, seen


def test_registry_render_is_valid_exposition():
    reg = MetricsRegistry()
    c = reg.counter("ralm_reqs_total", "requests")
    c.inc(3, labels={"tenant": "a"})
    c.inc(1, labels={"tenant": 'quo"te\n'})      # needs escaping
    reg.gauge("ralm_depth", "queue depth").set(5)
    reg.histogram("ralm_lat_seconds", "latency",
                  buckets=(0.1, 1.0)).observe(0.2)
    reg.counter("ralm_empty_total", "never incremented")
    text = reg.render()
    typed, seen = _check_exposition(text)
    assert "ralm_reqs_total" in typed and "ralm_lat_seconds" in typed
    assert "ralm_lat_seconds_p99" in typed       # reservoir companions
    assert 'ralm_reqs_total{tenant="a"} 3' in text.splitlines()
    assert any(s.startswith("ralm_empty_total") for s in seen)


def test_registry_idempotent_and_kind_clash():
    reg = MetricsRegistry()
    a = reg.counter("x_total")
    assert reg.counter("x_total") is a           # get-or-create
    reg.histogram("h_seconds")
    with pytest.raises(TypeError):
        reg.gauge("h_seconds")                   # kind mismatch
    # collectors run at scrape time, not registration time
    hits = []
    reg.register_collector(lambda: hits.append(1))
    assert not hits
    reg.render()
    reg.snapshot()
    assert len(hits) == 2


def test_counter_snapshot_shapes():
    reg = MetricsRegistry()
    plain = reg.counter("plain_total")
    plain.inc(2)
    assert reg.snapshot()["plain_total"] == 2.0  # unlabelled: scalar
    lab = reg.counter("lab_total")
    lab.inc(1, labels={"op": "scan"})
    assert reg.snapshot()["lab_total"] == {'{op="scan"}': 1.0}


# ---------------------------------------------------------------------------
# satellite: stats fixes (StageStat percentiles, qps active window)
# ---------------------------------------------------------------------------


def test_stagestat_percentiles_in_summary():
    st = StageStat()
    for i in range(1, 101):
        st.add(i * 1e-3)                         # 1ms .. 100ms
    s = st.summary()
    assert s["p50_us"] == pytest.approx(51_000, rel=0.05)
    assert s["p99_us"] == pytest.approx(100_000, rel=0.02)
    assert s["mean_us"] == pytest.approx(50_500, rel=0.01)
    assert s["count"] == 100


def test_retrieval_stats_qps_active_window():
    clock = FakeClock()
    st = RetrievalStats(clock=clock)
    assert st.qps() == 0.0                       # no traffic: defined
    # burst one: 8 queries over 0.1s
    st.record_submit(8)
    clock.t = 0.1
    st.record_batch(8)
    # a long idle gap must NOT deflate the rate (old bug: the window
    # was first-to-last wall time, so 100s idle -> qps ~ 0.16)
    clock.t = 100.0
    st.record_submit(8)                          # gap clipped to 1.0s
    clock.t = 100.1
    st.record_batch(8)
    assert st.qps() == pytest.approx(16 / 1.2)   # 0.1 + 1.0 + 0.1 active


def test_retrieval_stats_qps_single_instant():
    clock = FakeClock(10.0)
    st = RetrievalStats(clock=clock)
    st.record_submit(5)                          # one instant only
    clock.t = 10.25
    assert st.qps() == pytest.approx(20.0)       # measured to "now"
    clock.t = 500.0                              # ...but idle-clipped:
    assert st.qps() == pytest.approx(5.0)        # never decays below 1s


# ---------------------------------------------------------------------------
# trace-id propagation through a real scheduler wave
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_ralm():
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    start = rng.integers(0, 64, size=(64,))
    corpus = [start]
    for _ in range(31):
        corpus.append((3 * corpus[-1] + 1) % 64)
    corpus = np.stack(corpus, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                          list_cap=512).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    return cfg, params, corpus, ds, ccfg, rag


def _traced_engine(tiny_ralm, enabled=True, **kw):
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    kw.setdefault("max_seq", 64)
    kw.setdefault("kv_slots", 8)
    kw.setdefault("attn_seq_block", 64)
    eng = RalmEngine.monolithic(params, cfg, rag,
                                ds.async_retriever(ccfg), **kw)
    eng.set_tracer(Tracer(enabled=enabled))
    return eng


def test_trace_id_propagates_through_wave(tiny_ralm):
    """One request, end to end: every span the taxonomy in
    docs/observability.md promises shows up, on the right track, and
    the flow arrow links admission to the first-token wave."""
    corpus = tiny_ralm[2]
    eng = _traced_engine(tiny_ralm)
    req = RalmRequest(prompt=jnp.asarray(corpus[:2, :8]), steps=3,
                      tenant="traced")
    rid = eng.submit(req)
    assert req.trace_id == rid                   # defaulted at submit
    eng.run()

    doc = eng.tracer.export()
    assert validate_chrome_trace(doc) == []
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    for expected in ("queue.wait", "sched.admit", "sched.step",
                     "wave.decode", "wave.search", "wave.finish",
                     "retrieval.queue_wait", "retrieval.scan",
                     "retrieval.merge", "retrieval.gather",
                     "kvpool.alloc", "kvpool.release",
                     "jit.decode_compile"):
        assert expected in names, f"span {expected!r} missing"
    tracks = {e["tid"]: e["args"]["name"] for e in evs if e["ph"] == "M"}
    by_name = {e["name"]: e for e in evs if e["ph"] in ("X", "i")}
    assert tracks[by_name["wave.decode"]["tid"]] == "wave"
    assert tracks[by_name["retrieval.scan"]["tid"]] == "retrieval"
    assert tracks[by_name["kvpool.alloc"]["tid"]] == "kvpool"
    # the request's identity rides the spans...
    admit = by_name["sched.admit"]
    assert admit["args"]["request_id"] == rid
    assert by_name["queue.wait"]["args"]["trace_id"] == rid
    # ...and the flow arrow is paired on exactly that id
    flows = [e for e in evs if e.get("cat") == "flow"]
    assert {e["ph"] for e in flows} == {"s", "f"}
    assert all(e["id"] == rid for e in flows)
    # one wave span per generated token
    steps = [e for e in evs if e["name"] == "sched.step"]
    assert len(steps) == 3
    # TTFT decomposes: queue.wait ends before the first wave ends
    qw = by_name["queue.wait"]
    assert qw["ts"] + qw["dur"] <= steps[0]["ts"] + steps[0]["dur"] + 1.0


def test_disabled_tracer_records_nothing_on_wave(tiny_ralm):
    """Same workload, tracing off: zero events, and outputs are
    byte-identical to the traced engine (observability is read-only)."""
    corpus = tiny_ralm[2]
    on = _traced_engine(tiny_ralm)
    off = _traced_engine(tiny_ralm, enabled=False)
    out_on = np.asarray(on.generate(jnp.asarray(corpus[:2, :8]), steps=3))
    out_off = np.asarray(off.generate(jnp.asarray(corpus[:2, :8]), steps=3))
    assert off.tracer.events() == []
    assert len(on.tracer.events()) > 0
    np.testing.assert_array_equal(out_on, out_off)


# ---------------------------------------------------------------------------
# the gateway endpoints: /metricsz, /tracez, /statsz satellites
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def obs_gw(tiny_ralm):
    # the Gateway snapshots engine.tracer at construction: install first
    eng = _traced_engine(tiny_ralm)
    gateway = Gateway(eng, GatewayConfig())
    gateway.start_background()
    # one real completion so the latency histograms have data
    _stream_one(gateway.port, tiny_ralm[2][0, :8].tolist())
    yield gateway
    gateway.shutdown()


def _get(port, path):
    s = socket.create_connection(("127.0.0.1", port), timeout=60)
    s.sendall(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    raw = b""
    while True:
        data = s.recv(65536)
        if not data:
            break
        raw += data
    s.close()
    head, body = raw.split(b"\r\n\r\n", 1)
    status = int(head.split(b"\r\n")[0].split()[1])
    headers = {}
    for ln in head.decode().split("\r\n")[1:]:
        k, v = ln.split(":", 1)
        headers[k.strip().lower()] = v.strip()
    return status, headers, body


def _stream_one(port, prompt, max_tokens=4):
    body = json.dumps({"prompt": prompt, "max_tokens": max_tokens,
                       "stream": True}).encode()
    req = (f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
           f"Content-Length: {len(body)}\r\n\r\n").encode() + body
    s = socket.create_connection(("127.0.0.1", port), timeout=120)
    s.sendall(req)
    buf = b""
    while b"data: [DONE]\n\n" not in buf:
        data = s.recv(4096)
        assert data, "stream closed early"
        buf += data
    s.close()


def test_gateway_metricsz_exposition(obs_gw):
    status, headers, body = _get(obs_gw.port, "/metricsz")
    assert status == 200
    assert headers["content-type"].startswith("text/plain")
    text = body.decode()
    typed, seen = _check_exposition(text)
    # the client-facing SLO families have real observations
    samples = {}
    for line in text.splitlines():
        if line and not line.startswith("#"):
            name, _, v = line.rpartition(" ")
            samples[name] = float(v)
    assert samples["ralm_ttft_seconds_count"] >= 1
    assert samples["ralm_ttft_seconds_p50"] > 0
    assert samples["ralm_completions_total"] >= 1
    assert samples["ralm_tokens_out_total"] >= 1
    assert samples['ralm_admission_total{outcome="admitted"}'] >= 1
    assert samples['ralm_kv_slots{state="used"}'] == 0   # idle now
    assert "ralm_retrieval_queries_total" in samples
    assert samples['ralm_retrieval_stage_seconds'
                   '{stage="scan",stat="p99"}'] >= 0


def test_gateway_tracez_roundtrip_and_clear(obs_gw):
    status, _, body = _get(obs_gw.port, "/tracez")
    assert status == 200
    doc = json.loads(body)
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"sched.step", "wave.decode", "retrieval.scan"} <= names
    # drain the ring: the next scrape holds only track metadata
    status, _, body = _get(obs_gw.port, "/tracez?clear=1")
    assert status == 200
    assert validate_chrome_trace(json.loads(body)) == []
    _, _, body = _get(obs_gw.port, "/tracez")
    leftover = json.loads(body)["traceEvents"]
    assert all(e["ph"] == "M" for e in leftover)
    # and the tracer keeps recording after a clear
    _stream_one(obs_gw.port, [1, 2, 3, 4], max_tokens=2)
    _, _, body = _get(obs_gw.port, "/tracez")
    doc = json.loads(body)
    assert validate_chrome_trace(doc) == []
    assert any(e["name"] == "sched.step" for e in doc["traceEvents"])


def test_gateway_statsz_satellite_fields(obs_gw):
    _, _, body = _get(obs_gw.port, "/statsz")
    stats = json.loads(body)
    kv = stats["kv_pool"]
    for key in ("decode_compiles", "skip_fraction", "blocks_total",
                "blocks_skipped"):
        assert key in kv, key
    assert kv["decode_compiles"] >= 1
    kern = stats["kernels"]
    assert isinstance(kern["fallbacks"], dict)
    assert kern["fallback_total"] == sum(kern["fallbacks"].values())
    ret = stats["retrieval"]
    assert "p50_us" in ret["scan"] and "p99_us" in ret["scan"]
    assert ret["qps"] >= 0
    # /statsz is an aggregated view of the SAME registry as /metricsz
    assert stats["metrics"]["ralm_completions_total"] >= 1
    assert stats["metrics"]["ralm_ttft_seconds"]["count"] >= 1
