"""The unified ``repro.serve`` API: Retriever protocol, scheduler,
datastore builder, and monolithic/disaggregated parity.

Parity is the load-bearing claim (paper §3): disaggregation is a systems
transform, not a model change, so the same engine on split pools must
emit token-identical greedy sequences. The parity test runs in a
subprocess with 8 fake CPU devices (the XLA device count must be fixed
before jax initializes; same pattern as tests/test_distributed.py).
"""
import dataclasses
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.generate import RetrievalEngine, generate
from repro.models import transformer as tf
from repro.serve import (AsyncRetriever, DatastoreBuilder, LocalRetriever,
                         RagConfig, RalmEngine, RalmRequest, Retriever,
                         ServiceConfig)

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def run_sub(code: str) -> str:
    # generous timeout: the 8-fake-device parity subprocess compiles two
    # full engines and takes ~8min on this host; CI runners are slower
    env = dict(PYTHONPATH=SRC, PATH="/usr/bin:/bin",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               HOME="/tmp")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=1800, env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-3000:]}"
    return p.stdout


@pytest.fixture(scope="module")
def tiny_ralm():
    """Tiny decoder LM + datastore over a deterministic-bigram corpus
    (token t -> (3t+1) mod 64), built through DatastoreBuilder."""
    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    start = rng.integers(0, 64, size=(64,))
    corpus = [start]
    for _ in range(31):
        corpus.append((3 * corpus[-1] + 1) % 64)
    corpus = np.stack(corpus, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                          list_cap=512).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    return cfg, params, corpus, ds, ccfg, rag


# ---------------------------------------------------------------------------
# DatastoreBuilder
# ---------------------------------------------------------------------------

def test_datastore_roundtrip():
    """build() -> search() finds the indexed vectors; resolve() returns
    their payloads with missing-id masking folded in."""
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(512, 32)).astype(np.float32)
    payload = np.arange(512, dtype=np.int32) * 7
    ds = DatastoreBuilder(dim=32, nlist=8, m=8, list_cap=256,
                          num_shards=2).build(vecs, payload_tokens=payload)
    assert ds.num_vectors == 512 and ds.num_shards == 2
    ret = ds.retriever(ds.search_config(nprobe=8, k=4))
    assert isinstance(ret, Retriever)           # protocol conformance
    dists, ids = ret.search(jnp.asarray(vecs[:16]))
    assert ids.shape == (16, 4)
    # a vector queried against itself must be its own nearest neighbor
    hit = (np.asarray(ids) == np.arange(16)[:, None]).any(axis=1)
    assert hit.mean() > 0.9, hit
    # resolve: payload of the found ids, and -1 exactly where ids are -1
    toks = np.asarray(ret.resolve(ids))
    valid = np.asarray(ids) >= 0
    assert (toks[valid] == payload[np.asarray(ids)[valid]]).all()
    masked = ret.resolve(jnp.asarray([[0, -1, 3, -1]], jnp.int32))
    assert np.asarray(masked).tolist() == [[0, -1, 21, -1]]


def test_datastore_from_corpus_matches_manual(tiny_ralm):
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    keys, nxt = DatastoreBuilder.corpus_keys(params, cfg, corpus)
    assert keys.shape == (64 * 31, cfg.d_model)
    assert ds.num_vectors == keys.shape[0]
    assert (np.asarray(ds.payload_tokens) == nxt).all()


# ---------------------------------------------------------------------------
# scheduler: continuous batching semantics
# ---------------------------------------------------------------------------

def test_scheduler_interleaved_submit_step(tiny_ralm):
    """submit() between step()s joins the running loop; sequences finish
    independently; interleaving never changes anyone's tokens."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    ret = ds.retriever(ccfg)

    # reference: each request run alone
    ref_a = np.asarray(RalmEngine.monolithic(params, cfg, rag, ret)
                       .generate(jnp.asarray(corpus[:2, :8]), steps=6))
    ref_b = np.asarray(RalmEngine.monolithic(params, cfg, rag, ret)
                       .generate(jnp.asarray(corpus[2:4, :8]), steps=2))

    eng = RalmEngine.monolithic(params, cfg, rag, ret)
    rid_a = eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:2, :8]),
                                   steps=6))
    done = eng.step() + eng.step()              # A advances 2 tokens
    assert done == [] and eng.scheduler.num_active == 1
    rid_b = eng.submit(RalmRequest(prompt=jnp.asarray(corpus[2:4, :8]),
                                   steps=2))    # B joins mid-flight
    completions = []
    while eng.scheduler.has_work:
        completions.extend(eng.step())
    # continuous batching: B asked for 2 steps, so it completes two
    # global steps after joining — while A (6 steps) is still decoding.
    # The later-submitted request finishes first.
    order = [r.request_id for r in completions]
    assert order == [rid_b, rid_a], order
    by_id = {r.request_id: r for r in completions}
    assert by_id[rid_a].steps == 6 and by_id[rid_b].steps == 2
    assert (by_id[rid_a].tokens == ref_a).all()
    assert (by_id[rid_b].tokens == ref_b).all()


def test_scheduler_rejects_duplicate_request_id(tiny_ralm):
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    rid = eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:1, :8]),
                                 steps=1))
    with pytest.raises(ValueError, match="already issued"):
        eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:1, :8]),
                               steps=1, request_id=rid))


def test_generate_keeps_other_inflight_responses(tiny_ralm):
    """generate() drains the shared scheduler but must not discard other
    requests' completions — they surface on the next run()."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    rid_a = eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:1, :8]),
                                   steps=2))
    out_b = eng.generate(jnp.asarray(corpus[1:2, :8]), steps=4)
    assert out_b.shape == (1, 12)
    (resp_a,) = eng.run()               # A completed during generate()
    assert resp_a.request_id == rid_a and resp_a.tokens.shape == (1, 10)


def test_scheduler_zero_step_request(tiny_ralm):
    """steps=0 completes at admission with the prompt only (regression:
    the done-check must precede the decode, not follow it)."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:1, :8]), steps=0))
    (resp,) = eng.run()
    assert resp.tokens.shape == (1, 8) and resp.steps == 0


def test_scheduler_admission_control(tiny_ralm):
    """max_active bounds in-flight sequences; queued work still drains."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    eng.scheduler.max_active = 1
    for i in range(3):
        eng.submit(RalmRequest(prompt=jnp.asarray(corpus[i:i+1, :8]),
                               steps=2))
    seen_active = []
    completions = []
    while eng.scheduler.has_work:
        completions.extend(eng.step())
        seen_active.append(eng.scheduler.num_active)
    assert max(seen_active) <= 1
    assert [r.request_id for r in completions] == [0, 1, 2]


def test_scheduler_empty_queue_step(tiny_ralm):
    """step() with nothing queued or active is a no-op, not an error."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    assert not eng.scheduler.has_work
    assert eng.step() == []
    assert eng.scheduler.num_active == 0
    assert eng.run() == []              # draining nothing is also fine


def test_scheduler_all_sequences_finish_same_step(tiny_ralm):
    """Every active sequence completing on one step() empties the
    scheduler in that call and reports all completions at once."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    rids = [eng.submit(RalmRequest(prompt=jnp.asarray(corpus[i:i+1, :8]),
                                   steps=1)) for i in range(3)]
    done = eng.step()                   # one decode step finishes all 3
    assert sorted(r.request_id for r in done) == sorted(rids)
    assert not eng.scheduler.has_work and eng.scheduler.num_active == 0


def test_scheduler_max_active_reached_blocks_admission(tiny_ralm):
    """While max_active sequences are in flight, later submissions wait
    in the queue (they are admitted only as slots free up)."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    eng.scheduler.max_active = 2
    for i in range(4):
        eng.submit(RalmRequest(prompt=jnp.asarray(corpus[i:i+1, :8]),
                               steps=3))
    eng.step()
    assert eng.scheduler.num_active == 2         # admission capped
    assert len(eng.scheduler.queue) == 2         # rest still queued
    completions = eng.run()
    assert [r.request_id for r in completions] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# AsyncRetriever + RetrievalService: parity, coalescing, cache fast-path
# ---------------------------------------------------------------------------

def test_async_retriever_parity(tiny_ralm):
    """Acceptance criterion: greedy outputs via AsyncRetriever +
    RetrievalService are token-identical to the synchronous
    LocalRetriever path, under pipelined multi-request serving."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    prompts = [jnp.asarray(corpus[:2, :8]), jnp.asarray(corpus[2:4, :8])]
    sync_eng = RalmEngine.monolithic(params, cfg, rag, ds.retriever(ccfg))
    out_sync = sync_eng.generate_batches(prompts, steps=6)
    aret = ds.async_retriever(ccfg)
    assert isinstance(aret, AsyncRetriever) and isinstance(aret, Retriever)
    async_eng = RalmEngine.monolithic(params, cfg, rag, aret)
    out_async = async_eng.generate_batches(prompts, steps=6)
    for a, b in zip(out_sync, out_async):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_async_overlap_coalesces_waves(tiny_ralm):
    """Acceptance criterion: >= 2 concurrent sequences' queries coalesce
    into a single batched kernel dispatch per scheduler wave."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    aret = ds.async_retriever(ccfg)
    eng = RalmEngine.monolithic(params, cfg, rag, aret)
    eng.submit(RalmRequest(prompt=jnp.asarray(corpus[:2, :8]), steps=4))
    eng.submit(RalmRequest(prompt=jnp.asarray(corpus[2:4, :8]), steps=4))
    eng.run()
    st = aret.service.stats
    assert st.num_queries == 16                  # 2 req x 2 rows x 4 steps
    assert st.num_batches == 4                   # ONE dispatch per wave
    assert st.max_coalesced == 4                 # both sequences' rows
    assert st.coalescing_factor() == pytest.approx(4.0)


def test_async_cache_hit_skips_kernel(tiny_ralm):
    """Acceptance criterion: a repeated prompt is answered from the
    result cache — zero new kernel dispatches — with identical tokens."""
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    aret = ds.async_retriever(
        ccfg, service_cfg=ServiceConfig(cache_entries=256,
                                        cache_quant=1e-5))
    eng = RalmEngine.monolithic(params, cfg, rag, aret)
    out1 = np.asarray(eng.generate(jnp.asarray(corpus[:2, :8]), steps=4))
    n_dispatch = aret.service.stats.num_batches
    assert n_dispatch > 0 and aret.service.stats.cache_hits == 0
    out2 = np.asarray(eng.generate(jnp.asarray(corpus[:2, :8]), steps=4))
    assert (out1 == out2).all()
    assert aret.service.stats.num_batches == n_dispatch   # kernel skipped
    assert aret.service.stats.cache_hits == 8             # 2 rows x 4 steps


# ---------------------------------------------------------------------------
# the compatibility shims ride the same loop
# ---------------------------------------------------------------------------

def test_generate_shim_matches_engine(tiny_ralm):
    cfg, params, corpus, ds, ccfg, rag = tiny_ralm
    retr = RetrievalEngine(params=ds.params, shards=ds.shards, cfg=ccfg,
                           payload_tokens=ds.payload_tokens)
    assert isinstance(retr, LocalRetriever)
    out_shim = np.asarray(generate(params, cfg, rag,
                                   jnp.asarray(corpus[:2, :8]), steps=4,
                                   engine=retr))
    out_api = np.asarray(RalmEngine.monolithic(params, cfg, rag, retr)
                         .generate(jnp.asarray(corpus[:2, :8]), steps=4))
    assert (out_shim == out_api).all()


# ---------------------------------------------------------------------------
# monolithic == disaggregated (greedy parity, 8 fake devices)
# ---------------------------------------------------------------------------

def test_monolithic_disaggregated_parity():
    """Same seed, same prompts: the disaggregated engine (1 LM device +
    2 retrieval devices, DistributedRetriever) must emit exactly the
    monolithic engine's greedy tokens, for fresh and memorized prompts,
    while pipelining two request batches."""
    out = run_sub("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import transformer as tf
from repro.serve import DatastoreBuilder, RagConfig, RalmEngine

cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
params = tf.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
start = rng.integers(0, 64, size=(64,))
seqs = [start]
for _ in range(31):
    seqs.append((3 * seqs[-1] + 1) % 64)
corpus = np.stack(seqs, axis=1).astype(np.int32)

ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                      list_cap=512).from_corpus(params, cfg, corpus)
ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999, temperature=1.0)
prompts = [jnp.asarray(corpus[:4, :8]),
           jnp.asarray(rng.integers(0, 64, size=(2, 8), dtype=np.int32))]

mono = RalmEngine.monolithic(params, cfg, rag, retriever=ds.retriever(ccfg))
out_m = mono.generate_batches(prompts, steps=8)

dis = RalmEngine.disaggregated(params, cfg, rag, ds.params, ds.shards, ccfg,
                               payload_tokens=ds.payload_tokens,
                               lm_devices=1, ret_devices=2)
assert dis.backend.lm_mesh.devices.size == 1
assert dis.backend.ret_mesh.devices.size == 2
out_d = dis.generate_batches(prompts, steps=8)

for a, b in zip(out_m, out_d):
    assert (a == b).all(), (a, b)
assert (out_m[0][:, 8:] == corpus[:4, 8:16]).mean() > 0.8   # still a RALM
assert len(dis.times.decode_s) > 0 and len(dis.times.search_s) > 0
print("PARITY_OK ratio=%.2f" % dis.times.optimal_ratio())
""")
    assert "PARITY_OK" in out
