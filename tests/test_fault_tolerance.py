"""Fault tolerance: crash/restart bit-equivalence, stragglers, elasticity."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime.fault_tolerance import (SimulatedFailure, StragglerMonitor,
                                           TrainController)


def make_setup(tmp_path, name="run"):
    cfg = get_arch("dec_s").reduced
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50,
                             state_dtype="float32")

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: tf.lm_loss(p, cfg, batch, remat=False))(params)
        params, opt_state, m = adamw.apply_updates(params, grads, opt_state,
                                                   ocfg)
        m["loss"] = loss
        return params, opt_state, m

    data = SyntheticTokens(DataConfig(seq_len=16, global_batch=4,
                                      vocab_size=cfg.vocab_size))
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_opt_state(params, ocfg)
    ctl = TrainController(jax.jit(train_step), data, tmp_path / name,
                          ckpt_every=4)
    return cfg, params, opt, ctl


def test_crash_resume_identical_trajectory(tmp_path):
    """The paper-scale requirement: a node loss at any step must not change
    the training trajectory. Run A: 12 steps straight. Run B: crash at step
    8, restart, finish. Loss curves must agree exactly on shared steps."""
    _, p0, o0, ctl_a = make_setup(tmp_path, "a")
    ctl_a.run(p0, o0, total_steps=12)
    base = {m["step"]: m["loss"] for m in ctl_a.metrics_log}

    _, p1, o1, ctl_b = make_setup(tmp_path, "b")
    ctl_b.fail_at = 8
    with pytest.raises(SimulatedFailure):
        ctl_b.run(p1, o1, total_steps=12)
    # restart (fresh params — must be ignored in favor of the checkpoint)
    _, p2, o2, _ = make_setup(tmp_path, "ignored")
    ctl_b.run(p2, o2, total_steps=12)
    resumed = {m["step"]: m["loss"] for m in ctl_b.metrics_log}
    for s in range(12):
        assert s in resumed, f"step {s} missing after resume"
        np.testing.assert_allclose(resumed[s], base[s], rtol=1e-5,
                                   err_msg=f"step {s} diverged after crash")


def test_straggler_monitor():
    events = []
    mon = StragglerMonitor(threshold=2.0, on_straggler=events.append)
    for s in range(20):
        mon.record(s, 0.1)
    mon.record(20, 0.5)   # 5x median -> straggler
    assert len(events) == 1
    assert events[0].step == 20 and events[0].ratio > 2.0
    mon.record(21, 0.11)  # normal again
    assert len(events) == 1


def test_elastic_restore_shapes(tmp_path):
    """Checkpoint saved from one setup restores onto another 'device
    topology' (full arrays are mesh-agnostic; placement is re-derived)."""
    from repro.checkpoint import checkpoint as ck
    cfg = get_arch("dec_s").reduced
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    ck.save(tmp_path / "e", 10, params)
    like = jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(1), cfg))
    got, step = ck.restore(tmp_path / "e", like)
    assert step == 10
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
