"""Per-architecture smoke tests (assignment requirement: reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import transformer as tf

ARCHS = list(list_archs(include_paper=True))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.reduced
    key = jax.random.PRNGKey(0)
    params = tf.init_params(key, cfg)
    B, T = 2, 16
    batch = {"labels": jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                          cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, T, cfg.d_model), jnp.bfloat16)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(T)[None, None], (3, B, T)).astype(jnp.int32)
    else:
        batch["tokens"] = jax.random.randint(jax.random.PRNGKey(1), (B, T),
                                             0, cfg.vocab_size)
    if cfg.arch == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, 8, cfg.d_model), jnp.bfloat16)

    # forward: shapes + finiteness
    enc_states = (tf.encode(params, cfg, batch["enc_embeds"])
                  if cfg.arch == "encdec" else None)
    logits, _ = tf.forward(params, cfg, tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"),
                           positions=batch.get("positions"),
                           enc_states=enc_states)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one train step: loss finite, grads finite, params move
    loss, grads = jax.value_and_grad(
        lambda p: tf.lm_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """Prefill+decode must agree with train-mode forward on the same tokens."""
    spec = get_arch(arch)
    cfg = spec.reduced
    if cfg.frontend == "vision":
        pytest.skip("vision stub enters via embeds; covered by forward test")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    B, T = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg.vocab_size)
    enc_states = None
    enc_len = 0
    if cfg.arch == "encdec":
        enc = jax.random.normal(jax.random.PRNGKey(2), (B, 8, cfg.d_model),
                                jnp.bfloat16)
        enc_states = tf.encode(params, cfg, enc)
        enc_len = 8
    full, _ = tf.forward(params, cfg, tokens=toks, mode="train",
                         enc_states=enc_states)
    caches = tf.init_cache(cfg, B, max_seq=16, enc_len=enc_len)
    pos = jnp.broadcast_to(jnp.arange(T - 1)[None], (B, T - 1))
    if cfg.rope_mode == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, T - 1))
    _, caches = tf.forward(params, cfg, tokens=toks[:, :-1], positions=pos,
                           mode="prefill", caches=caches,
                           enc_states=enc_states)
    lg, _ = tf.decode_step(params, cfg, caches, toks[:, -1:],
                           jnp.full((B,), T - 1), enc_states=enc_states)
    # MoE capacity C depends on the token count, so prefill-vs-decode drop
    # patterns may differ for a few boundary tokens (inherent to
    # capacity-based dispatch) — compare distributions, not raw logits.
    tol = 6e-2 if cfg.block == "moe" else 2e-2
    np.testing.assert_allclose(
        np.asarray(lg, np.float32), np.asarray(full[:, -1], np.float32),
        rtol=tol, atol=tol)


def test_param_counts_match_published():
    """Full configs reproduce the published parameter counts (±3%)."""
    expect = {
        "qwen2_0_5b": 0.49e9, "llama3_405b": 405e9, "phi3_mini_3_8b": 3.8e9,
        "gemma3_4b": 3.88e9, "qwen2_vl_72b": 72e9, "dbrx_132b": 132e9,
        "phi3_5_moe_42b": 41.9e9, "rwkv6_3b": 3.1e9, "dec_s": 101e6,
        "dec_l": 1259e6,
    }
    for arch, want in expect.items():
        got = get_arch(arch).model.param_count()
        assert abs(got - want) / want < 0.03, (arch, got, want)


def test_moe_active_params():
    dbrx = get_arch("dbrx_132b").model
    assert dbrx.active_param_count() < 0.3 * dbrx.param_count()


def test_layer_pattern_classes():
    g = get_arch("gemma3_4b").model
    assert g.layer_classes().count("global") == 5   # 34 layers, 5:1 cycle
    assert g.layer_classes().count("local") == 29
    h = get_arch("hymba_1_5b").model
    assert h.layer_classes().count("global") == 2   # period-16 cycle
