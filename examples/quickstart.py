"""Quickstart: build a ChamVS index, search it, check recall — 60 seconds.

Uses the unified ``repro.serve`` surface: ``DatastoreBuilder`` owns the
train-quantizers/build-shards recipe, and searches go through the
``Retriever`` protocol that every serving deployment speaks.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivfpq import exact_search
from repro.serve import DatastoreBuilder

key = jax.random.PRNGKey(0)

# 1) a database: 16k vectors in 64-d, with cluster structure
centers = jax.random.normal(key, (64, 64))
assign = jax.random.randint(jax.random.PRNGKey(1), (16384,), 0, 64)
vecs = centers[assign] + 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                                 (16384, 64))

# 2) train IVF-PQ quantizers and build 4 "memory node" shards
#    (paper partition scheme 1: every IVF list striped across all shards)
builder = DatastoreBuilder(dim=64, nlist=64, m=16, list_cap=512,
                           num_shards=4, kmeans_iters=10, seed=0)
ds = builder.build(np.asarray(vecs), train_vectors=np.asarray(vecs[:8192]))
print(f"index: {ds.index_cfg.nlist} lists, {ds.num_shards} memory nodes, "
      f"{ds.index_cfg.db_bytes_per_vector():.0f} B/vector")

# 3) search: scan the IVF index, stream PQ codes, merge truncated top-k'
#    (through the Retriever protocol — same call the serving engine makes)
ccfg = ds.search_config(nprobe=16, k=32, backend="ref")
queries = vecs[:32] + 0.02
dists, ids = ds.retriever(ccfg).search(queries)

# 4) recall vs exact brute force: true top-10 found among the returned 32
_, true_ids = exact_search(vecs, queries, 10)
hits = float((ids[:, :, None] == true_ids[:, None, :]).any(1).mean())
print(f"search: k'={ccfg.k_prime(4)} per node (K={ccfg.k}); "
      f"R10@{ccfg.k}={hits:.3f}")
print("nearest ids[0]:", np.asarray(ids[0, :5]))

# 5) the same search through the Pallas near-memory kernel (interpret mode)
ccfg_k = ds.search_config(nprobe=16, k=32, backend="pallas")
d2, i2 = ds.retriever(ccfg_k).search(queries)
print("pallas kernel agrees:", bool(jnp.allclose(dists, d2, rtol=1e-4)))
