"""End-to-end RALM serving (paper Fig. 3 workflow) with batched requests,
through the unified ``repro.serve`` API.

Demonstrates the paper's central behavioural claim at desk scale: an
UNTRAINED tiny LM + a retrieval datastore reproduces memorized sequences,
because the knowledge lives in the database, not the weights (knowledge
editing without retraining, paper §1). The same ``RalmEngine`` runs
monolithic (one mesh) or disaggregated (LM pool + retrieval pool) —
identical tokens either way.

    PYTHONPATH=src python examples/serve_ralm.py [--disaggregate]

``--gateway`` instead serves the same engine over HTTP (OpenAI-style
``/v1/completions`` with SSE streaming; see docs/serving.md):

    PYTHONPATH=src python examples/serve_ralm.py --gateway --port 8000
    curl -N localhost:8000/v1/completions -H 'Content-Type: application/json' \
      -d '{"prompt": [17, 52, 31, 30, 27, 18, 55, 38],
           "max_tokens": 8, "stream": true}'
    # data: {"id": "cmpl-0", ..., "choices": [{"text": " 5", ...}]}
    # ...
    # data: [DONE]
"""
import argparse
import dataclasses
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import transformer as tf
from repro.serve import (DatastoreBuilder, RagConfig, RalmEngine,
                         ServiceConfig)

ap = argparse.ArgumentParser()
ap.add_argument("--disaggregate", action="store_true")
ap.add_argument("--async-retrieval", action="store_true",
                help="serve searches through a RetrievalService (wave "
                     "coalescing + LRU result cache)")
ap.add_argument("--per-sequence", action="store_true",
                help="use the per-sequence oracle decode loop instead of "
                     "wave-batched decode over the KV-cache pool")
ap.add_argument("--kv-slots", type=int, default=None,
                help="fix the KV pool capacity in prompt rows (admission "
                     "defers when full); default grows on demand")
ap.add_argument("--gateway", action="store_true",
                help="serve the engine over HTTP instead of running the "
                     "batch demo: OpenAI-style /v1/completions with SSE "
                     "streaming, per-tenant admission, load shedding")
ap.add_argument("--port", type=int, default=8000,
                help="gateway listen port (with --gateway)")
args = ap.parse_args()
wave = not args.per_sequence

# tiny decoder RALM (paper Dec-S family, reduced)
cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
params = tf.init_params(jax.random.PRNGKey(0), cfg)

# a corpus with deterministic structure: token t -> (3t+1) mod 64
rng = np.random.default_rng(0)
start = rng.integers(0, 64, size=(64,))
seqs = [start]
for _ in range(31):
    seqs.append((3 * seqs[-1] + 1) % 64)
corpus = np.stack(seqs, axis=1).astype(np.int32)

# deployment shape first: disaggregated needs one datastore shard per
# retrieval-pool device (memory node)
disaggregate = args.disaggregate and len(jax.devices()) >= 2
ret_devices = min(2, len(jax.devices()) - 1) if disaggregate else 1
num_shards = ret_devices if disaggregate else 2

# datastore: hidden state of every prefix -> next token (kNN-LM, interval 1)
ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8, list_cap=512,
                      num_shards=num_shards).from_corpus(params, cfg, corpus)
ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
print(f"datastore: {ds.num_vectors} vectors, {ds.num_shards} memory nodes, "
      f"k'={ccfg.k_prime(ds.num_shards)}")

rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999, temperature=1.0)

if disaggregate:
    if args.async_retrieval:
        import warnings
        warnings.warn("--async-retrieval is not wired into the "
                      "disaggregated path; using the synchronous "
                      "DistributedRetriever", RuntimeWarning)
    engine = RalmEngine.disaggregated(
        params, cfg, rag, ds.params, ds.shards, ccfg,
        payload_tokens=ds.payload_tokens, lm_devices=1,
        ret_devices=ret_devices, wave=wave, kv_slots=args.kv_slots)
    print(f"disaggregated pools: "
          f"LM={engine.backend.lm_mesh.devices.size} dev, "
          f"retrieval={engine.backend.ret_mesh.devices.size} dev")
elif args.async_retrieval:
    # searches coalesce per scheduler wave into one batched dispatch
    engine = RalmEngine.monolithic(
        params, cfg, rag,
        retriever=ds.async_retriever(ccfg,
                                     service_cfg=ServiceConfig(
                                         cache_entries=1024)),
        wave=wave, kv_slots=args.kv_slots)
else:
    engine = RalmEngine.monolithic(params, cfg, rag,
                                   retriever=ds.retriever(ccfg),
                                   wave=wave, kv_slots=args.kv_slots)

if args.gateway:
    # same engine, served over HTTP: streaming SSE completions, tenant
    # quotas + queue-depth backpressure, retrieval-quality degradation
    # under load (docs/serving.md, "The front door")
    from repro.serve import Gateway, GatewayConfig
    Gateway(engine, GatewayConfig(port=args.port)).serve_forever()
    sys.exit(0)

# two request batches in flight at once: the scheduler pipelines them
outs = engine.generate_batches([jnp.asarray(corpus[:4, :8]),
                                jnp.asarray(corpus[4:8, :8])], steps=8)
out = outs[0]

acc = (out[:, 8:16] == corpus[:4, 8:16]).mean()
print(f"retrieval-augmented continuation accuracy: {acc:.2f} "
      f"(untrained LM alone would be ~{1/64:.3f})")
print("generated :", out[0, 8:16].tolist())
print("ground tru:", corpus[0, 8:16].tolist())

if engine.pool is not None:   # wave mode: the whole batch rides one dispatch
    ps = engine.pool.stats
    print(f"kv pool: {engine.pool.capacity} slots "
          f"(high water {ps.high_water}), {ps.waves} waves of "
          f"{ps.mean_wave():.1f} rows avg in {engine.decode_dispatches} "
          f"LM dispatches, buckets {sorted(ps.buckets)}")
service = getattr(engine.retriever, "service", None)
if service is not None:   # async path only (--disaggregate has no service)
    st = service.stats
    print(f"retrieval service: {st.batched_rows} query rows coalesced "
          f"into {st.num_batches} kernel dispatches "
          f"({st.coalescing_factor():.1f} rows/dispatch, "
          f"{st.cache_hits} cache hits)")
