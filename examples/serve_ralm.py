"""End-to-end RALM serving (paper Fig. 3 workflow) with batched requests.

Demonstrates the paper's central behavioural claim at desk scale: an
UNTRAINED tiny LM + a retrieval datastore reproduces memorized sequences,
because the knowledge lives in the database, not the weights (knowledge
editing without retraining, paper §1).

    PYTHONPATH=src python examples/serve_ralm.py [--disaggregate]
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.chamvs import ChamVSConfig
from repro.core.generate import RetrievalEngine, generate
from repro.core.ivfpq import IVFPQConfig, build_shards, train_ivfpq
from repro.core.rag import RagConfig
from repro.models import transformer as tf

ap = argparse.ArgumentParser()
ap.add_argument("--disaggregate", action="store_true")
args = ap.parse_args()

# tiny decoder RALM (paper Dec-S family, reduced)
import dataclasses
cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
params = tf.init_params(jax.random.PRNGKey(0), cfg)

# a corpus with deterministic structure: token t -> (3t+1) mod 64
rng = np.random.default_rng(0)
start = rng.integers(0, 64, size=(64,))
seqs = [start]
for _ in range(31):
    seqs.append((3 * seqs[-1] + 1) % 64)
corpus = np.stack(seqs, axis=1).astype(np.int32)

# datastore: hidden state of every prefix -> next token (kNN-LM, interval 1)
_, _, hidden = tf.forward(params, cfg, tokens=jnp.asarray(corpus),
                          mode="train", return_hidden=True)
keys = np.asarray(hidden[:, :-1].astype(jnp.float32)).reshape(-1, cfg.d_model)
payload = jnp.asarray(corpus[:, 1:].reshape(-1))
icfg = IVFPQConfig(dim=cfg.d_model, nlist=8, m=8, list_cap=512)
db = train_ivfpq(jax.random.PRNGKey(1), jnp.asarray(keys), icfg,
                 kmeans_iters=8)
shards = build_shards(db, keys, icfg, num_shards=2)
ccfg = ChamVSConfig(ivfpq=icfg, nprobe=4, k=8, backend="ref")
print(f"datastore: {keys.shape[0]} vectors, 2 memory nodes, "
      f"k'={ccfg.k_prime(2)}")

rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999, temperature=1.0)

if args.disaggregate and len(jax.devices()) >= 2:
    from repro.core.coordinator import DisaggregatedRuntime
    rt = DisaggregatedRuntime(cfg, rag, params, db, shards, ccfg,
                              payload_tokens=payload, lm_devices=1,
                              ret_devices=1)
    outs = rt.generate_pipelined([jnp.asarray(corpus[:4, :8]),
                                  jnp.asarray(corpus[4:8, :8])], steps=8)
    out = outs[0]
    print(f"disaggregated pools: LM={rt.lm_mesh.devices.size} dev, "
          f"retrieval={rt.ret_mesh.devices.size} dev")
else:
    engine = RetrievalEngine(params=db, shards=shards, cfg=ccfg,
                             payload_tokens=payload)
    out = np.asarray(generate(params, cfg, rag, jnp.asarray(corpus[:4, :8]),
                              steps=8, engine=engine))

acc = (out[:, 8:16] == corpus[:4, 8:16]).mean()
print(f"retrieval-augmented continuation accuracy: {acc:.2f} "
      f"(untrained LM alone would be ~{1/64:.3f})")
print("generated :", out[0, 8:16].tolist())
print("ground tru:", corpus[0, 8:16].tolist())
