"""Train a retrieval-augmented encoder-decoder (RETRO-style, paper EncDec
family) end-to-end: the training batch carries retrieved-chunk embeddings
for the shallow encoder; the decoder cross-attends (paper §2.1 category 1).

Default runs a ~100M-param class model (paper EncDec-S) at reduced size for
a few hundred CPU steps with checkpointing + crash-safe resume; pass
``--full`` on real hardware for the exact Table-2 config.

    PYTHONPATH=src python examples/train_retro.py --steps 200
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime.fault_tolerance import TrainController

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--full", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/retro_ckpt")
args = ap.parse_args()

spec = get_arch("encdec_s")
cfg = spec.model if args.full else spec.reduced
rag = spec.rag
print(f"model: {cfg.name} ({cfg.param_count()/1e6:.2f}M params, "
      f"{cfg.n_enc_layers}-layer encoder + {cfg.n_layers}-layer decoder, "
      f"retrieval interval {rag.interval}, K={rag.k})")

ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
params = tf.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init_opt_state(params, ocfg)

base = SyntheticTokens(DataConfig(seq_len=32 if not args.full else 512,
                                  global_batch=4 if not args.full else 64,
                                  vocab_size=cfg.vocab_size))
enc_len = 16 if not args.full else rag.k * rag.chunk_len


class RetroData:
    """Wraps the token stream with retrieved-chunk embeddings (here the
    chunk embeddings are derived deterministically from the labels —
    an informative retrieval oracle, so the cross-attention pathway is
    actually trained to use the encoder)."""

    def __init__(self, src):
        self.src = src

    def host_batch(self, step, host_id=0, num_hosts=1):
        b = self.src.host_batch(step, host_id, num_hosts)
        rng = np.random.Generator(np.random.Philox(key=99, counter=step))
        B = b["tokens"].shape[0]
        # chunk embeddings correlated with the labels' prefix
        sig = b["labels"][:, :enc_len] % 31
        emb = (np.take(np.eye(32, cfg.d_model, dtype=np.float32), sig, 0)
               + 0.1 * rng.normal(size=(B, enc_len, cfg.d_model)))
        b["enc_embeds"] = emb.astype(np.float32)
        return b


def train_step(params, opt_state, batch):
    batch = dict(batch, enc_embeds=batch["enc_embeds"].astype(jnp.bfloat16))
    loss, grads = jax.value_and_grad(
        lambda p: tf.lm_loss(p, cfg, batch))(params)
    params, opt_state, m = adamw.apply_updates(params, grads, opt_state, ocfg)
    m["loss"] = loss
    return params, opt_state, m


ctl = TrainController(jax.jit(train_step), RetroData(base), args.ckpt_dir,
                      ckpt_every=50)
params, opt = ctl.run(params, opt, total_steps=args.steps)
losses = [m["loss"] for m in ctl.metrics_log]
k = max(len(losses) // 10, 1)
print(f"loss: {np.mean(losses[:k]):.4f} (first {k}) -> "
      f"{np.mean(losses[-k:]):.4f} (last {k})")
assert np.mean(losses[-k:]) < np.mean(losses[:k]), "did not learn"
print("checkpoints in", args.ckpt_dir)
