"""Roofline table (§Roofline deliverable): aggregates the dry-run records
into per-(arch x shape x mesh) terms, dominant bottleneck, MODEL_FLOPS
ratios, and the one-line bottleneck diagnosis."""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List

RESULTS = pathlib.Path(__file__).resolve().parent / "results"

ADVICE = {
    "compute_s": ("shard the replicated compute (uneven dims) or cut remat "
                  "recompute; MXU is the ceiling"),
    "memory_s": ("cut activation/cache traffic: fused scans, smaller "
                 "intermediates, int8 DB codes, split-KV reads"),
    "collective_s": ("reduce all-gather volume: FSDP prefetch overlap, "
                     "k'-truncated result aggregation, 1D-sharded tables"),
}


def load_records() -> List[Dict]:
    recs = []
    for f in sorted(RESULTS.glob("dryrun_*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def roofline_rows() -> List[Dict]:
    rows = []
    for r in load_records():
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "SKIP":
            rows.append(dict(name=name, us_per_call=0.0,
                             derived=f"SKIP;{r['reason'][:60]}"))
            continue
        if r.get("status") != "OK":
            rows.append(dict(name=name, us_per_call=0.0,
                             derived=f"FAIL;{r.get('error','')[:60]}"))
            continue
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        dom = r["dominant"]
        rows.append(dict(
            name=name,
            us_per_call=step * 1e6,
            derived=(f"dom={dom.replace('_s','')};"
                     f"c={r['compute_s']:.2e};m={r['memory_s']:.2e};"
                     f"n={r['collective_s']:.2e};"
                     f"useful={r['useful_flops_ratio']:.2f}")))
    return rows


def markdown_table(mesh: str = "single") -> str:
    """Full §Roofline table for EXPERIMENTS.md."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful ratio | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records():
        if r["mesh"] != mesh:
            continue
        if r.get("status") == "SKIP":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | "
                         f"— | — | {r['reason'][:70]} |")
            continue
        if r.get("status") != "OK":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | FAIL "
                         f"| — | — | {r.get('error','')[:70]} |")
            continue
        dom = r["dominant"].replace("_s", "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | **{dom}** | "
            f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.2f} | "
            f"{ADVICE[r['dominant']]} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table("single"))
    print()
    print(markdown_table("multi"))
