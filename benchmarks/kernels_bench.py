"""Fused vs staged ChamVS scan sweep (``--mode kernels``).

One row per (batch B, database size n, nprobe, shard count): the same
retrieval-service flush served by

  * **staged** — the per-shard pipeline (one chamvs dispatch per shard,
    materialized per-shard candidates, separate top-k per shard), the
    parity oracle; vs
  * **fused**  — ONE ``chamvs_scan`` dispatch over the
    ``stack_shards``-packed stack.

Both run the same kernel backend — default "pallas", the backend the
fusion claim is actually about: staged lowers to S separate
``pq_adc`` ``pallas_call``s plus per-shard top-k passes, fused lowers
to ONE ``chamvs_scan`` ``pallas_call`` with the running top-k' in its
grid. (With ``backend="ref"`` both modes already compile to a single
XLA executable per flush — there is no dispatch structure left to
measure, only XLA fusion luck — so the ref sweep is not the committed
artifact.) On a CPU host the Pallas kernels run in interpret mode;
relative cost there tracks grid-step count and per-step work, which is
exactly what the fusion changes — on a real accelerator pass
``interpret=False`` via the config.

Methodology notes (documented in the JSON meta):
  * batch sizes start at the service's wave scale (B >= 8) — sub-wave
    flushes are dispatch-overhead-dominated and the whole point of the
    retrieval service is that B=1 submits coalesce into waves;
  * the two modes are measured in adjacent paired windows and the
    reported speedup is the MEDIAN of per-pair ratios: sandbox/container
    noise on this host comes in multi-second epochs (a window can run
    1.5x slower than its neighbor), so per-mode minima can sample
    different epochs and fabricate regressions — the paired ratio
    cancels the epoch, the median rejects the stragglers. Reported
    walls are per-mode medians;
  * XLA runs single-threaded-eigen (set before jax imports) — on the
    2-vCPU sandbox this removes thread-pool jitter that otherwise
    swamps the structural difference;
  * the index uses nlist >= PALLAS_MIN_NLIST so the probe stage really
    runs the Pallas centroid scan; ``pallas_fallbacks`` per row proves
    no reference path leaked into a "pallas" number.

Emits ``BENCH_kernels.json`` via ``python -m benchmarks.run --mode
kernels``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Sequence

# must happen before jax initializes its CPU client (benchmarks.run only
# imports this module for --mode kernels, before any jax import)
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import jax
import jax.numpy as jnp
import numpy as np


def _build(dim: int, n_vecs: int, nlist: int, num_shards: int):
    from repro.core.ivfpq import IVFPQConfig, build_shards, train_ivfpq
    # cap at 4x the mean list load: k-means cluster sizes are skewed and
    # the padded layout must hold the largest per-shard list slice
    icfg = IVFPQConfig(dim=dim, nlist=nlist, m=max(dim // 8, 4),
                       list_cap=max(4 * n_vecs // (nlist * num_shards), 64))
    key = jax.random.PRNGKey(0)
    vecs = jax.random.normal(key, (n_vecs, dim))
    params = train_ivfpq(key, vecs[:min(n_vecs, 4096)], icfg,
                         kmeans_iters=6)
    shards = build_shards(params, np.asarray(vecs), icfg,
                          num_shards=num_shards)
    return icfg, params, shards


def _make_service(params, shards, cfg, max_batch: int):
    from repro.retrieval.service import RetrievalService, ServiceConfig
    return RetrievalService.local(
        params, shards, cfg, ServiceConfig(max_batch=max_batch,
                                           measure=True))


def _window(svc, queries, iters: int) -> float:
    svc.stats.reset()
    t0 = time.perf_counter()
    for it in range(1, iters + 1):
        svc.search(queries[it])
    return time.perf_counter() - t0


def run_sweep(
    batch_sizes: Sequence[int] = (8, 16),
    n_vecs_sweep: Sequence[int] = (4096, 8192),
    nprobes: Sequence[int] = (4, 16),
    shard_counts: Sequence[int] = (1, 4, 8),
    dim: int = 64,
    nlist: int = 128,
    k: int = 10,
    iters: int = 3,
    windows: int = 5,
    backend: str = "pallas",
) -> List[Dict[str, object]]:
    """One row per (B, n, nprobe, shards) with the fused and staged
    wall/stage breakdown side by side."""
    from repro.core.chamvs import ChamVSConfig
    from repro.kernels import registry

    rng = np.random.default_rng(0)
    rows: List[Dict[str, object]] = []
    for n_vecs in n_vecs_sweep:
        for num_shards in shard_counts:
            icfg, params, shards = _build(dim, n_vecs, nlist, num_shards)
            for nprobe in nprobes:
                for batch in batch_sizes:
                    queries = jnp.asarray(
                        rng.normal(size=(iters + 1, batch, dim)),
                        jnp.float32)
                    registry.reset_warnings()
                    svcs, walls = {}, {"fused": [], "staged": []}
                    for mode, fused in (("fused", True), ("staged", False)):
                        cfg = ChamVSConfig(ivfpq=icfg, nprobe=nprobe, k=k,
                                           backend=backend, fused=fused)
                        svcs[mode] = _make_service(params, shards, cfg,
                                                   batch)
                        svcs[mode].search(queries[0])   # warmup/compile
                    # adjacent paired windows: host noise epochs hit both
                    # modes of a pair, so the per-pair ratio cancels them
                    for _ in range(windows):
                        walls["staged"].append(
                            _window(svcs["staged"], queries, iters))
                        walls["fused"].append(
                            _window(svcs["fused"], queries, iters))
                    speedup = float(np.median(
                        [s / f for s, f in zip(walls["staged"],
                                               walls["fused"])]))
                    res = {}
                    for mode in ("fused", "staged"):
                        snap = svcs[mode].stats.snapshot()
                        res[mode] = dict(
                            wall_us_per_flush=float(
                                np.median(walls[mode])) / iters * 1e6,
                            scan_us=snap["scan"]["mean_us"],
                            merge_us=snap["merge"]["mean_us"],
                            scan_dispatches_per_flush=snap[
                                "scan_dispatches"] / snap["num_batches"],
                        )
                    row = dict(
                        batch=batch, n_vecs=n_vecs, nprobe=nprobe,
                        num_shards=num_shards, backend=backend,
                        pallas_fallbacks=registry.fallback_count(),
                        fused=res["fused"], staged=res["staged"],
                        speedup=speedup,
                    )
                    rows.append(row)
                    print(f"B={batch} n={n_vecs} nprobe={nprobe} "
                          f"S={num_shards}: fused "
                          f"{res['fused']['wall_us_per_flush']:.0f}us vs "
                          f"staged "
                          f"{res['staged']['wall_us_per_flush']:.0f}us "
                          f"({row['speedup']:.2f}x)")
    return rows


def main(out_path: str = "BENCH_kernels.json") -> None:
    rows = run_sweep()
    worse = [r for r in rows if r["speedup"] < 1.0]
    meta = dict(
        backend=rows[0]["backend"] if rows else "ref",
        note="fused = ONE chamvs_scan pallas_call over all shards; "
             "staged = per-shard pq_adc pallas_calls + per-shard top-k "
             "(parity oracle). Same backend both sides (pallas, "
             "interpret mode on this CPU host). speedup = median of "
             "adjacent paired-window ratios (cancels host noise "
             "epochs); walls are per-mode medians; single-threaded-"
             "eigen XLA; B >= 8 (wave scale — the service coalesces "
             "B=1 submits); nlist >= PALLAS_MIN_NLIST so the probe "
             "stage is genuinely Pallas (pallas_fallbacks per row).",
        points=len(rows),
        fused_never_slower=not worse,
    )
    with open(out_path, "w") as f:
        json.dump(dict(meta=meta, rows=rows), f, indent=2)
    print(f"wrote {out_path} ({len(rows)} rows; "
          f"fused_never_slower={not worse})")
    if worse:
        for r in worse:
            print(f"  REGRESSION: B={r['batch']} n={r['n_vecs']} "
                  f"nprobe={r['nprobe']} S={r['num_shards']} "
                  f"speedup={r['speedup']:.2f}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    main()
