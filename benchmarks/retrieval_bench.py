"""Retrieval QPS/latency sweep against the ``RetrievalService``
(paper Fig. 9/10 axes: batch size x nprobe, with the queue-wait /
scan / merge breakdown from ``repro.retrieval.stats``).

Run via ``python -m benchmarks.run --mode retrieval``; emits
``BENCH_retrieval.json`` with one row per (batch, nprobe) point.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _build(dim: int, n_vecs: int, nlist: int, num_shards: int):
    from repro.core.ivfpq import IVFPQConfig, build_shards, train_ivfpq
    icfg = IVFPQConfig(dim=dim, nlist=nlist, m=max(dim // 8, 4),
                       list_cap=max(2 * n_vecs // (nlist * num_shards), 64))
    key = jax.random.PRNGKey(0)
    vecs = jax.random.normal(key, (n_vecs, dim))
    params = train_ivfpq(key, vecs[:min(n_vecs, 4096)], icfg,
                         kmeans_iters=6)
    shards = build_shards(params, np.asarray(vecs), icfg,
                          num_shards=num_shards)
    return icfg, params, shards


def run_sweep(
    batch_sizes: Sequence[int] = (1, 4, 16, 64),
    nprobes: Sequence[int] = (4, 16),
    dim: int = 64,
    n_vecs: int = 8192,
    nlist: int = 64,
    num_shards: int = 4,
    k: int = 10,
    iters: int = 8,
    backend: str = "ref",
    merge_fanout: Optional[int] = None,
) -> List[Dict[str, object]]:
    """One row per (batch, nprobe): QPS + per-stage latency means.

    Each point uses a fresh service (fresh stats); one warmup flush per
    point excludes compile time from the measured window."""
    from repro.core.chamvs import ChamVSConfig
    from repro.retrieval.service import RetrievalService, ServiceConfig

    icfg, params, shards = _build(dim, n_vecs, nlist, num_shards)
    rng = np.random.default_rng(0)
    rows: List[Dict[str, object]] = []
    for nprobe in nprobes:
        cfg = ChamVSConfig(ivfpq=icfg, nprobe=nprobe, k=k, backend=backend)
        for batch in batch_sizes:
            svc = RetrievalService.local(
                params, shards, cfg,
                ServiceConfig(max_batch=batch, measure=True,
                              merge_fanout=merge_fanout))
            queries = jnp.asarray(
                rng.normal(size=(iters + 1, batch, dim)), jnp.float32)
            svc.search(queries[0])              # warmup: compile both stages
            svc.stats.reset()
            t0 = time.perf_counter()
            for it in range(1, iters + 1):
                svc.search(queries[it])
            wall = time.perf_counter() - t0
            snap = svc.stats.snapshot()
            rows.append(dict(
                batch=batch, nprobe=nprobe, num_shards=num_shards,
                backend=backend,
                merge_fanout=merge_fanout,
                qps=snap["num_queries"] / wall,
                us_per_query=wall / snap["num_queries"] * 1e6,
                queue_wait_us=snap["queue_wait"]["mean_us"],
                scan_us=snap["scan"]["mean_us"],
                merge_us=snap["merge"]["mean_us"],
                num_batches=snap["num_batches"],
                coalescing_factor=snap["coalescing_factor"],
            ))
    return rows


def main(out_path: str = "BENCH_retrieval.json") -> None:
    rows = run_sweep()
    with open(out_path, "w") as f:
        json.dump(dict(rows=rows), f, indent=2)
    print("batch,nprobe,qps,queue_wait_us,scan_us,merge_us")
    for r in rows:
        print(f"{r['batch']},{r['nprobe']},{r['qps']:.1f},"
              f"{r['queue_wait_us']:.1f},{r['scan_us']:.1f},"
              f"{r['merge_us']:.1f}")
    print(f"wrote {out_path} ({len(rows)} rows)")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    main()
