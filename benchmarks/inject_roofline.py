"""Regenerate the §Roofline table inside EXPERIMENTS.md from the dry-run
records (run after a sweep)."""
import pathlib
import re
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.roofline import markdown_table

exp = pathlib.Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"
text = exp.read_text()
table = markdown_table("single")
marker = "<!-- ROOFLINE_TABLE_SINGLE -->"
if marker in text:
    text = text.replace(marker, marker + "\n\n" + table)
else:
    # replace the previously injected table (between marker-begin lines)
    text = re.sub(r"(<!-- ROOFLINE_BEGIN -->).*?(<!-- ROOFLINE_END -->)",
                  r"\1\n" + table + r"\n\2", text, flags=re.S)
exp.write_text(text)
print("injected", len(table.splitlines()), "rows")
