"""Speculative-retrieval sweep: how much of the per-step retrieval
block does verify-and-rollback speculation hide?

Run via ``python -m benchmarks.run --mode speculation``; merges a
``speculation`` section into ``BENCH_serve.json``.

Method. Two engines per (speculate_k, interval, wave) cell over ONE
model + datastore:

  * baseline — ``speculate_k=0``, ``ServiceConfig.measure=True``: every
    due step sits behind the real search, and the service's blocking
    stage timers report exactly what it waited for. The denominator is
    the per-flush ``queue_wait + scan`` time — the retrieval block the
    baseline pays on the decode path.
  * speculating — ``speculate_k=k``, ``measure=False`` (blocking stage
    timers would serialize the flush and destroy the overlap being
    measured): due steps decode ahead on stale neighbors; the residual
    block is ``spec_wait`` (forcing the in-flight results at harvest —
    XLA drains its queue in enqueue order, so this wait covers only the
    scan, not the decode wave dispatched after it) plus ``spec_replay``
    (rollback re-decodes). The numerator is their sum.

``hidden_fraction = 1 - (spec_wait + spec_replay) / (queue_wait +
scan)`` over whole runs — the NET fraction of the baseline's retrieval
block the speculating engine no longer pays, rollback cost included
(``hidden_fraction_gross`` excludes replay for the decomposition).
``landed_fraction`` is the direct observation backing it: the share of
harvested points whose result arrays were ALREADY materialized
(``jax.Array.is_ready``) before the harvest forced them — those points
paid zero residual wait, the search ran entirely under the decode.

Corpus choice is load-bearing and reported, not hidden: acceptance is
workload-dependent. Queries one step apart retrieve the same payload
token only when the local context repeats, so the corpus here is
RUN-STRUCTURED (tokens repeat in runs of ``RUN_LEN=8``): consecutive
retrievals agree ~7/8 of the time, the regime speculation targets
(RaLMSpec §4 reports the same corpus sensitivity). A bigram corpus
(every step a new token) drives acceptance to ~0 and turns speculation
into pure rollback churn — that regime is covered by the parity tests,
not claimed as a speedup.

Greedy parity (base tokens == spec tokens) is asserted per cell and
recorded in each row: the hiding claim only counts if the output is
token-identical.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Sequence

RUN_LEN = 8
STEPS = 24
PROMPT_LEN = 4


def _build_world():
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.models import transformer as tf
    from repro.serve import DatastoreBuilder, RagConfig

    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # run-structured corpus: each row is 32/RUN_LEN runs of RUN_LEN
    # repeated tokens — consecutive-step retrievals agree inside a run
    runs = rng.integers(0, 64, size=(64, 32 // RUN_LEN))
    corpus = np.repeat(runs, RUN_LEN, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                          list_cap=512).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    return cfg, params, corpus, ds, ccfg, rag


def _make_engine(world, spec_k: int, interval: int, measure: bool):
    import dataclasses

    from repro.serve import RalmEngine, ServiceConfig

    cfg, params, _, ds, ccfg, rag = world
    rag = dataclasses.replace(rag, interval=interval)
    ret = ds.async_retriever(ccfg, service_cfg=ServiceConfig(
        measure=measure, cache_entries=0))
    return RalmEngine.monolithic(params, cfg, rag, retriever=ret,
                                 speculate_k=spec_k)


def _run_once(world, eng, wave: int, steps: int = STEPS):
    """One request of ``wave`` rows decoded to completion; returns
    (tokens, wall_s)."""
    import jax.numpy as jnp

    from repro.serve import RalmRequest

    corpus = world[2]
    prompt = jnp.asarray(corpus[0:wave, :PROMPT_LEN])
    t0 = time.perf_counter()
    eng.submit(RalmRequest(prompt=prompt, steps=steps))
    resp = eng.run()[0]
    return resp.tokens, time.perf_counter() - t0


def run_sweep(spec_ks: Sequence[int] = (1, 2),
              intervals: Sequence[int] = (1, 2),
              waves: Sequence[int] = (1, 2, 4, 8)) -> List[Dict]:
    import numpy as np

    world = _build_world()
    rows: List[Dict] = []
    for interval in intervals:
        for wave in waves:
            base = _make_engine(world, 0, interval, measure=True)
            # warm at FULL length: kv_len buckets grow with position, so
            # a short warmup leaves decode graphs uncompiled and the
            # measured window absorbs backend_compile time
            _run_once(world, base, wave, steps=STEPS)
            base.retriever.service.stats.reset()
            base_toks, base_s = _run_once(world, base, wave)
            bst = base.retriever.service.stats
            base_block_s = bst.queue_wait.total_s + bst.scan.total_s
            base_flushes = max(bst.num_batches, 1)
            for spec_k in spec_ks:
                spec = _make_engine(world, spec_k, interval,
                                    measure=False)
                _run_once(world, spec, wave, steps=STEPS)
                spec.retriever.service.stats.reset()
                spec_toks, spec_s = _run_once(world, spec, wave)
                sst = spec.retriever.service.stats
                resid_s = sst.spec_wait.total_s + sst.spec_replay.total_s
                parity = bool(np.array_equal(np.asarray(base_toks),
                                             np.asarray(spec_toks)))
                ntok = wave * STEPS
                rows.append(dict(
                    speculate_k=spec_k, interval=interval, wave=wave,
                    spec_issued=sst.spec_issued,
                    spec_verified=sst.spec_verified,
                    spec_landed=sst.spec_landed,
                    landed_fraction=round(
                        sst.spec_landed
                        / max(sst.spec_verified + sst.spec_discarded, 1),
                        4),
                    spec_accepted=sst.spec_accepted,
                    spec_rollbacks=sst.spec_rollbacks,
                    spec_replayed_steps=sst.spec_replayed_steps,
                    acceptance_rate=round(sst.spec_acceptance_rate(), 4),
                    base_block_us_per_flush=round(
                        base_block_s / base_flushes * 1e6, 1),
                    spec_wait_us_total=round(
                        sst.spec_wait.total_s * 1e6, 1),
                    spec_replay_us_total=round(
                        sst.spec_replay.total_s * 1e6, 1),
                    hidden_fraction=round(
                        1.0 - resid_s / base_block_s, 4)
                    if base_block_s > 0 else None,
                    hidden_fraction_gross=round(
                        1.0 - sst.spec_wait.total_s / base_block_s, 4)
                    if base_block_s > 0 else None,
                    base_tokens_per_s=round(ntok / base_s, 1),
                    spec_tokens_per_s=round(ntok / spec_s, 1),
                    parity=parity,
                ))
                r = rows[-1]
                print(f"k={spec_k} interval={interval} wave={wave}: "
                      f"accept={r['acceptance_rate']:.0%} "
                      f"rollbacks={r['spec_rollbacks']} "
                      f"hidden={r['hidden_fraction']} "
                      f"landed={r['landed_fraction']:.0%} "
                      f"parity={parity}")
    return rows


def main(out_path: str = "BENCH_serve.json") -> None:
    rows = run_sweep()
    meta = dict(
        run_len=RUN_LEN, steps=STEPS, prompt_len=PROMPT_LEN,
        note="hidden_fraction = 1 - (spec_wait + spec_replay) / "
             "(queue_wait + scan), whole-run totals: the NET share of "
             "the baseline's per-step retrieval block that speculation "
             "removed from the decode path, rollback replay cost "
             "included. spec_wait times ONLY the forcing of the "
             "in-flight result arrays at harvest (XLA executes its "
             "queue in enqueue order, so the wait excludes the decode "
             "wave dispatched after the scan); the verification math "
             "is excluded because the baseline pays the same "
             "interpolate in its finish phase. landed_fraction is the "
             "model-free cross-check: the share of harvested points "
             "whose results were already materialized (is_ready) "
             "before forcing — those searches ran entirely under the "
             "decode wave(s). Denominator from a speculation-off "
             "engine with blocking stage timers (measure=True); "
             "numerator from the speculating engine with measure=False "
             "(blocking timers would serialize the flush being "
             "overlapped). Corpus is "
             "run-structured (runs of run_len repeated tokens) so "
             "consecutive retrievals agree ~(run_len-1)/run_len of the "
             "time — acceptance is WORKLOAD-DEPENDENT and this file "
             "reports the favorable regime speculation targets; "
             "adversarial (bigram) corpora drive acceptance to ~0 and "
             "are covered by the parity tests instead. parity = greedy "
             "token-identity of the speculating run vs its baseline. "
             "Caveat: on a single-core host the overlapped scan still "
             "consumes serialized CPU time, so base/spec tokens_per_s "
             "stay comparable — hidden_fraction measures the decode-"
             "path BLOCK removed, which converts to wall-clock speedup "
             "only where the search runs on spare cores or a separate "
             "accelerator (the paper's disaggregated setting).")
    section = dict(meta=meta, rows=rows)
    try:
        with open(out_path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        doc = {}
    doc["speculation"] = section
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)

    parity_ok = all(r["parity"] for r in rows)
    big = [r for r in rows if r["wave"] >= 4
           and r["hidden_fraction"] is not None]
    claim = all(r["hidden_fraction_gross"] >= 0.70 for r in big)
    net_min = min(r["hidden_fraction"] for r in big) if big else None
    print(f"wrote {out_path} (speculation section, {len(rows)} rows); "
          f"greedy parity everywhere: {parity_ok}; "
          f">=70% of queue_wait+scan hidden at wave>=4: {claim} "
          f"(worst-case net, rollback replay charged: {net_min})")


if __name__ == "__main__":
    main()
