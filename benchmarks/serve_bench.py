"""Serving throughput sweep: tokens/s vs. active wave size over the
wave-batched ``RalmEngine`` (one LM dispatch + one retrieval dispatch
per scheduler wave), with the per-pool step breakdown — LM decode time
from a blocking timer around ``decode_wave``, retrieval stage times from
``repro.retrieval.stats``.

Run via ``python -m benchmarks.run --mode serve``; emits
``BENCH_serve.json`` with one row per (pool provisioning, wave size).
Two acceptance claims:
tokens/s improves monotonically-or-flat from wave size 1 to the max
bucket (the whole wave rides one dispatch, so adding rows amortizes the
per-step dispatch + kernel fixed costs — paper §5, Fig. 9/12 batch
sweeps), and the length-aware decode-attention path beats the legacy
full-pool einsum path per LM step (``lm_speedup``: adjacent
paired-window A/B against a second, legacy-configured engine in the
same process — the only comparison that survives this host's
multi-second noise epochs).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Sequence


class _TimedWave:
    """Blocking timer around a backend's ``decode_wave`` (the LM-pool
    side of the per-pool breakdown; retrieval stages come from the
    service stats, which block per flush the same way)."""

    def __init__(self, backend):
        self.backend = backend
        self.times_s: List[float] = []
        self._orig = backend.decode_wave

    def __enter__(self):
        def timed(caches, token, slots, position, enc_states=None, **kw):
            import jax
            t0 = time.perf_counter()
            out = self._orig(caches, token, slots, position,
                             enc_states=enc_states, **kw)
            jax.block_until_ready(out[0])
            self.times_s.append(time.perf_counter() - t0)
            return out
        self.backend.decode_wave = timed
        return self

    def __exit__(self, *exc):
        self.backend.decode_wave = self._orig
        return False


def _build_engines(kv_slots: int, max_seq: int):
    """Two engines over ONE model + datastore: ``kernel`` — the default
    decode-attention path (grouped ref + per-wave ``kv_len`` crop) —
    and ``legacy`` — the pre-kernel shapes (``attn_backend="einsum"``
    with ``attn_seq_block=max_seq``, i.e. full-pool attention reads).
    Measuring both in adjacent paired windows inside one process is the
    only comparison that survives this host's multi-second noise
    epochs; cross-run deltas against an old committed file do not."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.models import transformer as tf
    from repro.serve import (DatastoreBuilder, RagConfig, RalmEngine,
                             ServiceConfig)

    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    start = rng.integers(0, 64, size=(64,))
    seqs = [start]
    for _ in range(31):
        seqs.append((3 * seqs[-1] + 1) % 64)
    corpus = np.stack(seqs, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                          list_cap=512).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    engines, arets = {}, {}
    for mode, attn_kw in (("kernel", {}),
                          ("legacy", dict(attn_backend="einsum",
                                          attn_seq_block=max_seq))):
        aret = ds.async_retriever(ccfg,
                                  service_cfg=ServiceConfig(measure=True))
        engines[mode] = RalmEngine.monolithic(params, cfg, rag, aret,
                                              max_seq=max_seq,
                                              kv_slots=kv_slots, **attn_kw)
        arets[mode] = aret
    return engines, corpus, arets


def run_sweep(wave_sizes: Sequence[int] = (1, 2, 4, 8),
              steps: int = 48, prompt_len: int = 8,
              repeats: int = 7,
              pool_seqs: Sequence[Optional[int]] = (None, 512)
              ) -> List[Dict[str, object]]:
    """One row per (pool provisioning, wave size). Points of one
    provisioning share one engine pair (fixed pool shape + jit cache);
    each point submits ``w`` single-row requests decoded in lockstep,
    best-of-``repeats`` wall clock.

    ``pool_seqs`` sweeps the pool's provisioned context budget:
    ``None`` = tight (``max_seq = prompt + steps``, zero padding
    headroom — the configuration where length-aware attention cannot
    help by construction) and a provisioned value (the continuous-
    batching steady state: the pool sized for the deployment's longest
    request, live rows much shorter — where the legacy path pays the
    full padded axis every step and the crop wins).

    The timed window is the steady-state decode loop: admission
    (prefill + the free step-0 token) runs before the clock starts, so
    tokens/s isolates the wave-batching lever — ``steps - 1`` decode
    waves over ``w`` rows — from the per-request prefill cost."""
    import numpy as np

    import jax.numpy as jnp

    from repro.serve import RalmRequest

    max_wave = max(wave_sizes)

    rows: List[Dict[str, object]] = []
    for pool_seq in pool_seqs:
        max_seq = pool_seq if pool_seq is not None else prompt_len + steps
        # pre-align to the kernel engine's seq block (16) so BOTH A/B
        # engines run the same pool shape — otherwise the kernel side
        # alone pays the alignment padding and the pair is biased
        max_seq = -(-max_seq // 16) * 16
        engines, corpus, arets = _build_engines(
            kv_slots=max_wave, max_seq=max_seq)

        def run_once(engine, w: int) -> float:
            for i in range(w):
                engine.submit(RalmRequest(
                    prompt=jnp.asarray(corpus[i:i + 1, :prompt_len]),
                    steps=steps))
            engine.step()                # admission + step 0 (untimed)
            t0 = time.perf_counter()
            engine.run()
            return time.perf_counter() - t0

        rows.extend(_sweep_waves(engines, arets, run_once, wave_sizes,
                                 steps, prompt_len, max_seq, repeats, np))
    return rows


def _sweep_waves(engines, arets, run_once, wave_sizes, steps, prompt_len,
                 max_seq, repeats, np) -> List[Dict[str, object]]:
    rows: List[Dict[str, object]] = []
    for w in wave_sizes:
        engine = engines["kernel"]
        pre_buckets = set(engine.pool.stats.buckets) if engine.pool else set()
        pre_graphs = (set(engine.pool.stats.compiled) if engine.pool
                      else set())
        pre_blocks = ((engine.pool.stats.blocks_total,
                       engine.pool.stats.blocks_skipped)
                      if engine.pool else (0, 0))
        for mode in ("legacy", "kernel"):
            run_once(engines[mode], w)   # warmup: compile this bucket
        best = {}
        lm_samples = {"legacy": [], "kernel": []}
        for _ in range(repeats):
            # adjacent alternating windows, legacy then kernel, so both
            # modes sample the same host noise epochs; the reported
            # speedup is the ratio of per-mode MEDIANS (a single run
            # spans an appreciable fraction of an epoch, so per-pair
            # ratios are noisier than the medians themselves)
            for mode in ("legacy", "kernel"):
                eng = engines[mode]
                arets[mode].service.stats.reset()
                base_dispatch = eng.decode_dispatches
                with _TimedWave(eng.backend) as t:
                    wall = run_once(eng, w)
                lm_us = (sum(t.times_s) / len(t.times_s) * 1e6
                         if t.times_s else 0.0)
                lm_samples[mode].append(lm_us)
                if mode not in best or wall < best[mode][0]:
                    # keep the retrieval-stage snapshot of the SAME
                    # repeat the wall-clock/LM numbers come from, so
                    # each row's per-pool breakdown is consistent
                    best[mode] = (wall, eng.decode_dispatches -
                                  base_dispatch, lm_us,
                                  arets[mode].service.stats.snapshot())
        wall, dispatches, lm_us, snap = best["kernel"]
        ntok = w * (steps - 1)
        rows.append(dict(
            wave=w, steps=steps, prompt_len=prompt_len, pool_seq=max_seq,
            tokens_per_s=ntok / wall,
            us_per_token=wall / ntok * 1e6,
            wall_s=wall,
            decode_dispatches=dispatches,
            lm_step_us=float(np.median(lm_samples["kernel"])),
            lm_step_us_legacy=float(np.median(lm_samples["legacy"])),
            lm_step_us_best=lm_us,
            tokens_per_s_legacy=ntok / best["legacy"][0],
            # the honest decode-attn claim: per-mode lm-step medians
            # over adjacent alternating windows, legacy / kernel
            lm_speedup=float(np.median(lm_samples["legacy"])
                             / np.median(lm_samples["kernel"])),
            queue_wait_us=snap["queue_wait"]["mean_us"],
            scan_us=snap["scan"]["mean_us"],
            merge_us=snap["merge"]["mean_us"],
            search_batches=snap["num_batches"],
            coalescing_factor=snap["coalescing_factor"],
            # buckets this point compiled/used (pool stats are
            # cumulative across the sweep, so report the delta)
            buckets=sorted(set(engine.pool.stats.buckets) - pre_buckets),
            # length-aware decode attention: seq blocks skipped vs a
            # full-pool read, and the decode graphs this point added
            attn_skip_fraction=(
                (engine.pool.stats.blocks_skipped - pre_blocks[1])
                / max(engine.pool.stats.blocks_total - pre_blocks[0], 1)),
            decode_graphs=sorted(
                set(engine.pool.stats.compiled) - pre_graphs),
        ))
    return rows


def main(out_path: str = "BENCH_serve.json") -> None:
    rows = run_sweep()
    meta = dict(
        note="kernel rows (the headline fields) run the default decode-"
             "attention path: grouped-ref flavor + per-wave kv_len crop "
             "(attn_seq_block 16). lm_step_us_legacy / lm_speedup come "
             "from a second engine with attn_backend='einsum' and "
             "attn_seq_block=max_seq — the exact pre-kernel shapes — "
             "measured in ADJACENT ALTERNATING windows in the same "
             "process; lm_speedup is the ratio of per-mode lm-step "
             "MEDIANS (cross-run deltas on this host are noise-epoch-"
             "dominated and not comparable). pool_seq sweeps the "
             "provisioned "
             "context budget: the tight pool (prompt+steps, zero "
             "padding headroom) is where length-aware attention cannot "
             "help by construction — expect lm_speedup ~1.0 there; the "
             "provisioned pool is the continuous-batching steady state "
             "the crop targets.")
    with open(out_path, "w") as f:
        json.dump(dict(meta=meta, rows=rows), f, indent=2)
    print("pool_seq,wave,tokens_per_s,lm_step_us,lm_step_us_legacy,"
          "lm_speedup,scan_us,dispatches,attn_skip")
    for r in rows:
        print(f"{r['pool_seq']},{r['wave']},{r['tokens_per_s']:.1f},"
              f"{r['lm_step_us']:.1f},"
              f"{r['lm_step_us_legacy']:.1f},{r['lm_speedup']:.2f},"
              f"{r['scan_us']:.1f},{r['decode_dispatches']},"
              f"{r['attn_skip_fraction']:.2f}")
    pools = sorted(set(r["pool_seq"] for r in rows))
    mono = True
    for p in pools:
        tps = [r["tokens_per_s"] for r in rows if r["pool_seq"] == p]
        mono &= all(b >= a * 0.98 for a, b in zip(tps, tps[1:]))
    lm_faster = all(r["lm_speedup"] >= 1.0 for r in rows
                    if r["wave"] >= 4 and r["pool_seq"] == max(pools))
    print(f"wrote {out_path} ({len(rows)} rows); "
          f"monotonic-or-flat per pool: {mono}; lm_step reduced at "
          f"wave>=4 on the provisioned pool: {lm_faster}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    main()
