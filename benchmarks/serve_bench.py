"""Serving throughput sweep: tokens/s vs. active wave size over the
wave-batched ``RalmEngine`` (one LM dispatch + one retrieval dispatch
per scheduler wave), with the per-pool step breakdown — LM decode time
from a blocking timer around ``decode_wave``, retrieval stage times from
``repro.retrieval.stats``.

Run via ``python -m benchmarks.run --mode serve``; emits
``BENCH_serve.json`` with one row per wave size. The acceptance claim is
that tokens/s improves monotonically-or-flat from wave size 1 to the
max bucket: the whole wave rides one dispatch, so adding rows amortizes
the per-step dispatch + kernel fixed costs (paper §5, Fig. 9/12 batch
sweeps).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Sequence


class _TimedWave:
    """Blocking timer around a backend's ``decode_wave`` (the LM-pool
    side of the per-pool breakdown; retrieval stages come from the
    service stats, which block per flush the same way)."""

    def __init__(self, backend):
        self.backend = backend
        self.times_s: List[float] = []
        self._orig = backend.decode_wave

    def __enter__(self):
        def timed(caches, token, slots, position, enc_states=None):
            import jax
            t0 = time.perf_counter()
            out = self._orig(caches, token, slots, position,
                             enc_states=enc_states)
            jax.block_until_ready(out[0])
            self.times_s.append(time.perf_counter() - t0)
            return out
        self.backend.decode_wave = timed
        return self

    def __exit__(self, *exc):
        self.backend.decode_wave = self._orig
        return False


def _build_engine(kv_slots: int, max_seq: int):
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.models import transformer as tf
    from repro.serve import (DatastoreBuilder, RagConfig, RalmEngine,
                             ServiceConfig)

    cfg = dataclasses.replace(get_arch("dec_s").reduced, vocab_size=64)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    start = rng.integers(0, 64, size=(64,))
    seqs = [start]
    for _ in range(31):
        seqs.append((3 * seqs[-1] + 1) % 64)
    corpus = np.stack(seqs, axis=1).astype(np.int32)
    ds = DatastoreBuilder(dim=cfg.d_model, nlist=8, m=8,
                          list_cap=512).from_corpus(params, cfg, corpus)
    ccfg = ds.search_config(nprobe=4, k=8, backend="ref")
    rag = RagConfig(mode="knnlm", interval=1, k=8, lam=0.999,
                    temperature=1.0)
    aret = ds.async_retriever(ccfg, service_cfg=ServiceConfig(measure=True))
    engine = RalmEngine.monolithic(params, cfg, rag, aret,
                                   max_seq=max_seq, kv_slots=kv_slots)
    return engine, corpus, aret


def run_sweep(wave_sizes: Sequence[int] = (1, 2, 4, 8),
              steps: int = 48, prompt_len: int = 8,
              repeats: int = 5) -> List[Dict[str, object]]:
    """One row per wave size. All points share one engine (and so one
    fixed pool shape + jit cache); each point submits ``w`` single-row
    requests decoded in lockstep, best-of-``repeats`` wall clock.

    The timed window is the steady-state decode loop: admission
    (prefill + the free step-0 token) runs before the clock starts, so
    tokens/s isolates the wave-batching lever — ``steps - 1`` decode
    waves over ``w`` rows — from the per-request prefill cost."""
    import jax.numpy as jnp

    from repro.serve import RalmRequest

    max_wave = max(wave_sizes)
    engine, corpus, aret = _build_engine(
        kv_slots=max_wave, max_seq=prompt_len + steps)

    def run_once(w: int) -> float:
        for i in range(w):
            engine.submit(RalmRequest(
                prompt=jnp.asarray(corpus[i:i + 1, :prompt_len]),
                steps=steps))
        engine.step()                    # admission + step 0 (untimed)
        t0 = time.perf_counter()
        engine.run()
        return time.perf_counter() - t0

    rows: List[Dict[str, object]] = []
    for w in wave_sizes:
        pre_buckets = set(engine.pool.stats.buckets) if engine.pool else set()
        run_once(w)                      # warmup: compile this bucket
        best = None
        for _ in range(repeats):
            aret.service.stats.reset()
            base_dispatch = engine.decode_dispatches
            with _TimedWave(engine.backend) as t:
                wall = run_once(w)
            if best is None or wall < best[0]:
                # keep the retrieval-stage snapshot of the SAME repeat
                # the wall-clock/LM numbers come from, so each row's
                # per-pool breakdown is internally consistent
                best = (wall, engine.decode_dispatches - base_dispatch,
                        t, aret.service.stats.snapshot())
        wall, dispatches, timer, snap = best
        ntok = w * (steps - 1)
        rows.append(dict(
            wave=w, steps=steps, prompt_len=prompt_len,
            tokens_per_s=ntok / wall,
            us_per_token=wall / ntok * 1e6,
            wall_s=wall,
            decode_dispatches=dispatches,
            lm_step_us=(sum(timer.times_s) / len(timer.times_s) * 1e6
                        if timer.times_s else 0.0),
            queue_wait_us=snap["queue_wait"]["mean_us"],
            scan_us=snap["scan"]["mean_us"],
            merge_us=snap["merge"]["mean_us"],
            search_batches=snap["num_batches"],
            coalescing_factor=snap["coalescing_factor"],
            # buckets this point compiled/used (pool stats are
            # cumulative across the sweep, so report the delta)
            buckets=sorted(set(engine.pool.stats.buckets) - pre_buckets),
        ))
    return rows


def main(out_path: str = "BENCH_serve.json") -> None:
    rows = run_sweep()
    with open(out_path, "w") as f:
        json.dump(dict(rows=rows), f, indent=2)
    print("wave,tokens_per_s,lm_step_us,scan_us,merge_us,dispatches")
    for r in rows:
        print(f"{r['wave']},{r['tokens_per_s']:.1f},{r['lm_step_us']:.1f},"
              f"{r['scan_us']:.1f},{r['merge_us']:.1f},"
              f"{r['decode_dispatches']}")
    tps = [r["tokens_per_s"] for r in rows]
    mono = all(b >= a * 0.98 for a, b in zip(tps, tps[1:]))
    print(f"wrote {out_path} ({len(rows)} rows); "
          f"monotonic-or-flat: {mono}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    main()
